"""Train a small LM for a few hundred steps with the full substrate:
AdamW + cosine schedule, grad accumulation, async checkpointing, auto-resume.

    PYTHONPATH=src python examples/train_tiny.py --steps 200
(~20M params by default; --arch mamba2-2.7b --reduced etc. also work via
 repro.launch.train)
"""
import argparse
import shutil

from repro.config import ModelConfig, OptimizerConfig, ShardingConfig, TrainConfig
from repro.models import build_model
from repro.training import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_tiny")
    args = ap.parse_args()
    if args.fresh:
        shutil.rmtree(args.ckpt, ignore_errors=True)

    cfg = ModelConfig(
        name="tiny-20m", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=768, vocab_size=8192,
        activation="swiglu", dtype="float32")
    model = build_model(cfg, param_dtype="float32")
    tc = TrainConfig(
        model="tiny-dense", batch_size=8, seq_len=128, steps=args.steps,
        log_every=20, checkpoint_every=50, checkpoint_dir=args.ckpt,
        optimizer=OptimizerConfig(lr=6e-4, warmup_steps=40,
                                  total_steps=args.steps),
        sharding=ShardingConfig(gradient_accum=2))
    trainer = Trainer(tc, model=model)
    trainer.initialize()
    hist = trainer.train()
    print(f"\nfinal loss {hist[-1][1]:.3f} (start {hist[0][1]:.3f}); "
          f"checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
