"""Cluster-scale what-if simulation: sweep bandwidth/failure/hedging knobs on
the discrete-event cluster simulator (the §4 experiments generalized).

    PYTHONPATH=src python examples/cluster_sim.py
    PYTHONPATH=src python examples/cluster_sim.py --topology edge-regional-cloud
"""
import argparse
import collections

from repro.config import (PolicyConfig, SimConfig, TOPOLOGIES, TierConfig,
                          get_topology)
from repro.data.synthetic import RequestGenerator
from repro.serving.simulator import ClusterSimulator, EdgeCloudSimulator


def run(policy, bw=300e6, fail=0.0, hedge=0.0, n=400, rate=1.1):
    cfg = SimConfig(
        bandwidth_bps=bw, seed=1,
        edge=TierConfig("edge", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.15),
        cloud=TierConfig("cloud", "qwen2.5-vl-7b", 1, 312e12, 1555e9, mfu=0.42))
    sim = EdgeCloudSimulator(cfg, policy_name=policy,
                             policy_cfg=PolicyConfig(adaptive_tau=True),
                             fail_rate=fail, hedge_after_s=hedge,
                             cloud_servers=1, edge_servers=1)
    for r in RequestGenerator(seed=0, arrival_rate=rate).generate(n):
        sim.submit(r)
    sim.run()
    return sim.metrics()


def run_topology(topology_name, policy="moa-off", n=400, rate=2.5, seed=1):
    topo = get_topology(topology_name)
    sim = ClusterSimulator(SimConfig(seed=seed), policy_name=policy,
                           policy_cfg=PolicyConfig(adaptive_tau=True),
                           topology=topo)
    for r in RequestGenerator(seed=0, arrival_rate=rate).generate(n):
        sim.submit(r)
    sim.run()
    return sim, sim.metrics()


def main_two_tier():
    print("bandwidth sweep (moa-off):")
    for bw in (100e6, 200e6, 400e6, 800e6):
        m = run("moa-off", bw=bw)
        print(f"  {bw/1e6:5.0f} Mbps: lat={m['mean_latency_s']:.2f}s "
              f"acc={m['accuracy']*100:.1f}% frac_edge={m['frac_edge']:.2f}")

    print("\nfault tolerance (10% node failures, heartbeat retry):")
    for pol in ("moa-off", "cloud-only"):
        base = run(pol)
        faulty = run(pol, fail=0.10)
        hedged = run(pol, fail=0.10, hedge=2.0)
        print(f"  {pol:10s} lat: clean={base['mean_latency_s']:.2f}s "
              f"faulty={faulty['mean_latency_s']:.2f}s "
              f"faulty+hedge={hedged['mean_latency_s']:.2f}s "
              f"(retries/req={faulty['retries']:.2f})")

    print("\nstraggler mitigation (hedged requests on the slow tail):")
    m0 = run("moa-off", fail=0.05)
    m1 = run("moa-off", fail=0.05, hedge=1.5)
    print(f"  p99 without hedging: {m0['p99_latency_s']:.2f}s; "
          f"with: {m1['p99_latency_s']:.2f}s "
          f"({100 * m1['hedged']:.1f}% of requests hedged)")


def main_topology(name):
    print(f"multi-tier what-if on topology '{name}':")
    for pol in ("moa-off", "cloud-only", "edge-only", "perllm"):
        sim, m = run_topology(name, policy=pol)
        served = collections.Counter(o.served_tier for o in sim.outcomes)
        split = " ".join(f"{t}={served.get(t, 0)}"
                         for t in sim.topology.names)
        print(f"  {pol:12s} lat={m['mean_latency_s']:6.2f}s "
              f"acc={m['accuracy']*100:5.1f}% frac_local={m['frac_local']:.2f}"
              f" | served: {split}")
    sim, m = run_topology(name)
    print("\n  per-tier utilization / compute (moa-off):")
    for t in sim.topology.names:
        print(f"    {t:9s} util={m[f'{t}_util']:.2f} "
              f"flops={m[f'{t}_flops']:.3g}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", default=None, choices=sorted(TOPOLOGIES),
                    help="run the multi-tier what-if on this topology "
                         "instead of the default two-tier sweeps")
    args = ap.parse_args()
    if args.topology:
        main_topology(args.topology)
    else:
        main_two_tier()
