"""End-to-end serving driver: two REAL models (the paper's edge/cloud pair,
reduced configs) behind the MoA-Off scheduler, continuous batching, batched
requests with images + text.

    PYTHONPATH=src python examples/serve_edge_cloud.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro.config import ServingConfig
from repro.configs import reduced_config
from repro.data.synthetic import make_image
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.tiers import EdgeCloudServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--hedge-after", type=float, default=0.0,
                    help="live straggler hedging threshold (seconds)")
    ap.add_argument("--fail-rate", type=float, default=0.0,
                    help="inject node faults recovered via snapshot/restore")
    args = ap.parse_args()

    print("building edge (Qwen2-VL-2B-reduced) and cloud "
          "(Qwen2.5-VL-7B-reduced) engines…")
    sv = ServingConfig(max_batch=4, max_seq=128)
    ecfg = reduced_config("qwen2-vl-2b").replace(dtype="float32")
    ccfg = reduced_config("qwen2.5-vl-7b").replace(dtype="float32")
    em, cm = build_model(ecfg), build_model(ccfg)
    edge = TierEngine(em, em.init(jax.random.PRNGKey(0)), sv)
    cloud = TierEngine(cm, cm.init(jax.random.PRNGKey(1)), sv)
    server = EdgeCloudServer(edge, cloud, bandwidth_bps=300e6,
                             hedge_after_s=args.hedge_after,
                             fail_rate=args.fail_rate)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        u = rng.beta(1.6, 1.6)
        img = make_image(rng, u, 64, 64)
        text = (f"Question {i}: what is shown? "
                + "Also analyze Entity %d in detail. " % (i * 3) * rng.integers(0, 8))
        server.submit(text, image=img, max_new=args.max_new)

    results = server.run()
    dt = time.perf_counter() - t0
    n_edge = sum(r.tier == "edge" for r in results)
    print(f"\nserved {len(results)} requests in {dt:.1f}s "
          f"(edge={n_edge}, cloud={len(results) - n_edge})")
    for r in sorted(results, key=lambda r: r.rid):
        print(f"  rid={r.rid:3d} tier={r.tier:5s} routes={r.routes} "
              f"tokens={r.tokens[:4]}… lat={r.latency_s:.2f}s")
    # engine health
    print(f"\nedge heartbeat ok: {edge.heartbeat_ok()}, "
          f"cloud heartbeat ok: {cloud.heartbeat_ok()}")


if __name__ == "__main__":
    main()
