"""Quickstart: score multimodal inputs with the MoA-Off modality-aware module
and route them with the Eq. 5/6 policy.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import MoAOffScheduler, ModalityInput, Request
from repro.data.synthetic import make_image

rng = np.random.default_rng(0)
sched = MoAOffScheduler()  # kernel-backed scoring + adaptive Eq.5/6 policy

print("MoA-Off quickstart — per-modality complexity scoring & routing\n")
for i, (img_content, text) in enumerate([
    (0.1, "What color is the wall?"),
    (0.9, "Identify every Person and count the 37 objects near Building 9. "
          "Then explain how Region 4 relates to Region 7 in the scene. " * 3),
    (0.8, "Describe this."),
    (0.15, "List each Item with its Price and compare against Catalog 12. "
           "Cross-reference the Serial numbers 4451 through 4519." * 2),
]):
    img = make_image(rng, img_content, 256, 256)
    toks = text.split()
    req = Request(rid=i, arrival_s=0.0, modalities={
        "image": ModalityInput("image", data=img, size_bytes=img.size // 2),
        "text": ModalityInput("text", meta={
            "tokens": len(toks),
            "entities": sum(w[0].isupper() or w.isdigit() for w in toks),
            "sentences": max(1, text.count(".")),
        }),
    })
    decision = sched.route(req)
    scores = {k: round(m.complexity, 3) for k, m in req.modalities.items()}
    print(f"request {i}: scores={scores}")
    print(f"           routes={decision.routes}"
          f"   (fusion tier: {'cloud' if decision.any_cloud else 'edge'})\n")

print(f"modality-aware module mean cost: {sched.mean_score_cost_s()*1e3:.2f} ms"
      " (Pallas kernel in interpret mode on CPU; microseconds on TPU)")
