"""§4.2.3 claim: the modality-aware module is "orders of magnitude lighter
than running the MLLM". Microbenchmarks the complexity-scoring path
(CPU wall time here; FLOP comparison is hardware-independent)."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import RESULTS_DIR, write_csv
from repro.configs import get_config
from repro.core.complexity import image_complexity, text_complexity_from_counts
from repro.serving.cost_model import prefill_flops


def _time(fn, *args, reps=5, **kw):
    jax.block_until_ready(fn(*args, **kw))  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        # sync INSIDE the loop: otherwise async dispatch overlaps reps and
        # the mean under-reports true per-call latency
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / reps


def run():
    rng = np.random.default_rng(0)
    rows = []
    for hw in (256, 512, 1024):
        imgs = rng.uniform(0, 255, (1, hw, hw)).astype(np.float32)
        sec = _time(image_complexity, imgs)
        # single-pass stencils+histogram ~ 30 flops/pixel
        score_flops = 30.0 * hw * hw
        mllm_flops = prefill_flops(get_config("qwen2.5-vl-7b"), 64, 256)
        rows.append({"name": f"image_complexity_{hw}",
                     "us_per_call": sec * 1e6,
                     "score_flops": score_flops,
                     "mllm_prefill_flops": mllm_flops,
                     "flops_ratio": mllm_flops / score_flops})
    sec = _time(lambda: text_complexity_from_counts(
        np.full(64, 512), np.full(64, 12), np.full(64, 6)))
    rows.append({"name": "text_complexity_b64", "us_per_call": sec * 1e6,
                 "score_flops": 64 * 8, "mllm_prefill_flops": 0,
                 "flops_ratio": 0})
    path = write_csv(rows, os.path.join(RESULTS_DIR, "kernel_micro.csv"),
                     list(rows[0].keys()))
    print("\n§4.2.3 — modality-module overhead:")
    for r in rows:
        extra = (f"  ({r['flops_ratio']:.1e}x lighter than MLLM prefill)"
                 if r["flops_ratio"] else "")
        print(f"  {r['name']:24s} {r['us_per_call']:10.1f} us{extra}")
    return rows, path


if __name__ == "__main__":
    run()
