"""Table 1 reproduction: accuracy (%) per dataset x bandwidth x policy.

Validation targets from the paper:
  · MoA-Off within <0.4pp of cloud-only,
  · MoA-Off beats edge-only and PerLLM by >4.8pp absolute.
"""
from __future__ import annotations

import os

from benchmarks.common import (BANDWIDTHS, DATASETS, POLICIES, RESULTS_DIR,
                               run_grid, write_csv)


def run(n=None):
    rows = run_grid(n=n) if n else run_grid()
    path = write_csv(rows, os.path.join(RESULTS_DIR, "table1_accuracy.csv"),
                     ["dataset", "bandwidth_mbps", "policy", "accuracy",
                      "frac_edge", "n"])

    # pivot like the paper's Table 1
    print("\nTable 1 — Accuracy (%) comparison")
    print(f"{'':16s}" + "".join(f"{p:>12s}" for p in POLICIES))
    checks = []
    for ds in DATASETS:
        print(f"-- {ds} --")
        for bw in BANDWIDTHS:
            line = {r["policy"]: r for r in rows
                    if r["dataset"] == ds and r["bandwidth_mbps"] == bw / 1e6}
            print(f"{int(bw / 1e6):>4d} Mbps       " + "".join(
                f"{100 * line[p]['accuracy']:>12.1f}" for p in POLICIES))
            moa = 100 * line["moa-off"]["accuracy"]
            cloud = 100 * line["cloud-only"]["accuracy"]
            edge = 100 * line["edge-only"]["accuracy"]
            per = 100 * line["perllm"]["accuracy"]
            checks.append({
                "cell": f"{ds}@{int(bw / 1e6)}",
                "moa_vs_cloud_pp": round(moa - cloud, 2),
                "moa_vs_edge_pp": round(moa - edge, 2),
                "moa_vs_perllm_pp": round(moa - per, 2),
            })
    print("\npaper-claim checks (MoA-Off deltas, pp):")
    for c in checks:
        print(f"  {c['cell']:14s} vs cloud {c['moa_vs_cloud_pp']:+5.2f} "
              f"| vs edge {c['moa_vs_edge_pp']:+5.2f} "
              f"| vs perllm {c['moa_vs_perllm_pp']:+5.2f}")
    return rows, checks, path


if __name__ == "__main__":
    run()
