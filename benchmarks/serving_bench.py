"""Live-engine serving benchmark: prefill throughput, decode tokens/s, TTFT.

Benchmarks the REAL ``TierEngine`` hot path (not the discrete-event
simulator) at several batch sizes, in both modes:

* ``legacy`` — ``fused_steps=1``: the pre-PR per-token path (one jitted
  dispatch + one host logits sync + host numpy sampling per token, one
  retraced prefill per request, non-donated cache);
* ``fused``  — ``fused_steps=K``: the device-resident path (K-step jitted
  scan with on-device sampling, donated KV cache/keys, bucketed batched
  prefill with a donated scatter insert).

Emits ``BENCH_serving.json`` at the repo root so every PR records the perf
trajectory (CI uploads it as an artifact; ``--smoke`` runs a single batch
size with short timing loops).

    PYTHONPATH=src python benchmarks/serving_bench.py            # full grid
    PYTHONPATH=src python benchmarks/serving_bench.py --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time
from typing import List

import jax
import numpy as np

from repro.config import ServingConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.serving.engine import TierEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_serving.json")


def _engine(cfg, params, max_batch: int, max_seq: int, fused: int,
            decode_impl: str) -> TierEngine:
    sv = ServingConfig(max_batch=max_batch, max_seq=max_seq,
                       fused_steps=fused, decode_impl=decode_impl)
    # unreachable EOS: a random-init model must never end a timed rollout
    # early (greedy argmax could otherwise hit a real vocab id mid-timing)
    return TierEngine(build_model(cfg), params, sv, eos_id=-1)


def _prompt(length: int) -> np.ndarray:
    return (np.arange(length) % 200 + 4).astype(np.int32)


def bench_prefill(eng: TierEngine, prompt_len: int, rounds: int) -> dict:
    """All-slots batched admission with max_new=1 (prefill-dominated)."""
    b = len(eng.slots)

    def round_once():
        for rid in range(b):
            eng.submit(rid, _prompt(prompt_len), max_new=1)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        states, eng.finished = eng.finished, []
        ttft = [s.t_first_token - s.t_submit for s in states]
        return dt, ttft

    round_once()  # compile warmup (same shapes as the timed rounds)
    total_s, ttfts = 0.0, []
    for _ in range(rounds):
        dt, ttft = round_once()
        total_s += dt
        ttfts.extend(ttft)
    return {
        "prefill_tok_s": b * prompt_len * rounds / total_s,
        "ttft_ms": float(np.mean(ttfts) * 1e3),
    }


def bench_decode(eng: TierEngine, prompt_len: int, tokens_per_slot: int,
                 reps: int = 3) -> dict:
    """Steady-state decode: all slots busy, no admissions during timing.

    Each fill generates ``tokens_per_slot`` tokens per request against the
    capacity-sized cache (requests don't run to the cache limit — the
    headroom is what context buckets exploit); several fill→time→clear
    cycles are aggregated to ride out scheduler noise, and the rate comes
    from the engine's own ``decode_tokens`` counter, not assumed counts.
    """
    b = len(eng.slots)
    k = max(1, eng.fused_steps)
    warm = 2
    capacity_calls = (eng.serving.max_seq - prompt_len - 2) // k
    iters = max(1, min(tokens_per_slot // k, capacity_calls - warm))

    def clear():
        eng.slots = [None] * b
        eng.positions[:] = 0
        eng.finished.clear()

    total_s, total_tok = 0.0, 0
    for rep in range(reps + 1):
        for rid in range(b):
            eng.submit(rid, _prompt(prompt_len), max_new=10**9)
        for _ in range(warm):  # admit (+ compile on the first rep)
            eng.step()
        tok0 = eng.decode_tokens
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.step()
        dt = time.perf_counter() - t0
        toks = eng.decode_tokens - tok0
        assert all(s is not None for s in eng.slots), "slot died mid-timing"
        assert toks == b * k * iters, (toks, b, k, iters)
        clear()
        if rep == 0:
            continue  # discard the compile rep
        total_s += dt
        total_tok += toks
    return {
        "decode_tok_s": total_tok / total_s,
        "decode_iters": iters * reps,
        "tokens_per_host_call": b * k,
    }


def bench_serving(eng: TierEngine, prompt_len: int, rounds: int) -> dict:
    """End-to-end continuous batching: 4x oversubscribed request stream,
    tokens/s over the full run (prefill + decode + refills)."""
    b = len(eng.slots)

    def round_once():
        for rid in range(4 * b):
            eng.submit(rid, _prompt(prompt_len + (rid % 3) * 5),
                       max_new=16 + (rid % 2) * 8)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        eng.finished.clear()
        return dt

    round_once()  # compile warmup
    tok0 = eng.decode_tokens
    total = sum(round_once() for _ in range(rounds))
    return {"served_tok_s": (eng.decode_tokens - tok0) / total}


def run(batches: List[int], max_seq: int, fused_steps: int, prompt_len: int,
        decode_tokens: int, prefill_rounds: int, model_name: str,
        decode_impl: str) -> dict:
    cfg = reduced_config(model_name).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results = []
    speedup = {}
    for b in batches:
        row = {}
        for mode, fused in (("legacy", 1), ("fused", fused_steps)):
            eng = _engine(cfg, params, b, max_seq, fused, decode_impl)
            r = {"max_batch": b, "mode": mode, "fused_steps": fused}
            r.update(bench_prefill(eng, prompt_len, prefill_rounds))
            # each phase gets a fresh engine of the same mode (slots stay
            # pinned for the whole decode timing)
            eng = _engine(cfg, params, b, max_seq, fused, decode_impl)
            r.update(bench_decode(eng, prompt_len, decode_tokens))
            eng = _engine(cfg, params, b, max_seq, fused, decode_impl)
            r.update(bench_serving(eng, prompt_len, max(1, prefill_rounds // 2)))
            results.append(r)
            row[mode] = r
            print(f"  batch={b:2d} {mode:6s}: "
                  f"decode {r['decode_tok_s']:9.0f} tok/s | "
                  f"prefill {r['prefill_tok_s']:9.0f} tok/s | "
                  f"serve {r['served_tok_s']:8.0f} tok/s | "
                  f"ttft {r['ttft_ms']:7.2f} ms")
        speedup[str(b)] = {
            "decode": row["fused"]["decode_tok_s"] / row["legacy"]["decode_tok_s"],
            "prefill": row["fused"]["prefill_tok_s"] / row["legacy"]["prefill_tok_s"],
            "serving": row["fused"]["served_tok_s"] / row["legacy"]["served_tok_s"],
            "ttft": row["legacy"]["ttft_ms"] / row["fused"]["ttft_ms"],
        }
        print(f"  batch={b:2d} speedup: decode {speedup[str(b)]['decode']:.2f}x"
              f" | prefill {speedup[str(b)]['prefill']:.2f}x"
              f" | serving {speedup[str(b)]['serving']:.2f}x"
              f" | ttft {speedup[str(b)]['ttft']:.2f}x")

    return {
        "bench": "serving_hot_path",
        "created_unix": int(time.time()),
        "backend": jax.default_backend(),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "jax": jax.__version__},
        "model": f"{model_name} (reduced)",
        "dtype": "float32",
        "max_seq": max_seq,
        "prompt_len": prompt_len,
        "decode_tokens_per_slot": decode_tokens,
        "fused_steps": fused_steps,
        "decode_impl": decode_impl,
        "results": results,
        "speedup_fused_over_legacy": speedup,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: batch 8 only, short timing loops")
    ap.add_argument("--batches", type=int, nargs="+", default=None)
    ap.add_argument("--max-seq", type=int, default=256,
                    help="cache capacity (sized above the typical context, "
                         "as in real serving: the fused path's context "
                         "buckets only attend what's live)")
    ap.add_argument("--fused-steps", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=96,
                    help="tokens generated per request in the decode phase")
    ap.add_argument("--prefill-rounds", type=int, default=None)
    ap.add_argument("--model", default="qwen3-0.6b")
    ap.add_argument("--decode-impl", default="auto",
                    choices=["auto", "xla", "pallas"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    batches = args.batches or ([8] if args.smoke else [1, 4, 8])
    prefill_rounds = args.prefill_rounds or (3 if args.smoke else 5)

    print(f"serving bench: model={args.model} max_seq={args.max_seq} "
          f"fused_steps={args.fused_steps} backend={jax.default_backend()}")
    out = run(batches, args.max_seq, args.fused_steps, args.prompt_len,
              args.decode_tokens, prefill_rounds, args.model,
              args.decode_impl)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    key = str(max(batches))
    print(f"decode speedup at batch {key}: "
          f"{out['speedup_fused_over_legacy'][key]['decode']:.2f}x")


if __name__ == "__main__":
    main()
