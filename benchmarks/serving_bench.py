"""Live-engine serving benchmark: prefill throughput, decode tokens/s, TTFT.

Benchmarks the REAL ``TierEngine`` hot path (not the discrete-event
simulator) at several batch sizes, in both modes:

* ``legacy`` — ``fused_steps=1``: the pre-PR per-token path (one jitted
  dispatch + one host logits sync + host numpy sampling per token, one
  retraced prefill per request, non-donated cache);
* ``fused``  — ``fused_steps=K``: the device-resident path (K-step jitted
  scan with on-device sampling, donated KV cache/keys, bucketed batched
  prefill with a donated scatter insert).

Emits ``BENCH_serving.json`` at the repo root so every PR records the perf
trajectory (CI uploads it as an artifact; ``--smoke`` runs a single batch
size with short timing loops).

    PYTHONPATH=src python benchmarks/serving_bench.py            # full grid
    PYTHONPATH=src python benchmarks/serving_bench.py --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time
from typing import List

import jax
import numpy as np

from repro.config import ServingConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.serving.engine import TierEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_serving.json")


def _engine(cfg, params, max_batch: int, max_seq: int, fused: int,
            decode_impl: str) -> TierEngine:
    sv = ServingConfig(max_batch=max_batch, max_seq=max_seq,
                       fused_steps=fused, decode_impl=decode_impl)
    # unreachable EOS: a random-init model must never end a timed rollout
    # early (greedy argmax could otherwise hit a real vocab id mid-timing)
    return TierEngine(build_model(cfg), params, sv, eos_id=-1)


def _prompt(length: int) -> np.ndarray:
    return (np.arange(length) % 200 + 4).astype(np.int32)


def bench_prefill(eng: TierEngine, prompt_len: int, rounds: int) -> dict:
    """All-slots batched admission with max_new=1 (prefill-dominated)."""
    b = len(eng.slots)

    def round_once():
        for rid in range(b):
            eng.submit(rid, _prompt(prompt_len), max_new=1)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        states, eng.finished = eng.finished, []
        ttft = [s.t_first_token - s.t_submit for s in states]
        return dt, ttft

    round_once()  # compile warmup (same shapes as the timed rounds)
    total_s, ttfts = 0.0, []
    for _ in range(rounds):
        dt, ttft = round_once()
        total_s += dt
        ttfts.extend(ttft)
    return {
        "prefill_tok_s": b * prompt_len * rounds / total_s,
        "ttft_ms": float(np.mean(ttfts) * 1e3),
    }


def bench_decode(eng: TierEngine, prompt_len: int, tokens_per_slot: int,
                 reps: int = 3) -> dict:
    """Steady-state decode: all slots busy, no admissions during timing.

    Each fill generates ``tokens_per_slot`` tokens per request against the
    capacity-sized cache (requests don't run to the cache limit — the
    headroom is what context buckets exploit); several fill→time→clear
    cycles are aggregated to ride out scheduler noise, and the rate comes
    from the engine's own ``decode_tokens`` counter, not assumed counts.
    """
    b = len(eng.slots)
    k = max(1, eng.fused_steps)
    warm = 2
    capacity_calls = (eng.serving.max_seq - prompt_len - 2) // k
    iters = max(1, min(tokens_per_slot // k, capacity_calls - warm))

    def clear():
        eng.slots = [None] * b
        eng.positions[:] = 0
        eng.finished.clear()

    total_s, total_tok = 0.0, 0
    for rep in range(reps + 1):
        for rid in range(b):
            eng.submit(rid, _prompt(prompt_len), max_new=10**9)
        for _ in range(warm):  # admit (+ compile on the first rep)
            eng.step()
        tok0 = eng.decode_tokens
        t0 = time.perf_counter()
        for _ in range(iters):
            eng.step()
        dt = time.perf_counter() - t0
        toks = eng.decode_tokens - tok0
        assert all(s is not None for s in eng.slots), "slot died mid-timing"
        assert toks == b * k * iters, (toks, b, k, iters)
        clear()
        if rep == 0:
            continue  # discard the compile rep
        total_s += dt
        total_tok += toks
    return {
        "decode_tok_s": total_tok / total_s,
        "decode_iters": iters * reps,
        "tokens_per_host_call": b * k,
    }


def bench_serving(eng: TierEngine, prompt_len: int, rounds: int) -> dict:
    """End-to-end continuous batching: 4x oversubscribed request stream,
    tokens/s over the full run (prefill + decode + refills)."""
    b = len(eng.slots)

    def round_once():
        for rid in range(4 * b):
            eng.submit(rid, _prompt(prompt_len + (rid % 3) * 5),
                       max_new=16 + (rid % 2) * 8)
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        eng.finished.clear()
        return dt

    round_once()  # compile warmup
    tok0 = eng.decode_tokens
    total = sum(round_once() for _ in range(rounds))
    return {"served_tok_s": (eng.decode_tokens - tok0) / total}


def bench_multiturn(cfg, params, max_batch: int, max_seq: int,
                    fused: int, decode_impl: str, n_sessions: int,
                    turns: int, sys_len: int, turn_len: int,
                    max_new: int) -> dict:
    """Multi-turn chat: ``n_sessions`` sequential sessions x ``turns``
    turns sharing one system prompt. ``warm`` parks each finished turn's
    KV (session store) and shares the system prefix across sessions
    (prefix store); ``cold`` is the sessionless replay — every turn
    re-prefills its full history. The first two sessions are compile
    warmup (excluded from the stats). The engines' own ``prefill_tokens``
    counters prove warm turns prefill only their suffixes."""
    rng = np.random.default_rng(0)
    sys_ids = _prompt(sys_len)
    out = {}
    for mode in ("cold", "warm"):
        sv = ServingConfig(
            max_batch=max_batch, max_seq=max_seq, fused_steps=fused,
            decode_impl=decode_impl,
            prefix_cache_mb=64.0 if mode == "warm" else 0.0,
            session_cache_mb=64.0 if mode == "warm" else 0.0)
        eng = TierEngine(build_model(cfg), params, sv, eos_id=-1)
        ttft = [[] for _ in range(turns)]
        prefill = [0 for _ in range(turns)]
        rid = 0
        t_rng = np.random.default_rng(7)  # same turn ids in both modes
        # sessions 0-1 are compile warmup: turn lengths are identical
        # across sessions, so they trace every (prefill bucket, suffix
        # bucket, context rung) combo the timed sessions hit — session 1
        # additionally covers the cross-session prefix-hit trace
        warmup = 2
        for s in range(n_sessions + warmup):
            hist = np.concatenate(
                [sys_ids, t_rng.integers(4, 200, turn_len).astype(np.int32)])
            for turn in range(turns):
                if turn > 0:
                    prev = eng.finished[-1].generated
                    hist = np.concatenate(
                        [hist, np.asarray(prev, np.int32),
                         t_rng.integers(4, 200, turn_len).astype(np.int32)])
                eng.finished.clear()
                pf0 = eng.prefill_tokens
                eng.submit(rid, hist, max_new=max_new,
                           session=(f"s{s}" if mode == "warm" else None))
                eng.run_until_drained()
                st = eng.finished[-1]
                if s >= warmup:
                    ttft[turn].append(st.t_first_token - st.t_submit)
                    prefill[turn] += eng.prefill_tokens - pf0
                rid += 1
            eng.finished.clear()
        out[mode] = {
            "turn_ttft_ms": [float(np.mean(t) * 1e3) for t in ttft],
            "turn_prefill_tokens": prefill,
            "prefill_tokens_total": int(sum(prefill)),
        }
        if mode == "warm":
            out[mode]["resumed_turns"] = eng.resumed_sessions
            out[mode]["prefix_hits"] = eng.prefix_hits
            out[mode]["cached_tokens_reused"] = (eng.resumed_tokens
                                                 + eng.prefix_hit_tokens)
    warm_t = np.mean(out["warm"]["turn_ttft_ms"][1:])
    cold_t = np.mean(out["cold"]["turn_ttft_ms"][1:])
    out["warm_turn_ttft_speedup"] = float(cold_t / warm_t)
    out["warm_turn_prefill_reduction"] = float(
        sum(out["cold"]["turn_prefill_tokens"][1:])
        / max(sum(out["warm"]["turn_prefill_tokens"][1:]), 1))
    out["config"] = {"sessions": n_sessions, "turns": turns,
                     "system_prompt_len": sys_len, "turn_len": turn_len,
                     "max_new": max_new, "max_seq": max_seq}
    print(f"  multiturn: warm turn>=2 ttft "
          f"{[f'{v:.1f}' for v in out['warm']['turn_ttft_ms']]} ms vs cold "
          f"{[f'{v:.1f}' for v in out['cold']['turn_ttft_ms']]} ms | "
          f"speedup {out['warm_turn_ttft_speedup']:.2f}x | prefill "
          f"{out['warm']['prefill_tokens_total']} vs "
          f"{out['cold']['prefill_tokens_total']} tok")
    return out


def _dense_kv_bytes(eng: TierEngine) -> float:
    """Device bytes the dense engine's KV cache reserves (allocated up
    front for max_batch x max_seq, used or not)."""
    return float(sum(leaf.nbytes for leaf in jax.tree.leaves(eng.cache)))


def bench_concurrency_sweep(cfg, params, base_batch: int, max_seq: int,
                            fused: int, decode_impl: str,
                            factors: List[int], prompt_len: int,
                            max_new: int) -> dict:
    """Paged vs dense at EQUAL device KV bytes, concurrency swept up to
    64x the dense slot count.

    The dense engine reserves ``base_batch`` full-length rows, so it can
    never run more than ``base_batch`` requests at once — excess requests
    queue. The paged engine gets the SAME pool bytes
    (``base_batch * max_seq / page`` pages) but slots are cheap (a page
    table each), so short requests pack the pool: at 8-64x concurrency it
    decodes everyone together while dense serializes. Reported per level:
    tokens/s over the drain, p95 TTFT, and peak KV bytes actually used
    (paged: high-water pages; dense: the full up-front reservation)."""
    page = 32
    pool_pages = base_batch * max_seq // page
    model = build_model(cfg)
    out = {"base_batch": base_batch, "max_seq": max_seq,
           "kv_page_size": page, "equal_pool_pages": pool_pages,
           "prompt_len": prompt_len, "max_new": max_new, "levels": []}
    for f in factors:
        n = base_batch * f
        row = {"factor": f, "concurrency": n}
        for mode in ("dense", "paged"):
            if mode == "dense":
                sv = ServingConfig(max_batch=base_batch, max_seq=max_seq,
                                   fused_steps=fused,
                                   decode_impl=decode_impl)
            else:
                sv = ServingConfig(max_batch=n, max_seq=max_seq,
                                   fused_steps=fused, decode_impl=decode_impl,
                                   paged=True, kv_page_size=page,
                                   kv_pool_pages=pool_pages)
            eng = TierEngine(model, params, sv, eos_id=-1)

            def round_once():
                for rid in range(n):
                    eng.submit(rid, _prompt(prompt_len + rid % 4),
                               max_new=max_new)
                t0 = time.perf_counter()
                eng.run_until_drained()
                dt = time.perf_counter() - t0
                states, eng.finished = eng.finished, []
                return dt, [s.t_first_token - s.t_submit for s in states]

            round_once()  # compile warmup (same shapes as the timed round)
            tok0 = eng.decode_tokens
            dt, ttft = round_once()
            g = eng.kv_gauges()
            row[mode] = {
                "tok_s": (eng.decode_tokens - tok0) / dt,
                "p95_ttft_ms": float(np.percentile(ttft, 95) * 1e3),
                "peak_kv_bytes": (g["pages_high_water"] * g["page_bytes"]
                                  if mode == "paged"
                                  else _dense_kv_bytes(eng)),
            }
        row["tok_s_ratio"] = row["paged"]["tok_s"] / row["dense"]["tok_s"]
        out["levels"].append(row)
        print(f"  conc={n:3d} ({f:2d}x): paged "
              f"{row['paged']['tok_s']:8.0f} tok/s "
              f"p95 {row['paged']['p95_ttft_ms']:8.1f} ms "
              f"{row['paged']['peak_kv_bytes'] / 1e6:7.2f} MB | dense "
              f"{row['dense']['tok_s']:8.0f} tok/s "
              f"p95 {row['dense']['p95_ttft_ms']:8.1f} ms "
              f"{row['dense']['peak_kv_bytes'] / 1e6:7.2f} MB | "
              f"ratio {row['tok_s_ratio']:.2f}x")
    return out


def bench_prefix_fanout(cfg, params, max_seq: int, fused: int,
                        decode_impl: str, n_clients: int, sys_len: int,
                        max_new: int) -> dict:
    """Shared-prefix fan-out: ``n_clients`` concurrent requests extend ONE
    long system prompt. Paged serving maps the stored prefix pages into
    every client copy-on-write (one physical copy, refcounted), so peak KV
    bytes stay near one prefix + n short tails; dense duplicates the
    prefix rows into every slot."""
    model = build_model(cfg)
    sys_ids = _prompt(sys_len)
    rng = np.random.default_rng(3)
    out = {"n_clients": n_clients, "system_prompt_len": sys_len,
           "max_new": max_new}
    for mode in ("dense", "paged"):
        kw = dict(max_batch=n_clients, max_seq=max_seq, fused_steps=fused,
                  decode_impl=decode_impl, prefix_cache_mb=64.0)
        if mode == "paged":
            kw.update(paged=True, kv_page_size=32)
        eng = TierEngine(model, params, ServingConfig(**kw), eos_id=-1)

        def round_once(rid0):
            eng.submit(rid0, sys_ids, max_new=1)  # seed the prefix store
            eng.run_until_drained()
            for i in range(n_clients):
                tail = rng.integers(4, 200, 8 + i % 4).astype(np.int32)
                eng.submit(rid0 + 1 + i, np.concatenate([sys_ids, tail]),
                           max_new=max_new)
            t0 = time.perf_counter()
            eng.run_until_drained()
            dt = time.perf_counter() - t0
            states = [s for s in eng.finished if s.rid > rid0]
            eng.finished.clear()
            return dt, [s.t_first_token - s.t_submit for s in states]

        round_once(0)  # compile warmup
        tok0, pf0 = eng.decode_tokens, eng.prefill_tokens
        dt, ttft = round_once(1000)
        g = eng.kv_gauges()
        out[mode] = {
            "tok_s": (eng.decode_tokens - tok0) / dt,
            "p95_ttft_ms": float(np.percentile(ttft, 95) * 1e3),
            "prefill_tokens": eng.prefill_tokens - pf0,
            "prefix_hits": eng.prefix_hits,
            "peak_kv_bytes": (g["pages_high_water"] * g["page_bytes"]
                              if mode == "paged" else _dense_kv_bytes(eng)),
        }
        if mode == "paged":
            out[mode]["pages_shared_peak"] = g["pages_shared"]
    out["kv_bytes_ratio_dense_over_paged"] = (
        out["dense"]["peak_kv_bytes"] / max(out["paged"]["peak_kv_bytes"], 1))
    print(f"  fanout x{n_clients}: paged {out['paged']['tok_s']:.0f} tok/s, "
          f"{out['paged']['peak_kv_bytes'] / 1e6:.2f} MB peak "
          f"({out['paged']['prefill_tokens']} tok prefilled) | dense "
          f"{out['dense']['tok_s']:.0f} tok/s, "
          f"{out['dense']['peak_kv_bytes'] / 1e6:.2f} MB | KV bytes "
          f"{out['kv_bytes_ratio_dense_over_paged']:.1f}x smaller paged")
    return out


def run(batches: List[int], max_seq: int, fused_steps: int, prompt_len: int,
        decode_tokens: int, prefill_rounds: int, model_name: str,
        decode_impl: str) -> dict:
    cfg = reduced_config(model_name).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results = []
    speedup = {}
    for b in batches:
        row = {}
        for mode, fused in (("legacy", 1), ("fused", fused_steps)):
            eng = _engine(cfg, params, b, max_seq, fused, decode_impl)
            r = {"max_batch": b, "mode": mode, "fused_steps": fused}
            r.update(bench_prefill(eng, prompt_len, prefill_rounds))
            # each phase gets a fresh engine of the same mode (slots stay
            # pinned for the whole decode timing)
            eng = _engine(cfg, params, b, max_seq, fused, decode_impl)
            r.update(bench_decode(eng, prompt_len, decode_tokens))
            eng = _engine(cfg, params, b, max_seq, fused, decode_impl)
            r.update(bench_serving(eng, prompt_len, max(1, prefill_rounds // 2)))
            results.append(r)
            row[mode] = r
            print(f"  batch={b:2d} {mode:6s}: "
                  f"decode {r['decode_tok_s']:9.0f} tok/s | "
                  f"prefill {r['prefill_tok_s']:9.0f} tok/s | "
                  f"serve {r['served_tok_s']:8.0f} tok/s | "
                  f"ttft {r['ttft_ms']:7.2f} ms")
        speedup[str(b)] = {
            "decode": row["fused"]["decode_tok_s"] / row["legacy"]["decode_tok_s"],
            "prefill": row["fused"]["prefill_tok_s"] / row["legacy"]["prefill_tok_s"],
            "serving": row["fused"]["served_tok_s"] / row["legacy"]["served_tok_s"],
            "ttft": row["legacy"]["ttft_ms"] / row["fused"]["ttft_ms"],
        }
        print(f"  batch={b:2d} speedup: decode {speedup[str(b)]['decode']:.2f}x"
              f" | prefill {speedup[str(b)]['prefill']:.2f}x"
              f" | serving {speedup[str(b)]['serving']:.2f}x"
              f" | ttft {speedup[str(b)]['ttft']:.2f}x")

    return {
        "bench": "serving_hot_path",
        "created_unix": int(time.time()),
        "backend": jax.default_backend(),
        "host": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "jax": jax.__version__},
        "model": f"{model_name} (reduced)",
        "dtype": "float32",
        "max_seq": max_seq,
        "prompt_len": prompt_len,
        "decode_tokens_per_slot": decode_tokens,
        "fused_steps": fused_steps,
        "decode_impl": decode_impl,
        "results": results,
        "speedup_fused_over_legacy": speedup,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: batch 8 only, short timing loops")
    ap.add_argument("--batches", type=int, nargs="+", default=None)
    ap.add_argument("--max-seq", type=int, default=256,
                    help="cache capacity (sized above the typical context, "
                         "as in real serving: the fused path's context "
                         "buckets only attend what's live)")
    ap.add_argument("--fused-steps", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-tokens", type=int, default=96,
                    help="tokens generated per request in the decode phase")
    ap.add_argument("--prefill-rounds", type=int, default=None)
    ap.add_argument("--model", default="qwen3-0.6b")
    ap.add_argument("--decode-impl", default="auto",
                    choices=["auto", "xla", "pallas"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()

    batches = args.batches or ([8] if args.smoke else [1, 4, 8])
    prefill_rounds = args.prefill_rounds or (3 if args.smoke else 5)

    print(f"serving bench: model={args.model} max_seq={args.max_seq} "
          f"fused_steps={args.fused_steps} backend={jax.default_backend()}")
    out = run(batches, args.max_seq, args.fused_steps, args.prompt_len,
              args.decode_tokens, prefill_rounds, args.model,
              args.decode_impl)
    print("multi-turn chat scenario (prefix & session KV reuse)…")
    cfg = reduced_config(args.model).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    out["multiturn_chat"] = bench_multiturn(
        cfg, params, max_batch=4, max_seq=1024, fused=args.fused_steps,
        decode_impl=args.decode_impl,
        n_sessions=1 if args.smoke else 3,
        turns=3 if args.smoke else 4, sys_len=320, turn_len=12,
        max_new=12)
    print("paged KV concurrency sweep (equal pool bytes)…")
    out["paged_concurrency_sweep"] = bench_concurrency_sweep(
        cfg, params, base_batch=4, max_seq=args.max_seq,
        fused=args.fused_steps, decode_impl=args.decode_impl,
        factors=[1, 8] if args.smoke else [1, 8, 16, 32, 64],
        prompt_len=args.prompt_len, max_new=16)
    print("shared-prefix fan-out (copy-free CoW sharing)…")
    out["paged_prefix_fanout"] = bench_prefix_fanout(
        cfg, params, max_seq=args.max_seq, fused=args.fused_steps,
        decode_impl=args.decode_impl,
        n_clients=8 if args.smoke else 16, sys_len=128, max_new=8)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    key = str(max(batches))
    print(f"decode speedup at batch {key}: "
          f"{out['speedup_fused_over_legacy'][key]['decode']:.2f}x")


if __name__ == "__main__":
    main()
