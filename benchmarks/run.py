"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (us_per_call = simulated mean
request latency or kernel wall time; derived = the paper-claim metric that
table validates).

REPRO_SIM_REQUESTS controls simulation size (default 1200; paper used 5000).
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    from benchmarks import (ablation, accuracy_table, kernel_micro,
                            latency_fig3, overhead_fig4, roofline)

    lines = ["name,us_per_call,derived"]

    t0 = time.perf_counter()
    rows, checks, _ = accuracy_table.run()
    moa = [r for r in rows if r["policy"] == "moa-off"]
    mean_lat = sum(r["mean_latency_s"] for r in moa) / len(moa)
    worst_gap = min(-c["moa_vs_cloud_pp"] for c in checks)
    lines.append(f"table1_accuracy,{mean_lat * 1e6:.0f},"
                 f"moa_vs_cloud_worst_pp={-worst_gap:.2f}")

    rows, checks, _ = latency_fig3.run()
    moa = [r for r in rows if r["policy"] == "moa-off"]
    mean_lat = sum(r["mean_latency_s"] for r in moa) / len(moa)
    red = min(c["red_vs_perllm_pct"] for c in checks)
    lines.append(f"fig3_latency,{mean_lat * 1e6:.0f},"
                 f"latency_reduction_vs_perllm_pct={red:.1f}")

    rows, checks, _ = overhead_fig4.run()
    red = min(c["compute_red_vs_cloud_pct"] for c in checks)
    lines.append(f"fig4_overhead,0,compute_reduction_vs_cloud_pct={red:.1f}")

    rows, out, _ = ablation.run()
    lines.append(f"ablation_4p3,0,acc_drop_no_modality_pp="
                 f"{out['acc_drop_no_modality_pp']:.2f}")
    lines.append(f"ablation_4p3b,0,latency_rise_no_collab_pct="
                 f"{out['latency_rise_no_collab_pct']:.1f}")

    krows, _ = kernel_micro.run()
    img = next(r for r in krows if r["name"] == "image_complexity_512")
    lines.append(f"kernel_micro,{img['us_per_call']:.0f},"
                 f"mllm_to_score_flops_ratio={img['flops_ratio']:.2e}")

    try:
        rrows, _ = roofline.run("single")
        best = max(r["roofline_fraction"] for r in rrows)
        lines.append(f"roofline_single,0,best_roofline_fraction={best:.2f}")
    except (FileNotFoundError, IndexError, ValueError):
        lines.append("roofline_single,0,missing=run_dryrun_first")

    print("\n" + "=" * 60)
    print("\n".join(lines))
    print(f"\n[benchmarks] total {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
