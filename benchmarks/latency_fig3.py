"""Fig. 3 reproduction: end-to-end latency distribution per policy/dataset.

Validation targets: MoA-Off mean latency >30% below PerLLM and >50% below
cloud-only / edge-only.
"""
from __future__ import annotations

import os

from benchmarks.common import (DATASETS, POLICIES, RESULTS_DIR, run_grid,
                               write_csv)


def run(n=None):
    rows = run_grid(bandwidths=[300e6], n=n) if n else run_grid(
        bandwidths=[300e6])
    path = write_csv(rows, os.path.join(RESULTS_DIR, "fig3_latency.csv"),
                     ["dataset", "policy", "mean_latency_s", "p50_latency_s",
                      "p95_latency_s", "p99_latency_s"])
    print("\nFig. 3 — end-to-end latency (s) @300 Mbps")
    print(f"{'policy':12s} {'mean':>8s} {'p50':>8s} {'p95':>8s} {'p99':>8s}")
    checks = []
    for ds in DATASETS:
        print(f"-- {ds} --")
        line = {r["policy"]: r for r in rows if r["dataset"] == ds}
        for p in POLICIES:
            r = line[p]
            print(f"{p:12s} {r['mean_latency_s']:8.3f} {r['p50_latency_s']:8.3f} "
                  f"{r['p95_latency_s']:8.3f} {r['p99_latency_s']:8.3f}")
        moa = line["moa-off"]["mean_latency_s"]
        checks.append({
            "dataset": ds,
            "red_vs_cloud_pct": 100 * (1 - moa / line["cloud-only"]["mean_latency_s"]),
            "red_vs_edge_pct": 100 * (1 - moa / line["edge-only"]["mean_latency_s"]),
            "red_vs_perllm_pct": 100 * (1 - moa / line["perllm"]["mean_latency_s"]),
        })
    print("\npaper-claim checks (MoA-Off latency reduction, %):")
    for c in checks:
        print(f"  {c['dataset']:8s} vs cloud {c['red_vs_cloud_pct']:5.1f}% "
              f"| vs edge {c['red_vs_edge_pct']:5.1f}% "
              f"| vs perllm {c['red_vs_perllm_pct']:5.1f}%")
    return rows, checks, path


if __name__ == "__main__":
    run()
