"""Fig. 4 reproduction: computing + memory overhead per policy/dataset.

Validation target: MoA-Off reduces compute overhead by 30-65% vs cloud-only
and PerLLM; memory overhead lowest among collaborative policies.
"""
from __future__ import annotations

import os

from benchmarks.common import (DATASETS, POLICIES, RESULTS_DIR, run_grid,
                               write_csv)


def run(n=None):
    rows = run_grid(bandwidths=[300e6], n=n) if n else run_grid(
        bandwidths=[300e6])
    path = write_csv(rows, os.path.join(RESULTS_DIR, "fig4_overhead.csv"),
                     ["dataset", "policy", "total_flops", "edge_flops",
                      "cloud_flops", "total_mem_byte_s", "edge_mem_byte_s",
                      "cloud_mem_byte_s"])
    print("\nFig. 4 — resource overhead @300 Mbps (normalized to cloud-only)")
    checks = []
    for ds in DATASETS:
        line = {r["policy"]: r for r in rows if r["dataset"] == ds}
        base_f = line["cloud-only"]["total_flops"]
        base_m = line["cloud-only"]["total_mem_byte_s"]
        print(f"-- {ds} --  (flops_norm, mem_norm)")
        for p in POLICIES:
            r = line[p]
            print(f"{p:12s} {r['total_flops'] / base_f:8.3f} "
                  f"{r['total_mem_byte_s'] / base_m:8.3f}")
        moa_f = line["moa-off"]["total_flops"]
        checks.append({
            "dataset": ds,
            "compute_red_vs_cloud_pct": 100 * (1 - moa_f / base_f),
            "compute_red_vs_perllm_pct":
                100 * (1 - moa_f / line["perllm"]["total_flops"]),
            "mem_red_vs_cloud_pct":
                100 * (1 - line["moa-off"]["total_mem_byte_s"] / base_m),
        })
    print("\npaper-claim checks (MoA-Off overhead reduction, %):")
    for c in checks:
        print(f"  {c['dataset']:8s} compute vs cloud "
              f"{c['compute_red_vs_cloud_pct']:5.1f}% | vs perllm "
              f"{c['compute_red_vs_perllm_pct']:5.1f}% | mem vs cloud "
              f"{c['mem_red_vs_cloud_pct']:5.1f}%")
    return rows, checks, path


if __name__ == "__main__":
    run()
