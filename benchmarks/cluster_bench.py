"""End-to-end LIVE cluster benchmark: the unified runtime's LiveBackend
under an open-loop arrival process.

Drives ``ClusterServer`` (real reduced-model ``TierEngine`` per tier, the
real MoA-Off scheduler, modeled WAN links, executed partial offload,
EDF admission, optional hedging/fault injection) at a configurable request
rate and reports, per policy:

* p50 / p95 end-to-end latency and mean TTFT (streamed first token),
* goodput (SLO-met completions per second) vs. raw throughput,
* frac_local (fully-local routing fraction), hedge/retry/truncation rates,
* aggregate engine decode tokens/s.

It also runs a **hedge-path migration comparison** on the twin-edge
topology: the same speculative-hedged long-prompt workload, once with
re-prefilling clones (baseline) and once with cross-tier KV migration
(hedged in-service stragglers ship their slot and the donor is retired) —
reporting p50/p95 and the receiving tiers' prefill-token deltas, which
prove migrated requests never prefill twice.

A **chaos comparison** runs the same burst under a deterministic fault
storm (crashed edge tier, throttled twin, degraded cloud uplink) with the
resilience layer off vs on — tier health + circuit breaking must convert
terminal failures into degraded-but-on-time completions (goodput gain).

A **byzantine soak** replays the hedged-migration burst under a
whole-run wire storm (slot-payload corruption, event-stream
drop/dup/reorder) with the invariant auditor on — checksums + the
exactly-once delivery ledger must keep the run auditor-clean at goodput
parity with honest wires, while the wire counters prove the faults fired.

A **scale-out storm** sweeps open-loop arrival rates (Poisson, plus bursty
and diurnal patterns at the knee) against replicated edge engine pools
(R=1 vs R=2, local transport), per policy — the saturation curves
(goodput-at-SLO and p95 vs rate) that show R=2 pushing the knee out and
MoA-Off beating the static baselines past the single-replica knee.

This is the first end-to-end live-cluster number in the perf trajectory —
the serving bench (``serving_bench.py``) measures one engine's hot path;
this one measures the whole control plane. Emits ``BENCH_cluster.json`` at
the repo root (CI uploads it as an artifact; ``--smoke`` shrinks the grid).

    PYTHONPATH=src python benchmarks/cluster_bench.py            # full
    PYTHONPATH=src python benchmarks/cluster_bench.py --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.config import TOPOLOGIES, ServingConfig, get_topology
from repro.core.baselines import make_policy
from repro.core.scheduler import MoAOffScheduler
from repro.data.synthetic import make_image, make_text_meta
from repro.serving.tiers import (ClusterServer, build_cluster_engines,
                                 build_engine_pools)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_cluster.json")


def make_workload(n: int, rate: float, seed: int, hw: int = 48):
    """(delay_s, text, image) tuples from a Poisson arrival process whose
    content difficulty sweeps the scorer's range (same latent-knob scheme
    as the simulator's RequestGenerator, with real payloads)."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        u = float(rng.beta(1.6, 1.6))
        if i % 4 == 3:
            # easy image + entity-dense long text: routes the text out while
            # the image stays local -> exercises executed partial offload
            # (the routed tier encodes, embeddings ship to the fusion tier)
            u = 0.05
            text = (f"Request {i}: compare Table {i} against Report "
                    f"{i + 1} and Figure {i + 2}. " * 10)
        else:
            meta = make_text_meta(rng, float(rng.beta(1.4, 2.2)))
            words = max(4, min(60, meta["tokens"] // 4))
            text = (f"Request {i}: describe the Scene. "
                    + "and explain why the Detail matters. " * (words // 6))
        out.append((t, text, make_image(rng, u, hw, hw)))
    return out


def run_policy(policy: str, topo, sv: ServingConfig, workload, args) -> dict:
    topo = get_topology(topo) if isinstance(topo, str) else topo
    server = ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(policy, topology=topo)),
        hedge_after_s=args.hedge_after, fail_rate=args.fail_rate)
    # warmup: drive all-local, all-remote and split requests over several
    # prompt lengths with a long decode, so every engine's prefill buckets,
    # context-bucket ladder and encode paths compile before timing
    wrng = np.random.default_rng(1)
    for cx in ({"image": 0.05, "text": 0.05}, {"image": 0.95, "text": 0.95},
               {"image": 0.05, "text": 0.95}):
        for words in (3, 12, 24):
            server.submit("warm up the Compiler please. " * words,
                          image=make_image(wrng, 0.5, 48, 48),
                          max_new=max(args.max_new, 16), complexity=cx)
    server.run(timeout_s=args.timeout)
    n_warm = len(server.results)
    # warmup latencies are compile-dominated; don't let them poison the
    # adaptive-τ controller or the EWMA state for the timed run
    server.scheduler = MoAOffScheduler(
        policy=make_policy(policy, topology=topo))
    server.runtime.scheduler = server.scheduler
    tok0 = {t: (e.decode_tokens, e.encode_tokens)
            for t, e in server.engines.items()}
    off0 = server.backend.offloaded_encodes

    for delay, text, img in workload:
        server.submit(text, image=img, max_new=args.max_new,
                      slo_s=args.slo, delay_s=delay)
    t0 = time.perf_counter()
    results = server.run(timeout_s=args.timeout)[n_warm:]
    wall = time.perf_counter() - t0
    lats = np.array([r.latency_s for r in results])
    local = {t.name for t in topo.local_tiers}
    frac_local = float(np.mean([
        all(t in local for t in r.routes.values()) for r in results]))
    dec = sum(e.decode_tokens - tok0[t][0]
              for t, e in server.engines.items())
    enc = sum(e.encode_tokens - tok0[t][1]
              for t, e in server.engines.items())
    return {
        "n": len(results),
        "wall_s": wall,
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "mean_latency_s": float(lats.mean()),
        "mean_ttft_s": float(np.mean([r.ttft_s for r in results])),
        "goodput_rps": sum(r.on_time for r in results) / wall,
        "throughput_rps": len(results) / wall,
        "frac_local": frac_local,
        "hedged": float(np.mean([r.hedged for r in results])),
        "retries": float(np.mean([r.retries for r in results])),
        "truncated": float(np.mean([r.truncated for r in results])),
        "decode_tok_s": dec / wall,
        "encode_tokens": enc,  # frontend patch tokens encoded (any tier)
        # images genuinely encoded AWAY from their fusion tier — the
        # executed-partial-offload count
        "offloaded_encodes": server.backend.offloaded_encodes - off0,
    }


def run_hedge_migration(args) -> dict:
    """The hedge path with and without migration, on edge-edge-cloud
    (edge/edge1 serve the SAME model -> migration-compatible).

    Both modes run the SAME speculative hedging policy (queued requests AND
    mid-decode stragglers are hedged after ``hedge_after``); the ONLY
    difference is the clone mechanism — baseline clones re-prefill from
    token 0 and race their donor, migrated clones receive the donor's cache
    rows over the wire and retire it (preemption stays off so the delta is
    attributable to the hedge path alone). The receiving tiers'
    prefill-token deltas prove migrated work is never prefilled twice."""
    from repro.config import PolicyConfig

    topo = get_topology("edge-edge-cloud")
    n = 4 if args.smoke else 6
    sv = ServingConfig(max_batch=n, max_seq=256)
    # a tight burst of uniform long-prompt, long-decode requests: everyone
    # is admitted (no queue) and still decoding when the hedge fires, so
    # EVERY hedge is an in-service backup — the path migration changes
    workload = [(0.05 * i, f"Request {i}: summarize the Report. "
                 + "and weigh every Detail carefully. " * 12)
                for i in range(n)]
    out = {}
    for mode in ("baseline", "migrate"):
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
            hedge_after_s=0.5, hedge_in_service=True,
            migrate=(mode == "migrate"))
        # warm every engine out-of-band: the fused-decode context ladder up
        # to max_seq AND every (length-bucket, row-count) prefill trace the
        # burst can hit, so the timed region measures serving, not XLA
        for i, (tier, eng) in enumerate(server.engines.items()):
            rid = 90_000 + 1_000 * i
            for rows in (1, 2, n):
                for r in range(rows):
                    eng.submit(rid, (np.arange(100) % 300 + 4)
                               .astype(np.int32), max_new=4)
                    rid += 1
                eng.run_until_drained()
            eng.submit(rid, (np.arange(128) % 300 + 4).astype(np.int32),
                       max_new=120)  # context ladder through max_seq
            eng.run_until_drained()
        prefill0 = {t_: e.prefill_tokens for t_, e in server.engines.items()}
        for delay, text in workload:
            # pinned local: the whole burst decodes on the edge tier and
            # straggles into the hedge window together
            server.submit(text, max_new=96, slo_s=args.slo, delay_s=delay,
                          complexity={"text": 0.05})
        results = server.run(timeout_s=args.timeout)
        lats = np.array([r.latency_s for r in results])
        out[mode] = {
            "n": len(results),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "mean_latency_s": float(lats.mean()),
            "hedged": float(np.mean([r.hedged for r in results])),
            "migrated": float(np.mean([r.migrated for r in results])),
            "migrations": server.runtime.migrations,
            "migration_mb": float(sum(r.migration_bytes
                                      for r in results) / 1e6),
            # prefill tokens spent per tier during the timed run: with
            # migration the receiving twin decodes shipped slots instead of
            # re-prefilling them
            "prefill_tokens": {t_: e.prefill_tokens - prefill0[t_]
                               for t_, e in server.engines.items()},
        }
        print(f"  [hedge/{mode}] p50={out[mode]['p50_latency_s']:.3f}s "
              f"p95={out[mode]['p95_latency_s']:.3f}s "
              f"hedged={out[mode]['hedged']:.2f} "
              f"migrations={out[mode]['migrations']} "
              f"prefill={out[mode]['prefill_tokens']}", flush=True)
    return out


def run_speculative(args) -> dict:
    """Cross-tier speculative decoding vs plain offloaded decode on the
    two-tier pair, across WAN bandwidths.

    The same cloud-fused long-decode burst runs twice per bandwidth — once
    decoding every token on the cloud engine (plain offload) and once with
    the edge engine drafting k-token blocks the cloud verifies in ONE
    batched decode step (accepted prefixes commit k-at-a-time, the first
    mismatch rolls back). Output tokens are identical by construction
    (greedy verify == target-only decode); the reported delta is committed
    tokens/s and end-to-end latency.

    The stock reduced pair is size-degenerate (both tiers ~0.5 ms/step), so
    this scenario rebuilds the cloud engine at a deeper/wider reduction,
    restoring a steep draft<<target per-step asymmetry (~80x) in place of
    the paper's 2B-vs-7B pair. That is the regime speculation targets: one
    k+1-token verify forward streams the target's weights ONCE where plain
    offload streams them k+1 times."""
    from repro.config import PolicyConfig, SpecConfig, two_tier_topology
    from repro.configs import reduced_config
    from repro.models import build_model
    from repro.serving.engine import TierEngine

    n = 3 if args.smoke else 6
    max_new = 16 if args.smoke else 48
    k = 12
    bands = [20e6] if args.smoke else [20e6, 300e6]
    # single-stream latency scenario (the regime speculation targets):
    # max_batch=1 so both modes decode one request at a time
    sv = ServingConfig(max_batch=1, max_seq=256)

    def engines_for(topo):
        out = {}
        for i, tier in enumerate(topo.tiers):
            cfg = reduced_config(tier.model).replace(dtype="float32")
            if tier.name == "cloud":
                cfg = cfg.replace(num_layers=12, d_model=384, num_heads=8,
                                  num_kv_heads=4, d_ff=1536)
            model = build_model(cfg)
            out[tier.name] = TierEngine(
                model, model.init(jax.random.PRNGKey(i)), sv)
        return out
    workload = [(0.05 * i, f"Request {i}: summarize the Report. "
                 + "and weigh every Detail carefully. " * 10)
                for i in range(n)]
    out = {"draft_k": k, "bands": {}}
    for bw in bands:
        topo = two_tier_topology(bandwidth_bps=bw)
        per = {}
        for mode in ("offload", "speculative"):
            spec = (SpecConfig(draft_tier="edge", target_tier="cloud",
                               draft_k=k) if mode == "speculative" else None)
            server = ClusterServer(
                engines_for(topo), topology=topo,
                scheduler=MoAOffScheduler(policy=make_policy(
                    "moa-off", PolicyConfig(adaptive_tau=False),
                    topology=topo)),
                spec=spec)
            # warmup out-of-band: same shape as the burst, so the draft
            # scan / k+1-verify / re-feed traces all compile before timing
            server.submit("warm up the Compiler please. " * 12,
                          max_new=max_new, complexity={"text": 0.95})
            server.run(timeout_s=args.timeout)
            n_warm = len(server.results)
            for delay, text in workload:
                server.submit(text, max_new=max_new, slo_s=args.slo,
                              delay_s=delay, complexity={"text": 0.95})
            t0 = time.perf_counter()
            results = server.run(timeout_s=args.timeout)[n_warm:]
            wall = time.perf_counter() - t0
            rids = {r.rid for r in results}
            outs = [o for o in server.runtime.outcomes if o.rid in rids]
            drafted = sum(o.drafted_tokens for o in outs)
            accepted = sum(o.accepted_tokens for o in outs)
            lats = np.array([r.latency_s for r in results])
            toks = sum(len(r.tokens) for r in results)
            per[mode] = {
                "n": len(results),
                "wall_s": wall,
                "p50_latency_s": float(np.percentile(lats, 50)),
                "p95_latency_s": float(np.percentile(lats, 95)),
                "mean_ttft_s": float(np.mean([r.ttft_s for r in results])),
                "tok_s": toks / wall,  # committed output tokens/s
                "drafted_tokens": drafted,
                "accepted_tokens": accepted,
                "accept_rate": accepted / drafted if drafted else 0.0,
                "tokens": [r.tokens for r in sorted(results,
                                                    key=lambda r: r.rid)],
            }
            print(f"  [spec/{mode} @ {bw / 1e6:.0f}Mbps] "
                  f"p50={per[mode]['p50_latency_s']:.3f}s "
                  f"p95={per[mode]['p95_latency_s']:.3f}s "
                  f"tok/s={per[mode]['tok_s']:.1f} "
                  f"accept={per[mode]['accept_rate']:.0%}", flush=True)
        # greedy verify commits exactly the target-only stream: same tokens
        parity = per["offload"]["tokens"] == per["speculative"]["tokens"]
        for mode in per:
            del per[mode]["tokens"]
        per["token_parity"] = parity
        per["speedup_tok_s"] = (per["speculative"]["tok_s"]
                                / max(per["offload"]["tok_s"], 1e-9))
        print(f"  [spec @ {bw / 1e6:.0f}Mbps] speculative/offload tok/s = "
              f"{per['speedup_tok_s']:.2f}x | token parity: {parity}",
              flush=True)
        out["bands"][f"{bw / 1e6:.0f}Mbps"] = per
    return out


def run_sessions(args) -> dict:
    """Multi-turn chat through the WHOLE control plane: N sessions x T
    turns (shared system prompt) on the two-tier cluster, with sessions +
    prefix cache ON vs the sessionless replay (every turn re-submits and
    re-prefills its full history). Reports per-turn mean TTFT, the warm
    fraction, and the engines' prefill-token counters — the proof that
    only suffixes were prefilled on warm turns."""
    topo = get_topology("edge-cloud")
    n_sessions = 2 if args.smoke else 4
    turns = 3 if args.smoke else 4
    warmup = 2  # compile-warmup sessions (excluded from the stats)
    system = "you are a Helpful assistant; answer with Care. " * 40
    out = {}
    for mode in ("cold", "warm"):
        sv = ServingConfig(
            max_batch=4, max_seq=1024,
            prefix_cache_mb=64.0 if mode == "warm" else 0.0,
            session_cache_mb=64.0 if mode == "warm" else 0.0)
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", topology=topo)),
            sessions=(mode == "warm"))
        # compile warmup: throwaway sessions with the same turn lengths
        # (session 1 additionally traces the cross-session prefix-hit path)
        for s in range(n_sessions + warmup):
            for turn in range(turns):
                text = (system if turn == 0 else "") + (
                    f"turn {turn}: expand on Topic {s} with Detail. ")
                # submit_turn builds the full-history prompt either way;
                # with the runtime's sessions off this is the sessionless
                # replay — every turn re-prefills the whole conversation
                server.submit_turn(f"chat-{s}", text, max_new=12,
                                   slo_s=args.slo,
                                   complexity={"text": 0.05})
                server.run(timeout_s=args.timeout)
        results = server.results
        timed = results[warmup * turns:]  # warmup sessions excluded
        per_turn = [[] for _ in range(turns)]
        for i, r in enumerate(timed):
            per_turn[i % turns].append(r.ttft_s)
        out[mode] = {
            "n": len(timed),
            "turn_ttft_s": [float(np.mean(t)) for t in per_turn],
            "warm_frac": float(np.mean([bool(r.warm) for r in timed])),
            "warm_tokens": float(sum(r.warm_tokens for r in timed)),
            "prefill_tokens": {t_: e.prefill_tokens
                               for t_, e in server.engines.items()},
        }
        print(f"  [sessions/{mode}] per-turn ttft "
              f"{[f'{v * 1e3:.1f}' for v in out[mode]['turn_ttft_s']]} ms "
              f"warm={out[mode]['warm_frac']:.2f} "
              f"prefill={out[mode]['prefill_tokens']}", flush=True)
    warm_t = np.mean(out["warm"]["turn_ttft_s"][1:])
    cold_t = np.mean(out["cold"]["turn_ttft_s"][1:])
    out["warm_turn_ttft_speedup"] = float(cold_t / max(warm_t, 1e-9))
    out["config"] = {"sessions": n_sessions, "turns": turns,
                     "system_prompt_words": len(system.split())}
    print(f"  [sessions] warm-turn ttft speedup "
          f"{out['warm_turn_ttft_speedup']:.2f}x", flush=True)
    return out


def run_chaos(args) -> dict:
    """Graceful degradation under a deterministic fault storm: the SAME
    burst on edge-edge-cloud with the edge tier crashed for the whole run,
    its twin throttled 2x and the cloud uplink at half bandwidth — once
    with the resilience layer off (bounded retries hammer the dead tier
    until every routed request fails terminally) and once with tier health
    on (the circuit opens after two failures, arrivals and retries re-route
    to the best surviving tier, sheddable work is dropped at the deadline).
    Health-on must win on goodput: the storm is survivable, the baseline
    just doesn't route around it."""
    from repro.config import PolicyConfig, ResilienceConfig
    from repro.serving.faults import FaultEvent, FaultPlan

    topo = get_topology("edge-edge-cloud")
    n = 6 if args.smoke else 12
    sv = ServingConfig(max_batch=4, max_seq=192, heartbeat_timeout_s=0.2)
    plan = FaultPlan([
        FaultEvent("crash", "edge", t=0.0, duration=120.0),
        FaultEvent("slow", "edge1", t=0.0, duration=120.0, magnitude=2.0),
        FaultEvent("degrade", "cloud", t=0.0, duration=120.0, magnitude=0.5),
    ])
    rng = np.random.default_rng(7)
    workload, t = [], 0.0
    for i in range(n):
        t += rng.exponential(1.0 / 4.0)
        workload.append((t, f"Request {i}: describe the Scene. "
                         + "and explain why the Detail matters. " * 4))
    modes = {
        "health_off": None,
        "health_on": ResilienceConfig(
            health=True, quarantine_after=2, probe_after_s=10.0,
            retry_backoff=True, shed=True, transfer_timeout_s=2.0),
    }
    out = {}
    for mode, res_cfg in modes.items():
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
            fault_plan=plan, resilience=res_cfg)
        for i, eng in enumerate(server.engines.values()):  # compile warmup
            eng.submit(90_000 + i, (np.arange(24) % 300 + 4)
                       .astype(np.int32), max_new=24)
            eng.run_until_drained()
        t0 = time.perf_counter()
        for delay, text in workload:
            server.submit(text, max_new=16, slo_s=args.slo, delay_s=delay,
                          complexity={"text": 0.05})
        results = server.run(timeout_s=args.timeout)
        wall = time.perf_counter() - t0
        done = [r for r in results if not r.failed]
        lats = (np.array([r.latency_s for r in done]) if done
                else np.array([float("inf")]))
        health = server.runtime.health
        ok = sum((not r.failed) and r.on_time for r in results)
        out[mode] = {
            "n": len(results),
            "completed": len(done),
            "failed": sum(r.fail_reason == "retries" for r in results),
            "shed": sum(r.fail_reason == "shed" for r in results),
            "degraded": sum(r.degraded for r in results),
            "goodput_frac": ok / max(len(results), 1),
            "goodput_rps": ok / wall,
            # latency percentiles over COMPLETED requests (a terminal
            # failure resolves fast — it must not flatter the percentile)
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "quarantines": health.quarantine_count if health else 0,
            "restores": server.backend.restores,
        }
        print(f"  [chaos/{mode}] goodput={out[mode]['goodput_frac']:.2f} "
              f"failed={out[mode]['failed']} shed={out[mode]['shed']} "
              f"degraded={out[mode]['degraded']} "
              f"p95={out[mode]['p95_latency_s']:.3f}s "
              f"quarantines={out[mode]['quarantines']}", flush=True)
    out["goodput_gain"] = (out["health_on"]["goodput_frac"]
                           - out["health_off"]["goodput_frac"])
    return out


def run_soak(args) -> dict:
    """Byzantine wire soak: the SAME hedged long-decode burst on
    edge-edge-cloud, once on honest wires and once under a whole-run
    byzantine storm (migration/session payload corruption, plus
    drop/dup/reorder on every replica's sequenced event stream), BOTH
    runs with the invariant auditor on.

    The defense stack must make the storm invisible at the service level:
    every corrupted slot payload is caught by a CRC32 (and the clone
    re-prefills — recovered, never garbage KV), every duplicated frame is
    suppressed by the delivery ledger, every drop/reorder heals via
    outbox resync — so the byzantine run ends auditor-clean with goodput
    within 10% of the honest run, and the wire counters prove the faults
    actually fired."""
    from repro.config import PolicyConfig
    from repro.serving.faults import FaultPlan

    topo = get_topology("edge-edge-cloud")
    n = 4 if args.smoke else 6
    sv = ServingConfig(max_batch=n, max_seq=256)
    # the hedge-migration recipe: a tight burst of uniform long decodes
    # pinned local, in-service hedges after 50 ms (decode outlives the
    # window on any host), clones receive the donor's slot over the (now
    # hostile) migration wire
    workload = [(0.05 * i, f"Request {i}: audit the Ledger. "
                 + "and verify every Invariant still holds. " * 12)
                for i in range(n)]
    storm = FaultPlan.byzantine_storm(seed=args.seed + 1, corrupt=0.9,
                                      dup=0.25, drop=0.15, reorder=0.1)
    out = {}
    for mode in ("fault_free", "byzantine"):
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
            hedge_after_s=0.05, hedge_in_service=True, migrate=True,
            fault_plan=storm if mode == "byzantine" else None,
            audit=True)
        # warm every engine out-of-band (same ladder as the hedge bench)
        for i, (tier, eng) in enumerate(server.engines.items()):
            rid = 80_000 + 1_000 * i
            for rows in (1, 2, n):
                for r in range(rows):
                    eng.submit(rid, (np.arange(100) % 300 + 4)
                               .astype(np.int32), max_new=4)
                    rid += 1
                eng.run_until_drained()
            eng.submit(rid, (np.arange(128) % 300 + 4).astype(np.int32),
                       max_new=120)
            eng.run_until_drained()
        t0 = time.perf_counter()
        for delay, text in workload:
            server.submit(text, max_new=96, slo_s=args.slo, delay_s=delay,
                          complexity={"text": 0.05})
        results = server.run(timeout_s=args.timeout)
        wall = time.perf_counter() - t0
        lats = np.array([r.latency_s for r in results])
        ws = dict(server.runtime.wire_stats)
        verdict = server.runtime.auditor.last
        ok = sum((not r.failed) and r.on_time for r in results)
        out[mode] = {
            "n": len(results),
            "wall_s": wall,
            "goodput_rps": ok / wall,
            "goodput_frac": ok / max(len(results), 1),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "hedged": int(sum(r.hedged for r in results)),
            "migrations": server.runtime.migrations,
            "wire": ws,
            "audit_clean": bool(verdict["clean"]),
            "violations": list(verdict["violations"]),
        }
        print(f"  [soak/{mode}] goodput={out[mode]['goodput_frac']:.2f} "
              f"({out[mode]['goodput_rps']:.2f} rps) "
              f"p95={out[mode]['p95_latency_s']:.3f}s "
              f"corrupt={ws.get('corrupt_detected', 0)}"
              f"/{ws.get('corrupt_injected', 0)} "
              f"dups={ws.get('dups_suppressed', 0)} "
              f"resyncs={ws.get('resyncs', 0)} "
              f"audit={'CLEAN' if verdict['clean'] else 'VIOLATIONS'}",
              flush=True)
        if not verdict["clean"]:
            for v in verdict["violations"]:
                print(f"    ! {v}", flush=True)
    byz, ff = out["byzantine"], out["fault_free"]
    out["goodput_ratio"] = (byz["goodput_frac"]
                            / max(ff["goodput_frac"], 1e-9))
    out["storm"] = json.loads(storm.to_json())
    print(f"  [soak] byzantine/fault-free goodput ratio "
          f"{out['goodput_ratio']:.2f} | detected corruptions "
          f"{byz['wire'].get('corrupt_detected', 0)}, suppressed dups "
          f"{byz['wire'].get('dups_suppressed', 0)}", flush=True)
    return out


def make_storm_arrivals(n: int, rate: float, pattern: str,
                        seed: int) -> np.ndarray:
    """Arrival times for one storm cell: ``poisson`` (open-loop exponential
    gaps), ``burst`` (back-to-back clumps at the same mean rate — the
    worst case for a single replica's admission queue), or ``diurnal``
    (thinned inhomogeneous Poisson, rate swinging ±80% sinusoidally)."""
    rng = np.random.default_rng(seed)
    if pattern == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, n))
    if pattern == "burst":
        burst = 4
        times, t = [], 0.0
        while len(times) < n:
            t += rng.exponential(burst / rate)
            times.extend(t + 0.005 * j for j in range(burst))
        return np.asarray(times[:n])
    if pattern == "diurnal":
        period = max(4.0, n / rate / 2.0)
        lam_max = rate * 1.8
        times, t = [], 0.0
        while len(times) < n:
            t += rng.exponential(1.0 / lam_max)
            if rng.random() < (1 + 0.8 * np.sin(2 * np.pi * t / period)) / 1.8:
                times.append(t)
        return np.asarray(times)
    raise ValueError(f"unknown arrival pattern {pattern!r}")


def run_storm(args) -> dict:
    """Scale-out saturation curves: goodput-at-SLO and p95 latency vs
    arrival rate, per policy, per edge replica count, on the two-tier
    cluster with replicated engine pools (local transport).

    Two saturating resources bound the static policies: a small
    per-replica batch caps the edge tier's compute (edge-only knees
    first) and a constrained uplink makes every cloud-routed image queue
    on the WAN station (cloud-only knees on bandwidth — the paper's
    offload-overhead regime). R=2 must push the edge knee out
    (materially higher goodput-at-SLO under the same storm), and MoA-Off
    must beat both static policies past the single-replica knee by
    splitting the storm across compute and bandwidth headroom. Bursty
    and diurnal arrival patterns re-run the knee rate — the regimes
    where tier-local load balancing earns its keep."""
    import dataclasses as dc

    topo = get_topology("edge-cloud")
    # constrain the WAN so a remote-routed image costs ~0.15 s of uplink:
    # the cloud's knee is bandwidth (~7 rps), the edge's knee is compute
    topo = dc.replace(topo, tiers=tuple(
        dc.replace(t, uplink_bps=250e3) if t.is_remote else t
        for t in topo.tiers))
    sv = ServingConfig(max_batch=2, max_seq=128)
    slo_s = 2.5
    img_hw = 96  # 96x96 -> ~4.6 KB payload -> ~0.15 s on the 250 kbps WAN
    # decode-dominated requests + a step throttle emulate weak edge
    # silicon: a single replica serves ~9 rps, so the edge knee sits
    # between the 8 and 16 rps rate points (and R=2 pushes it past 16).
    # Past the edge knee MoA-Off splits the storm: the sub-tau share stays
    # within edge capacity (below the Eq. 5 load gate) while the overflow
    # rides the WAN headroom — beating either static policy alone
    max_new = 48
    edge_throttle = 4.0
    # full curves run process replicas (each tier decodes in its own
    # worker, so a saturated cloud cannot head-of-line block edge decode
    # through the shared advance loop); --smoke keeps the in-process
    # local transport for a cheap deterministic CI liveness lane
    transport = "local" if args.smoke else "process"
    if args.smoke:
        rates, policies = [4.0, 16.0], ["moa-off", "cloud-only"]
        reps, patterns = [1, 2], ["poisson"]
        n_for = lambda rate: 6  # noqa: E731
    else:
        rates = [2.0, 4.0, 8.0, 16.0]
        policies = ["moa-off", "edge-only", "cloud-only"]
        reps, patterns = [1, 2], ["poisson", "burst", "diurnal"]
        n_for = lambda rate: int(min(96, max(16, rate * 8)))  # noqa: E731
    knee_rate = rates[-1]
    curves = []
    for n_rep in reps:
        pools = build_engine_pools(topo, sv,
                                   replicas={"edge": n_rep, "cloud": 1},
                                   transport=transport)
        # compile warmup once per replica set, with STORM-SHAPED prompts:
        # prefill buckets compile per prompt-length bucket, so the warmup
        # must sweep the same words cycle the timed cells use (routed to
        # both tiers via the complexity extremes)
        wsrv = ClusterServer(pools, topology=topo, scheduler=MoAOffScheduler(
            policy=make_policy("moa-off", topology=topo)))
        wrng0 = np.random.default_rng(1)
        for cx in (0.05, 0.95):
            for words in (4, 12, 24):
                for _ in range(n_rep):
                    wsrv.submit("Request 0: describe the Scene. "
                                + "and explain why the Detail matters. "
                                * words,
                                image=make_image(wrng0, 0.5, img_hw, img_hw),
                                max_new=max_new,
                                complexity={"image": cx, "text": cx})
        wsrv.run(timeout_s=args.timeout)
        # throttle AFTER warmup: the sleep multiplies real step durations,
        # so throttling the (seconds-long) compile steps would stall the
        # first timed cell for minutes
        for repl in pools["edge"].transports:
            repl.set_throttle(edge_throttle)
        for pattern in patterns:
            cell_rates = rates if pattern == "poisson" else [knee_rate]
            for rate in cell_rates:
                n = n_for(rate)
                arrivals = make_storm_arrivals(n, rate, pattern, args.seed)
                wrng = np.random.default_rng(args.seed + 1)
                for pol in policies:
                    server = ClusterServer(
                        pools, topology=topo,
                        scheduler=MoAOffScheduler(
                            policy=make_policy(pol, topology=topo)))
                    t0 = time.perf_counter()
                    for i, t_arr in enumerate(arrivals):
                        words = (4, 12, 24)[i % 3]
                        u = float(wrng.beta(1.6, 1.6))
                        server.submit(
                            f"Request {i}: describe the Scene. "
                            + "and explain why the Detail matters. " * words,
                            image=make_image(wrng, u, img_hw, img_hw),
                            max_new=max_new, slo_s=slo_s,
                            delay_s=float(t_arr),
                            complexity={"image": u, "text": u})
                    results = server.run(timeout_s=args.timeout)
                    wall = time.perf_counter() - t0
                    lats = np.array([r.latency_s for r in results])
                    on_time = sum(r.on_time and not r.failed
                                  for r in results)
                    cell = {
                        "policy": pol, "replicas": n_rep, "rate": rate,
                        "pattern": pattern, "n": len(results),
                        "wall_s": wall,
                        "goodput_rps": on_time / wall,
                        "goodput_frac": on_time / max(len(results), 1),
                        "p50_latency_s": float(np.percentile(lats, 50)),
                        "p95_latency_s": float(np.percentile(lats, 95)),
                        "mean_ttft_s": float(np.mean(
                            [r.ttft_s for r in results])),
                        "frac_edge": float(np.mean(
                            [r.tier == "edge" for r in results])),
                    }
                    curves.append(cell)
                    print(f"  [storm {pattern} R={n_rep} rate={rate:g} "
                          f"{pol}] goodput={cell['goodput_rps']:.2f} rps "
                          f"({cell['goodput_frac']:.2f}) "
                          f"p95={cell['p95_latency_s']:.3f}s "
                          f"edge={cell['frac_edge']:.2f}", flush=True)
        for pool in pools.values():
            pool.close()

    def cell(pol, n_rep, rate, pattern="poisson"):
        for c in curves:
            if (c["policy"], c["replicas"], c["rate"],
                    c["pattern"]) == (pol, n_rep, rate, pattern):
                return c
        return None

    # summary: the acceptance deltas the curves must show
    k1, k2 = cell("moa-off", 1, knee_rate), cell("moa-off", 2, knee_rate)
    e1, e2 = cell("edge-only", 1, knee_rate), cell("edge-only", 2, knee_rate)
    base = [cell(p, 1, knee_rate)
            for p in policies if p != "moa-off"]
    summary = {
        "slo_s": slo_s, "knee_rate": knee_rate,
        "r1_goodput_at_knee": k1["goodput_rps"] if k1 else None,
        "r2_goodput_at_knee": k2["goodput_rps"] if k2 else None,
        "r2_over_r1": (k2["goodput_rps"] / max(k1["goodput_rps"], 1e-9)
                       if k1 and k2 else None),
        "r2_over_r1_edge_only": (
            e2["goodput_rps"] / max(e1["goodput_rps"], 1e-9)
            if e1 and e2 else None),
        "moa_off_vs_best_static_at_knee": (
            k1["goodput_rps"] / max(max(c["goodput_rps"] for c in base
                                        if c), 1e-9)
            if k1 and any(base) else None),
    }
    print(f"  [storm] R=2/R=1 goodput at {knee_rate:g} rps: "
          f"{summary['r2_over_r1']:.2f}x | moa-off vs best static: "
          f"{summary['moa_off_vs_best_static_at_knee']:.2f}x", flush=True)
    return {"curves": curves, "summary": summary,
            "config": {"rates": rates, "policies": policies,
                       "replicas": reps, "patterns": patterns,
                       "max_batch": sv.max_batch, "slo_s": slo_s,
                       "transport": transport}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slo", type=float, default=5.0)
    ap.add_argument("--hedge-after", type=float, default=0.0)
    ap.add_argument("--fail-rate", type=float, default=0.0)
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default="edge-cloud",
                    choices=sorted(TOPOLOGIES))
    ap.add_argument("--policies", nargs="*",
                    default=["moa-off", "edge-only", "cloud-only"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny workload, two policies")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.smoke:
        args.requests = 6
        args.arrival_rate = 8.0
        args.max_new = 4
        args.policies = ["moa-off", "cloud-only"]

    sv = ServingConfig(max_batch=args.max_batch, max_seq=args.max_seq)
    workload = make_workload(args.requests, args.arrival_rate, args.seed)
    results = {}
    for pol in args.policies:
        print(f"[{pol}] serving {args.requests} requests at "
              f"{args.arrival_rate:.1f} req/s on {args.topology}…",
              flush=True)
        m = run_policy(pol, args.topology, sv, workload, args)
        results[pol] = m
        print(f"  p50={m['p50_latency_s']:.3f}s p95={m['p95_latency_s']:.3f}s"
              f" ttft={m['mean_ttft_s']:.3f}s goodput={m['goodput_rps']:.2f}"
              f" rps frac_local={m['frac_local']:.2f}"
              f" decode={m['decode_tok_s']:.1f} tok/s", flush=True)

    print("[speculative] edge-drafted cloud-verified decoding vs plain "
          "offload across WAN bandwidths on edge-cloud…", flush=True)
    results["speculative"] = run_speculative(args)

    print("[hedge migration] re-prefill clones vs cross-tier KV migration "
          "on edge-edge-cloud…", flush=True)
    results["hedge_migration"] = run_hedge_migration(args)

    print("[sessions] multi-turn chat with prefix & session KV reuse vs "
          "sessionless replay on edge-cloud…", flush=True)
    results["multiturn_sessions"] = run_sessions(args)

    print("[chaos] deterministic fault storm, resilience layer off vs on, "
          "on edge-edge-cloud…", flush=True)
    results["chaos"] = run_chaos(args)

    print("[soak] byzantine wire storm (corrupt/drop/dup/reorder) with "
          "exactly-once delivery and the invariant auditor on "
          "edge-edge-cloud…", flush=True)
    results["soak"] = run_soak(args)

    print("[storm] scale-out saturation curves (replicated edge pool, "
          "poisson/burst/diurnal arrivals) on edge-cloud…", flush=True)
    results["storm"] = run_storm(args)

    payload = {
        "bench": "cluster_live",
        "meta": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.devices()[0]),
            "topology": args.topology,
            "requests": args.requests,
            "arrival_rate": args.arrival_rate,
            "max_new": args.max_new,
            "max_batch": args.max_batch,
            "slo_s": args.slo,
            "hedge_after_s": args.hedge_after,
            "fail_rate": args.fail_rate,
            "smoke": args.smoke,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
