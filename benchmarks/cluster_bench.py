"""End-to-end LIVE cluster benchmark: the unified runtime's LiveBackend
under an open-loop arrival process.

Drives ``ClusterServer`` (real reduced-model ``TierEngine`` per tier, the
real MoA-Off scheduler, modeled WAN links, executed partial offload,
EDF admission, optional hedging/fault injection) at a configurable request
rate and reports, per policy:

* p50 / p95 end-to-end latency and mean TTFT (streamed first token),
* goodput (SLO-met completions per second) vs. raw throughput,
* frac_local (fully-local routing fraction), hedge/retry/truncation rates,
* aggregate engine decode tokens/s.

This is the first end-to-end live-cluster number in the perf trajectory —
the serving bench (``serving_bench.py``) measures one engine's hot path;
this one measures the whole control plane. Emits ``BENCH_cluster.json`` at
the repo root (CI uploads it as an artifact; ``--smoke`` shrinks the grid).

    PYTHONPATH=src python benchmarks/cluster_bench.py            # full
    PYTHONPATH=src python benchmarks/cluster_bench.py --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.config import TOPOLOGIES, ServingConfig, get_topology
from repro.core.baselines import make_policy
from repro.core.scheduler import MoAOffScheduler
from repro.data.synthetic import make_image, make_text_meta
from repro.serving.tiers import ClusterServer, build_cluster_engines

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_cluster.json")


def make_workload(n: int, rate: float, seed: int, hw: int = 48):
    """(delay_s, text, image) tuples from a Poisson arrival process whose
    content difficulty sweeps the scorer's range (same latent-knob scheme
    as the simulator's RequestGenerator, with real payloads)."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        u = float(rng.beta(1.6, 1.6))
        if i % 4 == 3:
            # easy image + entity-dense long text: routes the text out while
            # the image stays local -> exercises executed partial offload
            # (the routed tier encodes, embeddings ship to the fusion tier)
            u = 0.05
            text = (f"Request {i}: compare Table {i} against Report "
                    f"{i + 1} and Figure {i + 2}. " * 10)
        else:
            meta = make_text_meta(rng, float(rng.beta(1.4, 2.2)))
            words = max(4, min(60, meta["tokens"] // 4))
            text = (f"Request {i}: describe the Scene. "
                    + "and explain why the Detail matters. " * (words // 6))
        out.append((t, text, make_image(rng, u, hw, hw)))
    return out


def run_policy(policy: str, topo, sv: ServingConfig, workload, args) -> dict:
    topo = get_topology(topo) if isinstance(topo, str) else topo
    server = ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(policy, topology=topo)),
        hedge_after_s=args.hedge_after, fail_rate=args.fail_rate)
    # warmup: drive all-local, all-remote and split requests over several
    # prompt lengths with a long decode, so every engine's prefill buckets,
    # context-bucket ladder and encode paths compile before timing
    wrng = np.random.default_rng(1)
    for cx in ({"image": 0.05, "text": 0.05}, {"image": 0.95, "text": 0.95},
               {"image": 0.05, "text": 0.95}):
        for words in (3, 12, 24):
            server.submit("warm up the Compiler please. " * words,
                          image=make_image(wrng, 0.5, 48, 48),
                          max_new=max(args.max_new, 16), complexity=cx)
    server.run(timeout_s=args.timeout)
    n_warm = len(server.results)
    # warmup latencies are compile-dominated; don't let them poison the
    # adaptive-τ controller or the EWMA state for the timed run
    server.scheduler = MoAOffScheduler(
        policy=make_policy(policy, topology=topo))
    server.runtime.scheduler = server.scheduler
    tok0 = {t: (e.decode_tokens, e.encode_tokens)
            for t, e in server.engines.items()}
    off0 = server.backend.offloaded_encodes

    for delay, text, img in workload:
        server.submit(text, image=img, max_new=args.max_new,
                      slo_s=args.slo, delay_s=delay)
    t0 = time.perf_counter()
    results = server.run(timeout_s=args.timeout)[n_warm:]
    wall = time.perf_counter() - t0
    lats = np.array([r.latency_s for r in results])
    local = {t.name for t in topo.local_tiers}
    frac_local = float(np.mean([
        all(t in local for t in r.routes.values()) for r in results]))
    dec = sum(e.decode_tokens - tok0[t][0]
              for t, e in server.engines.items())
    enc = sum(e.encode_tokens - tok0[t][1]
              for t, e in server.engines.items())
    return {
        "n": len(results),
        "wall_s": wall,
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "mean_latency_s": float(lats.mean()),
        "mean_ttft_s": float(np.mean([r.ttft_s for r in results])),
        "goodput_rps": sum(r.on_time for r in results) / wall,
        "throughput_rps": len(results) / wall,
        "frac_local": frac_local,
        "hedged": float(np.mean([r.hedged for r in results])),
        "retries": float(np.mean([r.retries for r in results])),
        "truncated": float(np.mean([r.truncated for r in results])),
        "decode_tok_s": dec / wall,
        "encode_tokens": enc,  # frontend patch tokens encoded (any tier)
        # images genuinely encoded AWAY from their fusion tier — the
        # executed-partial-offload count
        "offloaded_encodes": server.backend.offloaded_encodes - off0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slo", type=float, default=5.0)
    ap.add_argument("--hedge-after", type=float, default=0.0)
    ap.add_argument("--fail-rate", type=float, default=0.0)
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default="edge-cloud",
                    choices=sorted(TOPOLOGIES))
    ap.add_argument("--policies", nargs="*",
                    default=["moa-off", "edge-only", "cloud-only"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny workload, two policies")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.smoke:
        args.requests = 6
        args.arrival_rate = 8.0
        args.max_new = 4
        args.policies = ["moa-off", "cloud-only"]

    sv = ServingConfig(max_batch=args.max_batch, max_seq=args.max_seq)
    workload = make_workload(args.requests, args.arrival_rate, args.seed)
    results = {}
    for pol in args.policies:
        print(f"[{pol}] serving {args.requests} requests at "
              f"{args.arrival_rate:.1f} req/s on {args.topology}…",
              flush=True)
        m = run_policy(pol, args.topology, sv, workload, args)
        results[pol] = m
        print(f"  p50={m['p50_latency_s']:.3f}s p95={m['p95_latency_s']:.3f}s"
              f" ttft={m['mean_ttft_s']:.3f}s goodput={m['goodput_rps']:.2f}"
              f" rps frac_local={m['frac_local']:.2f}"
              f" decode={m['decode_tok_s']:.1f} tok/s", flush=True)

    payload = {
        "bench": "cluster_live",
        "meta": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.devices()[0]),
            "topology": args.topology,
            "requests": args.requests,
            "arrival_rate": args.arrival_rate,
            "max_new": args.max_new,
            "max_batch": args.max_batch,
            "slo_s": args.slo,
            "hedge_after_s": args.hedge_after,
            "fail_rate": args.fail_rate,
            "smoke": args.smoke,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
