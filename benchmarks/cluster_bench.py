"""End-to-end LIVE cluster benchmark: the unified runtime's LiveBackend
under an open-loop arrival process.

Drives ``ClusterServer`` (real reduced-model ``TierEngine`` per tier, the
real MoA-Off scheduler, modeled WAN links, executed partial offload,
EDF admission, optional hedging/fault injection) at a configurable request
rate and reports, per policy:

* p50 / p95 end-to-end latency and mean TTFT (streamed first token),
* goodput (SLO-met completions per second) vs. raw throughput,
* frac_local (fully-local routing fraction), hedge/retry/truncation rates,
* aggregate engine decode tokens/s.

It also runs a **hedge-path migration comparison** on the twin-edge
topology: the same speculative-hedged long-prompt workload, once with
re-prefilling clones (baseline) and once with cross-tier KV migration
(hedged in-service stragglers ship their slot and the donor is retired) —
reporting p50/p95 and the receiving tiers' prefill-token deltas, which
prove migrated requests never prefill twice.

A **chaos comparison** runs the same burst under a deterministic fault
storm (crashed edge tier, throttled twin, degraded cloud uplink) with the
resilience layer off vs on — tier health + circuit breaking must convert
terminal failures into degraded-but-on-time completions (goodput gain).

This is the first end-to-end live-cluster number in the perf trajectory —
the serving bench (``serving_bench.py``) measures one engine's hot path;
this one measures the whole control plane. Emits ``BENCH_cluster.json`` at
the repo root (CI uploads it as an artifact; ``--smoke`` shrinks the grid).

    PYTHONPATH=src python benchmarks/cluster_bench.py            # full
    PYTHONPATH=src python benchmarks/cluster_bench.py --smoke    # CI
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.config import TOPOLOGIES, ServingConfig, get_topology
from repro.core.baselines import make_policy
from repro.core.scheduler import MoAOffScheduler
from repro.data.synthetic import make_image, make_text_meta
from repro.serving.tiers import ClusterServer, build_cluster_engines

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_cluster.json")


def make_workload(n: int, rate: float, seed: int, hw: int = 48):
    """(delay_s, text, image) tuples from a Poisson arrival process whose
    content difficulty sweeps the scorer's range (same latent-knob scheme
    as the simulator's RequestGenerator, with real payloads)."""
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        t += rng.exponential(1.0 / rate)
        u = float(rng.beta(1.6, 1.6))
        if i % 4 == 3:
            # easy image + entity-dense long text: routes the text out while
            # the image stays local -> exercises executed partial offload
            # (the routed tier encodes, embeddings ship to the fusion tier)
            u = 0.05
            text = (f"Request {i}: compare Table {i} against Report "
                    f"{i + 1} and Figure {i + 2}. " * 10)
        else:
            meta = make_text_meta(rng, float(rng.beta(1.4, 2.2)))
            words = max(4, min(60, meta["tokens"] // 4))
            text = (f"Request {i}: describe the Scene. "
                    + "and explain why the Detail matters. " * (words // 6))
        out.append((t, text, make_image(rng, u, hw, hw)))
    return out


def run_policy(policy: str, topo, sv: ServingConfig, workload, args) -> dict:
    topo = get_topology(topo) if isinstance(topo, str) else topo
    server = ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(policy, topology=topo)),
        hedge_after_s=args.hedge_after, fail_rate=args.fail_rate)
    # warmup: drive all-local, all-remote and split requests over several
    # prompt lengths with a long decode, so every engine's prefill buckets,
    # context-bucket ladder and encode paths compile before timing
    wrng = np.random.default_rng(1)
    for cx in ({"image": 0.05, "text": 0.05}, {"image": 0.95, "text": 0.95},
               {"image": 0.05, "text": 0.95}):
        for words in (3, 12, 24):
            server.submit("warm up the Compiler please. " * words,
                          image=make_image(wrng, 0.5, 48, 48),
                          max_new=max(args.max_new, 16), complexity=cx)
    server.run(timeout_s=args.timeout)
    n_warm = len(server.results)
    # warmup latencies are compile-dominated; don't let them poison the
    # adaptive-τ controller or the EWMA state for the timed run
    server.scheduler = MoAOffScheduler(
        policy=make_policy(policy, topology=topo))
    server.runtime.scheduler = server.scheduler
    tok0 = {t: (e.decode_tokens, e.encode_tokens)
            for t, e in server.engines.items()}
    off0 = server.backend.offloaded_encodes

    for delay, text, img in workload:
        server.submit(text, image=img, max_new=args.max_new,
                      slo_s=args.slo, delay_s=delay)
    t0 = time.perf_counter()
    results = server.run(timeout_s=args.timeout)[n_warm:]
    wall = time.perf_counter() - t0
    lats = np.array([r.latency_s for r in results])
    local = {t.name for t in topo.local_tiers}
    frac_local = float(np.mean([
        all(t in local for t in r.routes.values()) for r in results]))
    dec = sum(e.decode_tokens - tok0[t][0]
              for t, e in server.engines.items())
    enc = sum(e.encode_tokens - tok0[t][1]
              for t, e in server.engines.items())
    return {
        "n": len(results),
        "wall_s": wall,
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
        "mean_latency_s": float(lats.mean()),
        "mean_ttft_s": float(np.mean([r.ttft_s for r in results])),
        "goodput_rps": sum(r.on_time for r in results) / wall,
        "throughput_rps": len(results) / wall,
        "frac_local": frac_local,
        "hedged": float(np.mean([r.hedged for r in results])),
        "retries": float(np.mean([r.retries for r in results])),
        "truncated": float(np.mean([r.truncated for r in results])),
        "decode_tok_s": dec / wall,
        "encode_tokens": enc,  # frontend patch tokens encoded (any tier)
        # images genuinely encoded AWAY from their fusion tier — the
        # executed-partial-offload count
        "offloaded_encodes": server.backend.offloaded_encodes - off0,
    }


def run_hedge_migration(args) -> dict:
    """The hedge path with and without migration, on edge-edge-cloud
    (edge/edge1 serve the SAME model -> migration-compatible).

    Both modes run the SAME speculative hedging policy (queued requests AND
    mid-decode stragglers are hedged after ``hedge_after``); the ONLY
    difference is the clone mechanism — baseline clones re-prefill from
    token 0 and race their donor, migrated clones receive the donor's cache
    rows over the wire and retire it (preemption stays off so the delta is
    attributable to the hedge path alone). The receiving tiers'
    prefill-token deltas prove migrated work is never prefilled twice."""
    from repro.config import PolicyConfig

    topo = get_topology("edge-edge-cloud")
    n = 4 if args.smoke else 6
    sv = ServingConfig(max_batch=n, max_seq=256)
    # a tight burst of uniform long-prompt, long-decode requests: everyone
    # is admitted (no queue) and still decoding when the hedge fires, so
    # EVERY hedge is an in-service backup — the path migration changes
    workload = [(0.05 * i, f"Request {i}: summarize the Report. "
                 + "and weigh every Detail carefully. " * 12)
                for i in range(n)]
    out = {}
    for mode in ("baseline", "migrate"):
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
            hedge_after_s=0.5, hedge_in_service=True,
            migrate=(mode == "migrate"))
        # warm every engine out-of-band: the fused-decode context ladder up
        # to max_seq AND every (length-bucket, row-count) prefill trace the
        # burst can hit, so the timed region measures serving, not XLA
        for i, (tier, eng) in enumerate(server.engines.items()):
            rid = 90_000 + 1_000 * i
            for rows in (1, 2, n):
                for r in range(rows):
                    eng.submit(rid, (np.arange(100) % 300 + 4)
                               .astype(np.int32), max_new=4)
                    rid += 1
                eng.run_until_drained()
            eng.submit(rid, (np.arange(128) % 300 + 4).astype(np.int32),
                       max_new=120)  # context ladder through max_seq
            eng.run_until_drained()
        prefill0 = {t_: e.prefill_tokens for t_, e in server.engines.items()}
        for delay, text in workload:
            # pinned local: the whole burst decodes on the edge tier and
            # straggles into the hedge window together
            server.submit(text, max_new=96, slo_s=args.slo, delay_s=delay,
                          complexity={"text": 0.05})
        results = server.run(timeout_s=args.timeout)
        lats = np.array([r.latency_s for r in results])
        out[mode] = {
            "n": len(results),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "mean_latency_s": float(lats.mean()),
            "hedged": float(np.mean([r.hedged for r in results])),
            "migrated": float(np.mean([r.migrated for r in results])),
            "migrations": server.runtime.migrations,
            "migration_mb": float(sum(r.migration_bytes
                                      for r in results) / 1e6),
            # prefill tokens spent per tier during the timed run: with
            # migration the receiving twin decodes shipped slots instead of
            # re-prefilling them
            "prefill_tokens": {t_: e.prefill_tokens - prefill0[t_]
                               for t_, e in server.engines.items()},
        }
        print(f"  [hedge/{mode}] p50={out[mode]['p50_latency_s']:.3f}s "
              f"p95={out[mode]['p95_latency_s']:.3f}s "
              f"hedged={out[mode]['hedged']:.2f} "
              f"migrations={out[mode]['migrations']} "
              f"prefill={out[mode]['prefill_tokens']}", flush=True)
    return out


def run_sessions(args) -> dict:
    """Multi-turn chat through the WHOLE control plane: N sessions x T
    turns (shared system prompt) on the two-tier cluster, with sessions +
    prefix cache ON vs the sessionless replay (every turn re-submits and
    re-prefills its full history). Reports per-turn mean TTFT, the warm
    fraction, and the engines' prefill-token counters — the proof that
    only suffixes were prefilled on warm turns."""
    topo = get_topology("edge-cloud")
    n_sessions = 2 if args.smoke else 4
    turns = 3 if args.smoke else 4
    warmup = 2  # compile-warmup sessions (excluded from the stats)
    system = "you are a Helpful assistant; answer with Care. " * 40
    out = {}
    for mode in ("cold", "warm"):
        sv = ServingConfig(
            max_batch=4, max_seq=1024,
            prefix_cache_mb=64.0 if mode == "warm" else 0.0,
            session_cache_mb=64.0 if mode == "warm" else 0.0)
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", topology=topo)),
            sessions=(mode == "warm"))
        # compile warmup: throwaway sessions with the same turn lengths
        # (session 1 additionally traces the cross-session prefix-hit path)
        for s in range(n_sessions + warmup):
            for turn in range(turns):
                text = (system if turn == 0 else "") + (
                    f"turn {turn}: expand on Topic {s} with Detail. ")
                # submit_turn builds the full-history prompt either way;
                # with the runtime's sessions off this is the sessionless
                # replay — every turn re-prefills the whole conversation
                server.submit_turn(f"chat-{s}", text, max_new=12,
                                   slo_s=args.slo,
                                   complexity={"text": 0.05})
                server.run(timeout_s=args.timeout)
        results = server.results
        timed = results[warmup * turns:]  # warmup sessions excluded
        per_turn = [[] for _ in range(turns)]
        for i, r in enumerate(timed):
            per_turn[i % turns].append(r.ttft_s)
        out[mode] = {
            "n": len(timed),
            "turn_ttft_s": [float(np.mean(t)) for t in per_turn],
            "warm_frac": float(np.mean([bool(r.warm) for r in timed])),
            "warm_tokens": float(sum(r.warm_tokens for r in timed)),
            "prefill_tokens": {t_: e.prefill_tokens
                               for t_, e in server.engines.items()},
        }
        print(f"  [sessions/{mode}] per-turn ttft "
              f"{[f'{v * 1e3:.1f}' for v in out[mode]['turn_ttft_s']]} ms "
              f"warm={out[mode]['warm_frac']:.2f} "
              f"prefill={out[mode]['prefill_tokens']}", flush=True)
    warm_t = np.mean(out["warm"]["turn_ttft_s"][1:])
    cold_t = np.mean(out["cold"]["turn_ttft_s"][1:])
    out["warm_turn_ttft_speedup"] = float(cold_t / max(warm_t, 1e-9))
    out["config"] = {"sessions": n_sessions, "turns": turns,
                     "system_prompt_words": len(system.split())}
    print(f"  [sessions] warm-turn ttft speedup "
          f"{out['warm_turn_ttft_speedup']:.2f}x", flush=True)
    return out


def run_chaos(args) -> dict:
    """Graceful degradation under a deterministic fault storm: the SAME
    burst on edge-edge-cloud with the edge tier crashed for the whole run,
    its twin throttled 2x and the cloud uplink at half bandwidth — once
    with the resilience layer off (bounded retries hammer the dead tier
    until every routed request fails terminally) and once with tier health
    on (the circuit opens after two failures, arrivals and retries re-route
    to the best surviving tier, sheddable work is dropped at the deadline).
    Health-on must win on goodput: the storm is survivable, the baseline
    just doesn't route around it."""
    from repro.config import PolicyConfig, ResilienceConfig
    from repro.serving.faults import FaultEvent, FaultPlan

    topo = get_topology("edge-edge-cloud")
    n = 6 if args.smoke else 12
    sv = ServingConfig(max_batch=4, max_seq=192, heartbeat_timeout_s=0.2)
    plan = FaultPlan([
        FaultEvent("crash", "edge", t=0.0, duration=120.0),
        FaultEvent("slow", "edge1", t=0.0, duration=120.0, magnitude=2.0),
        FaultEvent("degrade", "cloud", t=0.0, duration=120.0, magnitude=0.5),
    ])
    rng = np.random.default_rng(7)
    workload, t = [], 0.0
    for i in range(n):
        t += rng.exponential(1.0 / 4.0)
        workload.append((t, f"Request {i}: describe the Scene. "
                         + "and explain why the Detail matters. " * 4))
    modes = {
        "health_off": None,
        "health_on": ResilienceConfig(
            health=True, quarantine_after=2, probe_after_s=10.0,
            retry_backoff=True, shed=True, transfer_timeout_s=2.0),
    }
    out = {}
    for mode, res_cfg in modes.items():
        server = ClusterServer(
            build_cluster_engines(topo, sv), topology=topo,
            scheduler=MoAOffScheduler(policy=make_policy(
                "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
            fault_plan=plan, resilience=res_cfg)
        for i, eng in enumerate(server.engines.values()):  # compile warmup
            eng.submit(90_000 + i, (np.arange(24) % 300 + 4)
                       .astype(np.int32), max_new=24)
            eng.run_until_drained()
        t0 = time.perf_counter()
        for delay, text in workload:
            server.submit(text, max_new=16, slo_s=args.slo, delay_s=delay,
                          complexity={"text": 0.05})
        results = server.run(timeout_s=args.timeout)
        wall = time.perf_counter() - t0
        done = [r for r in results if not r.failed]
        lats = (np.array([r.latency_s for r in done]) if done
                else np.array([float("inf")]))
        health = server.runtime.health
        ok = sum((not r.failed) and r.on_time for r in results)
        out[mode] = {
            "n": len(results),
            "completed": len(done),
            "failed": sum(r.fail_reason == "retries" for r in results),
            "shed": sum(r.fail_reason == "shed" for r in results),
            "degraded": sum(r.degraded for r in results),
            "goodput_frac": ok / max(len(results), 1),
            "goodput_rps": ok / wall,
            # latency percentiles over COMPLETED requests (a terminal
            # failure resolves fast — it must not flatter the percentile)
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "quarantines": health.quarantine_count if health else 0,
            "restores": server.backend.restores,
        }
        print(f"  [chaos/{mode}] goodput={out[mode]['goodput_frac']:.2f} "
              f"failed={out[mode]['failed']} shed={out[mode]['shed']} "
              f"degraded={out[mode]['degraded']} "
              f"p95={out[mode]['p95_latency_s']:.3f}s "
              f"quarantines={out[mode]['quarantines']}", flush=True)
    out["goodput_gain"] = (out["health_on"]["goodput_frac"]
                           - out["health_off"]["goodput_frac"])
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--slo", type=float, default=5.0)
    ap.add_argument("--hedge-after", type=float, default=0.0)
    ap.add_argument("--fail-rate", type=float, default=0.0)
    ap.add_argument("--timeout", type=float, default=600.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", default="edge-cloud",
                    choices=sorted(TOPOLOGIES))
    ap.add_argument("--policies", nargs="*",
                    default=["moa-off", "edge-only", "cloud-only"])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny workload, two policies")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.smoke:
        args.requests = 6
        args.arrival_rate = 8.0
        args.max_new = 4
        args.policies = ["moa-off", "cloud-only"]

    sv = ServingConfig(max_batch=args.max_batch, max_seq=args.max_seq)
    workload = make_workload(args.requests, args.arrival_rate, args.seed)
    results = {}
    for pol in args.policies:
        print(f"[{pol}] serving {args.requests} requests at "
              f"{args.arrival_rate:.1f} req/s on {args.topology}…",
              flush=True)
        m = run_policy(pol, args.topology, sv, workload, args)
        results[pol] = m
        print(f"  p50={m['p50_latency_s']:.3f}s p95={m['p95_latency_s']:.3f}s"
              f" ttft={m['mean_ttft_s']:.3f}s goodput={m['goodput_rps']:.2f}"
              f" rps frac_local={m['frac_local']:.2f}"
              f" decode={m['decode_tok_s']:.1f} tok/s", flush=True)

    print("[hedge migration] re-prefill clones vs cross-tier KV migration "
          "on edge-edge-cloud…", flush=True)
    results["hedge_migration"] = run_hedge_migration(args)

    print("[sessions] multi-turn chat with prefix & session KV reuse vs "
          "sessionless replay on edge-cloud…", flush=True)
    results["multiturn_sessions"] = run_sessions(args)

    print("[chaos] deterministic fault storm, resilience layer off vs on, "
          "on edge-edge-cloud…", flush=True)
    results["chaos"] = run_chaos(args)

    payload = {
        "bench": "cluster_live",
        "meta": {
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "device": str(jax.devices()[0]),
            "topology": args.topology,
            "requests": args.requests,
            "arrival_rate": args.arrival_rate,
            "max_new": args.max_new,
            "max_batch": args.max_batch,
            "slo_s": args.slo,
            "hedge_after_s": args.hedge_after,
            "fail_rate": args.fail_rate,
            "smoke": args.smoke,
        },
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
