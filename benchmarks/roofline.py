"""Deliverable (g): roofline table from the dry-run artifacts.

Reads results/dryrun_*.json (written by repro.launch.dryrun), emits
results/roofline.csv and a markdown table for EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import RESULTS_DIR, write_csv
from repro.config import ROOFLINE


def load_cells(results_dir: str = RESULTS_DIR, mesh: str = "single",
               tag: str = "") -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "dryrun_*.json"))):
        if path.endswith("summary.json"):
            continue
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh or rec.get("tag", "") != (tag or ""):
            continue
        if not rec.get("ok"):
            continue
        cells.append(rec)
    return cells


def table(cells: List[dict]) -> List[dict]:
    rows = []
    for rec in cells:
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": rec["model_flops"],
            "hlo_flops_total": rec["hlo_flops_total"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "roofline_bound_s": bound,
            # fraction of ideal: time if compute ran at peak / actual bound
            "roofline_fraction": (rec["model_flops"]
                                  / (rec["devices"] * ROOFLINE.peak_flops)
                                  ) / bound if bound else 0.0,
            "fits_hbm": rec.get("fits_hbm"),
            "args_temp_gb": (rec["memory"].get("argument_size_in_bytes", 0)
                             + rec["memory"].get("temp_size_in_bytes", 0)) / 1e9,
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def run(mesh: str = "single"):
    cells = load_cells(mesh=mesh)
    rows = table(cells)
    path = write_csv(rows, os.path.join(RESULTS_DIR, f"roofline_{mesh}.csv"),
                     list(rows[0].keys()) if rows else ["arch"])
    print(f"\nRoofline table ({mesh}-pod, {len(rows)} cells) — seconds/step:")
    print(f"{'arch':22s}{'shape':12s}{'compute':>10s}{'memory':>10s}"
          f"{'collect':>10s}  {'dom':10s}{'useful':>7s}{'frac':>6s}{'fits':>5s}")
    for r in rows:
        print(f"{r['arch']:22s}{r['shape']:12s}{r['compute_s']:10.4f}"
              f"{r['memory_s']:10.4f}{r['collective_s']:10.4f}  "
              f"{r['dominant']:10s}{r['useful_flops_ratio']:7.2f}"
              f"{r['roofline_fraction']:6.2f}{str(r['fits_hbm'])[:1]:>5s}")
    return rows, path


def pallas_attention_projection(rec: dict, q_block: int = 512,
                                boundary_factor: float = 3.0) -> dict:
    """Project the memory term with the Pallas flash kernel in place of XLA
    attention: the S^2 score matrices never leave VMEM, so their HBM traffic
    (score bytes x fusion-boundary crossings) is replaced by the kernel's IO
    (Q/K/V/O once + KV re-streamed once per Q block).

    Correctness of the kernel is validated against the jnp oracle in
    tests/test_kernels.py (interpret mode); this projection is the analytic
    IO bound used to size the win before hardware measurement.
    """
    from repro.config import SHAPES
    from repro.configs import get_config

    cfg = get_config(rec["arch"])
    if cfg.family in ("ssm",):
        return {}
    shape = SHAPES[rec["shape"]]
    n_dev = rec["devices"]
    tp = 16
    dp = n_dev // tp
    s = shape.seq_len + (cfg.num_patches if cfg.frontend == "vision_stub"
                         else 0)
    b_l = max(1, shape.global_batch // dp)
    h_l = max(1, cfg.num_heads // tp)
    kv_l = max(1, min(cfg.num_kv_heads, tp) // 1)
    hd = cfg.resolved_head_dim
    layers = cfg.num_layers
    if shape.kind == "decode":
        scores = layers * b_l * h_l * shape.seq_len * 4.0  # (1 x T) rows
        kernel_io = layers * b_l * (cfg.num_kv_heads * hd * 2
                                    * shape.seq_len * 2)  # stream K+V once
    else:
        passes = 3.0 if shape.kind == "train" else 1.0  # fwd+remat+bwd
        scores = layers * b_l * h_l * (s * s / 2) * 4.0 * passes
        kv_bytes = s * cfg.num_kv_heads * hd * 2 / tp
        n_qb = max(1, s // q_block)
        kernel_io = layers * b_l * (n_qb * kv_bytes / 2 * 2) * passes
    scores *= boundary_factor
    mem_bytes = rec["per_device_bytes"]
    projected = max(mem_bytes - scores, mem_bytes * 0.02) + kernel_io
    return {
        "score_traffic_est": scores,
        "kernel_io_est": kernel_io,
        "memory_s_projected": projected / ROOFLINE.hbm_bw,
    }


def markdown(rows: List[dict]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | roofline frac | fits HBM |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['dominant']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['fits_hbm']} |")
    return "\n".join(out)


if __name__ == "__main__":
    run("single")
    run("multi")
