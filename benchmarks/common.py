"""Shared benchmark harness: run the policy grid of §4 (datasets x
bandwidths x policies) on the discrete-event simulator with the paper's
testbed calibration (single A100 cloud, single 3090 edge, Qwen2-VL-2B /
Qwen2.5-VL-7B, τ=0.5, averaged weights)."""
from __future__ import annotations

import csv
import os
from typing import Dict, List

from repro.config import PolicyConfig, SimConfig
from repro.data.synthetic import RequestGenerator
from repro.serving.accuracy_model import MMBENCH, VQAV2
from repro.serving.simulator import EdgeCloudSimulator

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")

POLICIES = ["cloud-only", "edge-only", "perllm", "moa-off"]
BANDWIDTHS = [200e6, 300e6, 400e6]
DATASETS = {"vqav2": VQAV2, "mmbench": MMBENCH}

# §4.1 operating point: 5000 images in the paper; we default lower for CI
# speed but keep the arrival rate that loads a single-GPU tier to ~75%.
N_REQUESTS = int(os.environ.get("REPRO_SIM_REQUESTS", "1200"))
ARRIVAL_RATE = 1.1  # req/s — loads a single-GPU tier to ~90%
EDGE_MFU = 0.15  # 3090-class achievable fraction for a 2B VLM


# paper-faithful policy: STATIC τ = 0.5 (§4.1); the adaptive-τ controller is
# our beyond-paper extension, evaluated separately in EXPERIMENTS.md
PAPER_POLICY = PolicyConfig(adaptive_tau=False)


def run_grid(policies: List[str] = POLICIES,
             bandwidths: List[float] = BANDWIDTHS,
             datasets: Dict = DATASETS, n: int = N_REQUESTS,
             fail_rate: float = 0.0, hedge_after_s: float = 0.0,
             policy_cfg: PolicyConfig = PAPER_POLICY,
             seed: int = 0) -> List[dict]:
    from repro.config import TierConfig

    rows = []
    for ds_name, acc_model in datasets.items():
        for bw in bandwidths:
            for pol in policies:
                gen = RequestGenerator(seed=seed, arrival_rate=ARRIVAL_RATE)
                sim_cfg = SimConfig(
                    bandwidth_bps=bw, seed=seed + 1,
                    edge=TierConfig("edge", "qwen2-vl-2b", 1, 35.6e12,
                                    936e9, mfu=EDGE_MFU),
                    cloud=TierConfig("cloud", "qwen2.5-vl-7b", 1, 312e12,
                                     1_555e9, mfu=0.42))
                sim = EdgeCloudSimulator(
                    sim_cfg,
                    policy_name=pol, policy_cfg=policy_cfg,
                    acc_model=acc_model, fail_rate=fail_rate,
                    hedge_after_s=hedge_after_s,
                    cloud_servers=1, edge_servers=1)  # the paper's testbed
                for r in gen.generate(n):
                    sim.submit(r)
                sim.run()
                m = sim.metrics()
                m.update({"dataset": ds_name, "bandwidth_mbps": bw / 1e6,
                          "policy": pol, "n": n})
                rows.append(m)
    return rows


def write_csv(rows: List[dict], path: str, fields: List[str]) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields, extrasaction="ignore")
        w.writeheader()
        for r in rows:
            w.writerow(r)
    return path
