"""§4.3 ablation reproduction:
  (a) remove modality-aware offloading  -> accuracy drops (~6.8pp in paper);
  (b) remove collaborative scheduling   -> latency (+21.5%), compute (+18.7%)
      and memory (+16.3%) overheads rise.

The collaborative component reacts to SYSTEM STATE, so this benchmark runs
under pressure (node-failure injection -> retries pile queues up): the full
MoA-Off (Eq.5 state gates + queue-balancing adaptive τ) re-routes around the
backlog; the no-collab variant keeps routing blindly.
"""
from __future__ import annotations

import os

from benchmarks.common import RESULTS_DIR, run_grid, write_csv
from repro.config import PolicyConfig
from repro.serving.accuracy_model import VQAV2

FULL = PolicyConfig(adaptive_tau=True)


def run(n=None):
    pols = ["moa-off", "moa-off-no-modality", "moa-off-no-collab"]
    kw = dict(policies=pols, bandwidths=[300e6], datasets={"vqav2": VQAV2},
              fail_rate=0.08, policy_cfg=FULL)
    rows = run_grid(n=n, **kw) if n else run_grid(**kw)
    path = write_csv(rows, os.path.join(RESULTS_DIR, "ablation.csv"),
                     ["policy", "accuracy", "mean_latency_s", "total_flops",
                      "total_mem_byte_s", "retries"])
    line = {r["policy"]: r for r in rows}
    full = line["moa-off"]
    noma = line["moa-off-no-modality"]
    noco = line["moa-off-no-collab"]
    out = {
        "acc_drop_no_modality_pp":
            100 * (full["accuracy"] - noma["accuracy"]),
        "latency_rise_no_collab_pct":
            100 * (noco["mean_latency_s"] / full["mean_latency_s"] - 1),
        "compute_rise_no_collab_pct":
            100 * (noco["total_flops"] / full["total_flops"] - 1),
        "mem_rise_no_collab_pct":
            100 * (noco["total_mem_byte_s"] / full["total_mem_byte_s"] - 1),
    }
    print("\n§4.3 ablation (paper: -6.8pp acc; +21.5% lat, +18.7% compute, "
          "+16.3% mem):")
    for k, v in out.items():
        print(f"  {k:32s} {v:+6.2f}")
    return rows, out, path


if __name__ == "__main__":
    run()
