"""Model-component tests: MoE dropping vs dense oracle, SSD chunking
invariance, RG-LRU scan vs sequential recurrence, local attention semantics,
vocab padding, chunked CE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import reduced_config
from repro.models import attention as A
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.layers import cross_entropy_loss, pad_vocab, unembed
from repro.models.spec import init_tree

RNG = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# attention variants agree
# ---------------------------------------------------------------------------


def test_flash_xla_matches_dense():
    q = jax.random.normal(RNG, (2, 128, 8, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 128, 4, 32))
    pos = jnp.arange(128, dtype=jnp.int32)
    d = A.dense_attention(q, k, v, pos, pos, causal=True)
    f = A.flash_attention_xla(q, k, v, pos, pos, causal=True,
                              q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(d, f, rtol=2e-5, atol=2e-5)


def test_local_attention_matches_dense_window():
    q = jax.random.normal(RNG, (1, 96, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 96, 1, 16))
    pos = jnp.arange(96, dtype=jnp.int32)
    w = 32
    d = A.dense_attention(q, k, v, pos, pos, causal=True, window=w)
    l = A.local_attention(q, k, v, pos, window=w)
    np.testing.assert_allclose(d, l, rtol=2e-5, atol=2e-5)


def test_local_attention_ragged_length():
    q = jax.random.normal(RNG, (1, 50, 2, 16))  # not a multiple of window
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 50, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 50, 2, 16))
    pos = jnp.arange(50, dtype=jnp.int32)
    d = A.dense_attention(q, k, v, pos, pos, causal=True, window=16)
    l = A.local_attention(q, k, v, pos, window=16)
    np.testing.assert_allclose(d, l, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def _moe_cfg(cf=8.0):
    return (reduced_config("qwen3-moe-235b-a22b")
            .replace(dtype="float32", capacity_factor=cf))


def test_moe_dropping_matches_dense_with_headroom():
    cfg = _moe_cfg(cf=8.0)  # capacity high enough that nothing drops
    params = init_tree(M.moe_specs(cfg), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    yd, auxd = M.moe_dense_forward(params, x, cfg)
    yl, auxl = M.moe_dropping_local(params, x.reshape(-1, cfg.d_model), cfg,
                                    None, None)
    np.testing.assert_allclose(yd, yl.reshape(x.shape), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(auxd, auxl, rtol=1e-5)


def test_moe_dropping_drops_on_overflow():
    cfg = _moe_cfg(cf=0.25)  # force capacity pressure
    params = init_tree(M.moe_specs(cfg), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model))
    yl, _ = M.moe_dropping_local(params, x.reshape(-1, cfg.d_model), cfg,
                                 None, None)
    yd, _ = M.moe_dense_forward(params, x, cfg)
    # outputs differ (drops happened) but remain finite
    assert np.isfinite(np.asarray(yl)).all()
    assert float(jnp.max(jnp.abs(yl.reshape(x.shape) - yd))) > 0


def test_moe_aux_loss_balanced_is_one():
    """Perfectly uniform routing gives aux = E * E*(1/E)*(1/E) = 1."""
    probs = jnp.full((128, 8), 1 / 8.0)
    ids = jnp.tile(jnp.arange(8)[None, :2], (128, 1))
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 8, (128, 2)))
    aux = M._aux_loss(probs, ids, 8)
    assert 0.8 < float(aux) < 1.3


def test_moe_grads_reach_router_and_experts():
    cfg = _moe_cfg()
    params = init_tree(M.moe_specs(cfg), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))

    def loss(p):
        y, aux = M.moe_dropping_local(p, x.reshape(-1, cfg.d_model), cfg,
                                      None, None)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for key in ("router", "wi", "wo"):
        assert float(jnp.max(jnp.abs(g[key]))) > 0, key


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------


def _ssd_io(s, seed=0):
    cfg = reduced_config("mamba2-2.7b").replace(dtype="float32")
    k = jax.random.PRNGKey(seed)
    b, h, p, n = 2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xh = jax.random.normal(k, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(seed + 1),
                                           (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(seed + 2), (h,)) * 0.3)
    bm = jax.random.normal(jax.random.PRNGKey(seed + 3), (b, s, n)) * 0.3
    cm = jax.random.normal(jax.random.PRNGKey(seed + 4), (b, s, n)) * 0.3
    return xh, dt, a, bm, cm


def _ssd_sequential(xh, dt, a, bm, cm):
    """O(S) reference recurrence: h = exp(dt*a) h + dt * B (x) x."""
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    hstate = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None, :])  # (B,H)
        hstate = (hstate * da[:, :, None, None]
                  + jnp.einsum("bn,bhp->bhpn", bm[:, t],
                               xh[:, t] * dt[:, t][..., None]))
        ys.append(jnp.einsum("bn,bhpn->bhp", cm[:, t], hstate))
    return jnp.stack(ys, axis=1), hstate


@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 48)])
def test_ssd_chunked_matches_sequential(s, chunk):
    xh, dt, a, bm, cm = _ssd_io(s)
    y, hN = S._ssd_chunked(xh, dt, a, bm, cm, chunk)
    y_ref, h_ref = _ssd_sequential(xh, dt, a, bm, cm)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(hN, h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_chunk_size_invariance():
    xh, dt, a, bm, cm = _ssd_io(64)
    y1, h1 = S._ssd_chunked(xh, dt, a, bm, cm, 8)
    y2, h2 = S._ssd_chunked(xh, dt, a, bm, cm, 32)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(h1, h2, rtol=1e-4, atol=1e-4)


def test_ssd_block_prefill_then_decode_matches_forward():
    cfg = reduced_config("mamba2-2.7b").replace(dtype="float32")
    params = init_tree(S.ssd_specs(cfg), RNG, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 33, cfg.d_model)) * 0.1
    full, _ = S.ssd_forward(params, x, cfg)
    part, cache = S.ssd_forward(params, x[:, :32], cfg)
    last, cache2 = S.ssd_decode(params, x[:, 32:33], cfg, cache)
    np.testing.assert_allclose(full[:, 32:33], last, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def test_rglru_scan_matches_sequential():
    a = jax.nn.sigmoid(jax.random.normal(RNG, (2, 24, 8)))
    b = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 8))
    h, h_last = R._lru_scan(a, b, None)
    ref = jnp.zeros((2, 8))
    outs = []
    for t in range(24):
        ref = a[:, t] * ref + b[:, t]
        outs.append(ref)
    np.testing.assert_allclose(h, jnp.stack(outs, 1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h_last, ref, rtol=1e-5, atol=1e-5)


def test_rglru_forward_then_decode_continues_state():
    cfg = reduced_config("recurrentgemma-9b").replace(dtype="float32")
    params = init_tree(R.rglru_specs(cfg), RNG, jnp.float32)
    params = {**params}
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 17, cfg.d_model)) * 0.1
    full, _ = R.rglru_forward(params, x, cfg)
    part, cache = R.rglru_forward(params, x[:, :16], cfg)
    last, _ = R.rglru_decode(params, x[:, 16:17], cfg, cache)
    np.testing.assert_allclose(full[:, 16:17], last, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


@given(v=st.integers(100, 300_000))
@settings(max_examples=50, deadline=None)
def test_pad_vocab_properties(v):
    p = pad_vocab(v)
    assert p >= v and p % 256 == 0 and p - v < 256


def test_unembed_masks_padded_vocab():
    table = jnp.ones((512, 8))
    x = jnp.ones((1, 1, 8))
    logits = unembed(x, table, true_vocab=300)
    assert float(logits[0, 0, 299]) > -1e29
    assert float(logits[0, 0, 300]) < -1e29


def test_chunked_ce_matches_direct():
    cfg = reduced_config("qwen3-0.6b").replace(dtype="float32")
    from repro.models import build_model

    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 64
    tok = jax.random.randint(RNG, (b, s), 0, cfg.vocab_size)
    lab = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, cfg.vocab_size)
    hidden, _, _, _ = model.forward(params, {"tokens": tok}, "train")
    table = params["embed"]
    direct = cross_entropy_loss(
        unembed(hidden.astype(jnp.float32), table, cfg.vocab_size), lab)
    chunked = model._chunked_ce(hidden, table, lab, jnp.ones((b, s)))
    np.testing.assert_allclose(float(direct), float(chunked), rtol=1e-5)
