"""Prefix & session KV reuse: warm (prefix-hit / resumed-session) serving is
token-for-token identical to cold serving for every family, only suffixes
are ever prefilled on a hit, the stores LRU-evict under their byte budgets,
and the cluster runtime routes session turns sticky-by-default with
identical hit/miss decision traces through both execution backends."""
import copy

import numpy as np
import pytest

from repro.config import (PolicyConfig, ServingConfig, SimConfig,
                          get_topology, two_tier_topology)
from repro.core.baselines import make_policy
from repro.core.scheduler import MoAOffScheduler
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.prefix import (ParkedSession, PrefixStore, SessionStore,
                                  extension_suffix, prefix_buckets)
from repro.serving.simulator import ClusterSimulator
from repro.serving.tiers import ClusterServer, build_cluster_engines

FAMILY_PARAMS = [
    "dense",
    # the heavier families ride the slow mark to keep the smoke lane fast
    pytest.param("vlm", marks=pytest.mark.slow),
    pytest.param("moe", marks=pytest.mark.slow),
    pytest.param("ssm", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
]


def make_engine(cfg, params, max_batch=2, max_seq=128, **sv_kw):
    sv = ServingConfig(max_batch=max_batch, max_seq=max_seq, **sv_kw)
    return TierEngine(build_model(cfg), params, sv, eos_id=-1)


def _family_inputs(cfg, base_len=40, ext_len=10, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.integers(4, 200, size=base_len).astype(np.int32)
    ext = rng.integers(4, 200, size=ext_len).astype(np.int32)
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["patches"] = rng.standard_normal(
            (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
    return base, ext, extras


def _drain_tokens(eng, rid):
    done = {s.rid: s.generated for s in eng.run_until_drained()}
    eng.finished.clear()
    return done[rid]


# ---------------------------------------------------------------------------
# store unit behavior (pure host logic, shared by both backends)
# ---------------------------------------------------------------------------


def test_prefix_buckets_ladder():
    assert prefix_buckets(100) == [16, 32, 64, 100]
    assert prefix_buckets(64) == [16, 32, 64]
    assert prefix_buckets(8) == []  # below the minimum prefix
    assert prefix_buckets(16) == [16]


def test_extension_suffix():
    cached = np.arange(5)
    assert extension_suffix(cached, np.arange(8)).tolist() == [5, 6, 7]
    assert extension_suffix(cached, np.arange(5)) is None  # no new tokens
    other = np.array([9, 9, 9, 9, 9, 5])
    assert extension_suffix(cached, other) is None  # not an extension


def test_prefix_store_lookup_prefers_longest():
    s = PrefixStore(1e9)
    toks = np.arange(100)
    for n in prefix_buckets(100):
        s.insert(toks[:n], b"", 100.0, data=n)
    hit = s.lookup(np.concatenate([toks, [1, 2]]), b"")
    assert len(hit.tokens) == 100
    hit = s.lookup(toks[:40], b"")  # only 32 leaves a suffix
    assert len(hit.tokens) == 32
    assert s.lookup(toks[:16], b"") is None  # would leave no suffix
    assert s.lookup(np.arange(100) + 1, b"") is None  # different content
    assert s.lookup(toks[:40], b"img") is None  # different extras


def test_prefix_store_lru_eviction_under_tight_budget():
    s = PrefixStore(1000.0)
    a, b, c = (np.arange(20) + k * 100 for k in range(3))
    assert s.insert(a, b"", 400.0, data="a")
    assert s.insert(b, b"", 400.0, data="b")
    assert s.lookup(np.concatenate([a, [1]]), b"") is not None  # touch a
    assert s.insert(c, b"", 400.0, data="c")  # evicts b (LRU)
    assert s.evictions == 1
    assert s.lookup(np.concatenate([b, [1]]), b"") is None
    assert s.lookup(np.concatenate([a, [1]]), b"") is not None
    assert s.lookup(np.concatenate([c, [1]]), b"") is not None
    # an entry larger than the whole budget is refused outright
    assert not s.insert(np.arange(99), b"", 5000.0)


def test_session_store_budget_and_resume_consumes():
    s = SessionStore(1000.0)
    assert s.park("a", ParkedSession(np.arange(4), b"", 600.0))
    assert s.park("b", ParkedSession(np.arange(4), b"", 600.0))  # evicts a
    assert "a" not in s and "b" in s
    assert s.resume("b") is not None
    assert "b" not in s  # consumed
    dead = SessionStore(0.0)
    assert not dead.enabled
    assert not dead.park("x", ParkedSession(np.arange(4), b"", 1.0))


# ---------------------------------------------------------------------------
# engine: warm vs cold token parity, suffix-only prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_prefix_hit_matches_cold(family, family_model):
    """A prompt extending a stored prefix decodes token-for-token like a
    cold full prefill, while the engine prefills ONLY the suffix."""
    cfg, params = family_model(family)
    base, ext, extras = _family_inputs(cfg)
    full = np.concatenate([base, ext])

    cold = make_engine(cfg, params)
    cold.submit(0, full, max_new=8, extras=dict(extras))
    want = _drain_tokens(cold, 0)

    warm = make_engine(cfg, params, prefix_cache_mb=64.0)
    warm.submit(0, base, max_new=8, extras=dict(extras))
    _drain_tokens(warm, 0)
    pf0 = warm.prefill_tokens
    warm.submit(1, full, max_new=8, extras=dict(extras))
    assert _drain_tokens(warm, 1) == want
    assert warm.prefix_hits == 1
    # cached counts reused cache POSITIONS: vision prefix included
    vis = cfg.num_patches if extras else 0
    assert warm.prefix_hit_tokens == len(base) + vis
    # ONLY the suffix was prefilled on the hit
    assert warm.prefill_tokens - pf0 == len(ext)


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_resumed_session_matches_cold(family, family_model):
    """Turn k+1 resuming a parked session decodes token-for-token like cold
    prefilling the whole history, charging only the new tokens."""
    cfg, params = family_model(family)
    base, ext, extras = _family_inputs(cfg)

    eng = make_engine(cfg, params)
    eng.submit(0, base, max_new=6, extras=dict(extras), session="s")
    gen1 = _drain_tokens(eng, 0)
    assert eng.parks == 1 and "s" in eng.sessions
    hist = np.concatenate([base, np.asarray(gen1, np.int32), ext])
    pf0 = eng.prefill_tokens
    eng.submit(1, hist, max_new=6, extras=dict(extras), session="s")
    got = _drain_tokens(eng, 1)

    cold = make_engine(cfg, params)
    cold.submit(0, hist, max_new=6, extras=dict(extras))
    assert got == _drain_tokens(cold, 0)
    assert eng.resumed_sessions == 1
    # suffix = the last generated token (sampled, never fed) + new tokens
    assert eng.prefill_tokens - pf0 == len(ext) + 1
    assert "s" in eng.sessions  # turn 2 re-parked


def test_prefix_store_disabled_is_default(family_model):
    cfg, params = family_model("dense")
    eng = make_engine(cfg, params)
    assert not eng.prefix_store.enabled
    base, ext, _ = _family_inputs(cfg)
    eng.submit(0, base, max_new=4)
    _drain_tokens(eng, 0)
    pf0 = eng.prefill_tokens
    eng.submit(1, np.concatenate([base, ext]), max_new=4)
    _drain_tokens(eng, 1)
    assert eng.prefix_hits == 0
    assert eng.prefill_tokens - pf0 == len(base) + len(ext)  # full prefill


def test_engine_lru_eviction_under_tight_prefix_budget(family_model):
    """A prefix budget too small for two prompts keeps only the most
    recent one's rows (and the evicted prompt misses back to cold)."""
    cfg, params = family_model("dense")
    rng = np.random.default_rng(3)
    a = rng.integers(4, 200, size=48).astype(np.int32)
    b = rng.integers(4, 200, size=48).astype(np.int32)
    # budget sized to roughly ONE prompt's ladder of rows
    one = make_engine(cfg, params, prefix_cache_mb=64.0)
    one.submit(0, a, max_new=2)
    _drain_tokens(one, 0)
    need_mb = one.prefix_store.lru.bytes / 1e6
    eng = make_engine(cfg, params, prefix_cache_mb=need_mb * 1.2)
    eng.submit(0, a, max_new=2)
    _drain_tokens(eng, 0)
    eng.submit(1, b, max_new=2)
    _drain_tokens(eng, 1)
    assert eng.prefix_store.evictions > 0
    # b's prefixes survived; a's longest prefix was evicted
    hit_b = eng.prefix_store.lookup(np.concatenate([b, [5]]), b"")
    assert hit_b is not None and len(hit_b.tokens) == len(b)
    hit_a = eng.prefix_store.lookup(np.concatenate([a, [5]]), b"")
    assert hit_a is None or len(hit_a.tokens) < len(a)


def test_session_park_respects_budget(family_model):
    cfg, params = family_model("dense")
    base, ext, _ = _family_inputs(cfg)
    eng = make_engine(cfg, params, session_cache_mb=0.0)
    eng.submit(0, base, max_new=4, session="s")
    _drain_tokens(eng, 0)
    assert eng.parks == 0 and "s" not in eng.sessions
    pf0 = eng.prefill_tokens
    hist = np.concatenate([base, ext])
    eng.submit(1, hist, max_new=4, session="s")
    _drain_tokens(eng, 1)
    assert eng.resumed_sessions == 0  # nothing parked: cold fallback
    assert eng.prefill_tokens - pf0 == len(hist)


def test_park_session_marks_inflight_request(family_model):
    cfg, params = family_model("dense")
    base, _, _ = _family_inputs(cfg)
    eng = make_engine(cfg, params)
    eng.submit(0, base, max_new=30)
    assert eng.park_session(0, "late")  # still waiting
    eng.step()
    eng.submit(1, base, max_new=30)
    assert eng.park_session(1, "late2")  # waiting while 0 decodes
    assert not eng.park_session(99, "nope")
    eng.run_until_drained()
    assert "late" in eng.sessions and "late2" in eng.sessions


def test_session_wire_roundtrip_preserves_prompt_tokens(family_model):
    """A parked payload survives the versioned wire format with its prompt
    tokens and extras fingerprint (cross-tier session moves ship these)."""
    from repro.serving.engine import SlotPayload

    cfg, params = family_model("dense")
    base, ext, _ = _family_inputs(cfg)
    eng = make_engine(cfg, params)
    eng.submit(0, base, max_new=4, session="s")
    _drain_tokens(eng, 0)
    parked = eng.sessions.peek("s")
    wire = parked.data.to_bytes()
    back = SlotPayload.from_bytes(wire)
    assert np.array_equal(back.prompt_tokens, base)
    assert back.extras_fp == parked.data.extras_fp
    assert back.seq.session == "s"
    # a second engine adopts it and the next turn resumes warm
    eng2 = make_engine(cfg, params)
    assert eng2.adopt_session("s", back)
    hist = np.concatenate([base,
                           np.asarray(parked.data.seq.generated, np.int32),
                           ext])
    eng2.submit(7, hist, max_new=4, session="s")
    _drain_tokens(eng2, 7)
    assert eng2.resumed_sessions == 1


def test_adopt_rejects_incompatible_payload(family_model):
    import dataclasses

    cfg, params = family_model("dense")
    base, _, _ = _family_inputs(cfg)
    eng = make_engine(cfg, params)
    eng.submit(0, base, max_new=4, session="s")
    _drain_tokens(eng, 0)
    payload = eng.sessions.resume("s").data
    other = make_engine(cfg, params, max_seq=64)  # different geometry
    assert not other.adopt_session("s", payload)
    wrong = dataclasses.replace(payload, model="other-model", _wire=None)
    assert not eng.adopt_session("s", wrong)


# ---------------------------------------------------------------------------
# cluster runtime: sticky routing, parity, analytic discounting
# ---------------------------------------------------------------------------


def _twin_topo_servers(sv=None, **kw):
    topo = get_topology("edge-edge-cloud")
    sv = sv or ServingConfig(max_batch=2, max_seq=256)
    return ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(
            "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
        **kw)


def _two_tier_server(sv=None, **kw):
    topo = two_tier_topology()
    sv = sv or ServingConfig(max_batch=2, max_seq=256)
    return ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(
            "moa-off", PolicyConfig(adaptive_tau=False),
            topology=topo)), **kw)


@pytest.mark.slow
def test_sim_and_live_agree_on_multiturn_sessions():
    """Three turns of one chat through both backends: identical routing,
    sticky decisions and hit/miss (resume/park) traces, and the live
    engine's prefill counter proves only suffixes were prefilled on warm
    turns."""
    server = _two_tier_server(sessions=True)
    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=two_tier_topology(), sessions=True)
    sim_reqs = []
    for turn in range(3):
        req = server.build_turn(
            "chat-1", f"turn {turn}: please describe the Scene more. ",
            max_new=6, complexity={"text": 0.05})
        sreq = copy.deepcopy(req)
        sreq.arrival_s = 100.0 * (turn + 1)
        sim_reqs.append(sreq)
        server.submit_request(req)
        server.run()  # turns are sequential: each extends the last
    for r in sim_reqs:
        sim.submit(r)
    sim.run()

    live = {r.rid: r for r in server.results}
    ana = {o.rid: o for o in sim.outcomes}
    for i, r in enumerate(sim_reqs):
        lt = server.runtime.records[r.rid].trace()
        at = sim.runtime.records[r.rid].trace()
        assert lt == at  # identical lifecycle incl. sticky/resume/park
        assert live[r.rid].warm == ana[r.rid].warm
        assert live[r.rid].warm == ("" if i == 0 else "resume")
    # live engine really skipped the history prefill on warm turns
    eng = server.engines[server.results[-1].tier]
    assert eng.resumed_sessions == 2
    m = sim.metrics()
    assert m["resumed"] == pytest.approx(2 / 3)
    assert m["warm_tokens"] > 0


def _equal_twin_topology():
    """Two IDENTICAL local edges (same model and speed: any queue imbalance
    flips the argmin) plus the standard remote cloud."""
    from repro.config import ClusterTopology, TierSpec

    return ClusterTopology("equal-twin", (
        TierSpec("edge", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.25,
                 capability=0.0),
        TierSpec("edge2", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.25,
                 capability=0.0),
        TierSpec("cloud", "qwen2.5-vl-7b", 1, 312e12, 1_555e9, mfu=0.42,
                 uplink_bps=300e6, rtt_s=0.02, capability=1.0),
    ))


@pytest.mark.slow
def test_session_move_ships_parked_state_to_preferred_tier():
    """With a move threshold, a turn whose parked tier is busier than an
    idle identical twin ships the parked payload there instead of sticking
    — and still resumes warm. Identical decision through both backends."""
    topo = _equal_twin_topology()
    sv = ServingConfig(max_batch=1, max_seq=256)
    server = ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(
            "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
        sessions=True, session_move_threshold=1)
    # turn 1 parks on edge (idle tie-break picks the first twin)
    server.submit_turn("s", "hello there friend. ", max_new=4,
                       complexity={"text": 0.05})
    server.run()
    assert server.results[0].tier == "edge"
    # a blocker queues on edge; turn 2 then prefers the idle twin and the
    # parked state moves ahead of it
    server.submit("block the edge tier for a while please. " * 2,
                  max_new=60, complexity={"text": 0.05})
    rid2 = server.submit_turn("s", "tell me more. ", max_new=4,
                              complexity={"text": 0.05})
    server.run()
    res2 = next(r for r in server.results if r.rid == rid2)
    trace = server.runtime.records[rid2].trace()
    assert res2.tier == "edge2"
    assert ("session_move", "edge2") in trace
    assert res2.warm == "resume"  # moved AND resumed warm
    assert server.runtime.session_moves == 1

    # analytic mirror: same decisions on the same topology
    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=_equal_twin_topology(),
                           sessions=True, session_move_threshold=1)
    from repro.core.request import ModalityInput, Request

    def sim_req(rid, t, tokens, decode, sid):
        return Request(rid=rid, arrival_s=t, modalities={
            "text": ModalityInput("text", size_bytes=tokens * 4,
                                  complexity=0.05,
                                  meta={"tokens": tokens, "entities": 0,
                                        "sentences": 1})},
            decode_tokens=decode, slo_s=30.0, session=sid)

    sim.submit(sim_req(0, 1.0, 8, 4, "s"))
    sim.submit(sim_req(1, 10.0, 16, 200, None))  # queues on edge
    sim.submit(sim_req(2, 10.001, 16, 4, "s"))
    sim.run()
    at = sim.runtime.records[2].trace()
    assert ("session_move", "edge2") in at
    assert ("resume", "edge2") in at
    assert sim.runtime.session_moves == 1


def test_sticky_turn_overrides_modality_routes():
    """A sticky session turn serves ENTIRELY on the parked tier even when
    the scheduler would route a modality elsewhere: no phantom off-fusion
    encode or WAN transfer is charged for work that never happens."""
    from repro.core.request import ModalityInput, Request

    def turn(rid, t, tokens, sid, cx):
        return Request(rid=rid, arrival_s=t, modalities={
            "text": ModalityInput("text", size_bytes=tokens * 4,
                                  complexity=cx,
                                  meta={"tokens": tokens, "entities": 0,
                                        "sentences": 1})},
            decode_tokens=8, slo_s=30.0, session=sid)

    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=two_tier_topology(), sessions=True)
    sim.submit(turn(0, 1.0, 32, "s", 0.05))  # parks on edge
    # turn 2 is complex enough that the scheduler would pick cloud — but
    # the parked KV lives on edge, so the turn sticks and serves there
    sim.submit(turn(1, 100.0, 96, "s", 0.95))
    sim.run()
    out = {o.rid: o for o in sim.outcomes}
    assert out[1].warm == "resume"
    assert out[1].served_tier == "edge"
    assert out[1].routes == {"text": "edge"}  # overridden with the stick
    assert out[1].transfer_bytes == 0.0  # nothing crossed the WAN
    trace = sim.runtime.records[1].trace()
    assert ("sticky", "edge") in trace
    assert not any(s.startswith("encode") or s == "transfer"
                   for s, _ in trace)
    # control: without a session the same request goes to cloud
    ctl = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=two_tier_topology())
    ctl.submit(turn(0, 1.0, 96, None, 0.95))
    ctl.run()
    assert ctl.outcomes[0].served_tier == "cloud"


def test_analytic_sessions_charge_suffix_only():
    """With sessions on, turn 2's service pays less prefill than the same
    request cold: lower flops AND lower latency, with the warm trace."""
    from repro.core.request import ModalityInput, Request

    def turn(rid, t, tokens, sid):
        return Request(rid=rid, arrival_s=t, modalities={
            "text": ModalityInput("text", size_bytes=tokens * 4,
                                  complexity=0.05,
                                  meta={"tokens": tokens, "entities": 0,
                                        "sentences": 1})},
            decode_tokens=16, slo_s=30.0, session=sid)

    def run(sessions):
        sim = ClusterSimulator(SimConfig(seed=0),
                               policy_cfg=PolicyConfig(adaptive_tau=False),
                               topology=two_tier_topology(),
                               sessions=sessions)
        sim.submit(turn(0, 1.0, 64, "s"))
        sim.submit(turn(1, 100.0, 200, "s"))  # extends the history
        sim.run()
        return sim

    warm = run(True)
    cold = run(False)
    w1 = next(o for o in warm.outcomes if o.rid == 1)
    c1 = next(o for o in cold.outcomes if o.rid == 1)
    assert w1.warm == "resume" and c1.warm == ""
    assert w1.warm_tokens > 0
    assert sum(w1.tier_flops.values()) < sum(c1.tier_flops.values())
    assert w1.latency_s < c1.latency_s
    assert ("park", w1.served_tier) in warm.runtime.records[0].trace()
    m = warm.metrics()
    assert {"resumed", "prefix_hits", "warm_tokens",
            "session_moves"} <= set(m)
    assert "resumed" not in cold.metrics()  # gated: golden key set intact


def test_analytic_prefix_mirror_hits_on_real_ids():
    """Requests carrying real token ids hit the analytic prefix mirror the
    same way the live engine does: same content rule, suffix-only cost."""
    from repro.core.request import ModalityInput, Request

    rng = np.random.default_rng(0)
    base = rng.integers(4, 200, size=64).astype(np.int32)
    full = np.concatenate([base, rng.integers(4, 200, 32).astype(np.int32)])

    def req(rid, t, ids):
        return Request(rid=rid, arrival_s=t, modalities={
            "text": ModalityInput("text", data=ids,
                                  size_bytes=len(ids) * 4, complexity=0.05,
                                  meta={"tokens": len(ids), "entities": 0,
                                        "sentences": 1})},
            decode_tokens=8, slo_s=30.0)

    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=two_tier_topology(),
                           prefix_cache_mb=64.0)
    sim.submit(req(0, 1.0, base))
    sim.submit(req(1, 100.0, full))
    sim.submit(req(2, 200.0, full[:32]))  # shares only the 16/32 buckets
    sim.run()
    out = {o.rid: o for o in sim.outcomes}
    assert out[0].warm == ""
    assert out[1].warm == "prefix" and out[1].warm_tokens == len(base)
    assert out[2].warm == "prefix" and out[2].warm_tokens == 16
    assert sim.backend.prefix_hits == 2


def test_live_session_turns_resume_and_park():
    """Fast live path: two turns of one session through ClusterServer's
    submit_turn — turn 2 routes sticky, resumes the parked state, and
    prefills only its suffix (prefill counter proof)."""
    server = _two_tier_server(sessions=True)
    server.submit_turn("chat", "hello there, introduce Yourself please. ",
                       max_new=4, complexity={"text": 0.05})
    server.run()
    (r1,) = server.results
    assert r1.warm == ""
    eng = server.engines[r1.tier]
    pf0 = eng.prefill_tokens
    server.submit_turn("chat", "now expand on that Thought. ",
                       max_new=4, complexity={"text": 0.05})
    server.run()
    r2 = server.results[1]
    assert r2.warm == "resume" and r2.warm_tokens > 0
    trace = server.runtime.records[r2.rid].trace()
    assert ("sticky", r1.tier) in trace
    assert ("resume", r2.tier) in trace
    assert ("park", r1.tier) in trace  # turn 2 re-parked for turn 3
    # only the new tokens (last generated + new text) were prefilled
    hist_len = len(server._session_hist["chat"]["ids"])
    assert eng.prefill_tokens - pf0 < hist_len
    assert server.backend.parked_sessions()[r1.tier] == 1


def test_ssm_warm_scan_prefix_hit_fast(family_model):
    """The recurrent-state warm path (per-token decode scan, exact-length
    store entries) on the smoke lane: state families hit only on prompts
    extending the EXACT stored sequence."""
    cfg, params = family_model("ssm")
    base, ext, _ = _family_inputs(cfg, base_len=24, ext_len=6)
    cold = make_engine(cfg, params)
    cold.submit(0, np.concatenate([base, ext]), max_new=4)
    want = _drain_tokens(cold, 0)

    warm = make_engine(cfg, params, prefix_cache_mb=64.0)
    warm.submit(0, base, max_new=4)
    _drain_tokens(warm, 0)
    # a shorter prefix of the stored sequence cannot hit (no slicing of
    # point-in-time state): different suffix start -> cold
    warm.submit(1, np.concatenate([base[:20], ext]), max_new=4)
    _drain_tokens(warm, 1)
    assert warm.prefix_hits == 0
    warm.submit(2, np.concatenate([base, ext]), max_new=4)
    assert _drain_tokens(warm, 2) == want
    assert warm.prefix_hits == 1 and warm.prefix_hit_tokens == len(base)


def test_live_prefix_cache_across_requests_two_tier():
    """Two independent requests sharing a system prefix: the second is a
    prefix hit on the live path, with identical tokens to a cold replay."""
    sv = ServingConfig(max_batch=2, max_seq=256, prefix_cache_mb=64.0)
    server = _two_tier_server(sv)
    system = "you are a Helpful assistant; answer with Care please. " * 2
    server.submit(system + "first question about the Weather. ",
                  max_new=4, complexity={"text": 0.05})
    server.run()
    server.submit(system + "second question about the Ocean. ",
                  max_new=4, complexity={"text": 0.05})
    server.run()
    warm_res = server.results[1]
    assert warm_res.warm == "prefix"
    assert warm_res.warm_tokens > 0
    trace = server.runtime.records[warm_res.rid].trace()
    assert ("prefix", warm_res.tier) in trace

    cold = _two_tier_server()
    cold.submit(system + "first question about the Weather. ",
                max_new=4, complexity={"text": 0.05})
    cold.run()
    cold.submit(system + "second question about the Ocean. ",
                max_new=4, complexity={"text": 0.05})
    cold.run()
    assert server.results[1].tokens == cold.results[1].tokens
