import os

# smoke tests and benches see ONE device; only the dry-run forces 512
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# one reduced arch per serving-capable model family (encdec is exercised via
# its own audio pipeline tests) — shared by the migration round-trip suite
FAMILY_ARCHS = {
    "dense": "qwen3-0.6b",
    "vlm": "qwen2-vl-2b",
    "moe": "qwen3-moe-235b-a22b",
    "ssm": "mamba2-2.7b",
    "hybrid": "recurrentgemma-9b",
}


def make_twin_edge_server(sv=None, **kw):
    """edge-edge-cloud live server: edge/edge1 serve the SAME model
    (migration-compatible) while cloud serves another (incompatible).
    Every engine is pre-warmed (prefill bucket + the fused-decode context
    ladder) so migration timing in tests isn't compile-dominated. Shared by
    the migration and runtime-parity suites."""
    import numpy as np

    from repro.config import PolicyConfig, ServingConfig, get_topology
    from repro.core.baselines import make_policy
    from repro.core.scheduler import MoAOffScheduler
    from repro.serving.tiers import ClusterServer, build_cluster_engines

    topo = get_topology("edge-edge-cloud")
    sv = sv or ServingConfig(max_batch=2, max_seq=192)
    server = ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(
            "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)),
        **kw)
    for i, eng in enumerate(server.engines.values()):
        eng.submit(90_000 + i, (np.arange(24) % 300 + 4).astype(np.int32),
                   max_new=120)
        eng.run_until_drained()
    return server


@pytest.fixture(scope="session")
def family_model():
    """``family -> (cfg, params)`` factory with a session-wide cache, so a
    family's reduced model is built and initialized at most once per run."""
    from repro.configs import reduced_config
    from repro.models import build_model

    cache = {}

    def get(family):
        if family not in cache:
            cfg = reduced_config(FAMILY_ARCHS[family]).replace(
                dtype="float32")
            model = build_model(cfg)
            cache[family] = (cfg, model.init(jax.random.PRNGKey(0)))
        return cache[family]

    return get
