"""Property-based fuzzing of prefix/suffix split points: for ANY prompt and
ANY split, seeding the prefix store with the prefix and then serving the
full prompt must decode token-for-token like a cold full prefill, and must
prefill only the suffix. Runs the sliceable ladder (dense) and the
point-in-time state path (ssm) through the same property."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.config import ServingConfig  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serving.engine import TierEngine  # noqa: E402
from repro.serving.prefix import prefix_buckets  # noqa: E402


def _engine(cfg, params, prefix_mb=0.0):
    sv = ServingConfig(max_batch=2, max_seq=128, prefix_cache_mb=prefix_mb)
    return TierEngine(build_model(cfg), params, sv, eos_id=-1)


def _tokens(eng, rid):
    done = {s.rid: s.generated for s in eng.run_until_drained()}
    eng.finished.clear()
    return done[rid]


@pytest.mark.slow
@settings(max_examples=8, deadline=None)
@given(data=st.data())
@pytest.mark.parametrize("family", ["dense", "ssm"])
def test_any_split_point_matches_cold(family, data, family_model):
    cfg, params = family_model(family)
    total = data.draw(st.integers(min_value=20, max_value=80), label="total")
    split = data.draw(st.integers(min_value=16, max_value=total - 1),
                      label="split")
    seed = data.draw(st.integers(min_value=0, max_value=2**16), label="seed")
    rng = np.random.default_rng(seed)
    full = rng.integers(4, 200, size=total).astype(np.int32)

    cold = _engine(cfg, params)
    cold.submit(0, full, max_new=6)
    want = _tokens(cold, 0)

    warm = _engine(cfg, params, prefix_mb=64.0)
    warm.submit(0, full[:split], max_new=2)  # seeds the store
    _tokens(warm, 0)
    pf0 = warm.prefill_tokens
    warm.submit(1, full, max_new=6)
    got = _tokens(warm, 1)
    assert got == want

    # the hit covers the longest stored prefix at or below the split
    if family == "dense":
        usable = [n for n in prefix_buckets(split) if n < total]
        want_cached = max(usable) if usable else 0
    else:  # point-in-time state: exact split only (and only if it's short
        # enough to leave a suffix)
        want_cached = split if 16 <= split < total else 0
    if want_cached:
        assert warm.prefix_hits == 1
        assert warm.prefix_hit_tokens == want_cached
        assert warm.prefill_tokens - pf0 == total - want_cached
    else:
        assert warm.prefix_hits == 0
        assert warm.prefill_tokens - pf0 == total
