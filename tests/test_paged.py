"""Paged KV pool: paged serving is token-for-token identical to dense
serving at temp=0 for every family (cold, warm prefix-hit, resumed
session), admission back-pressures instead of failing when the pool runs
out of pages, CoW refcounts free pages exactly when the last reader drops,
paged slots migrate across pool designs on the unchanged wire format, and
the allocator invariants hold under property fuzzing of page size x prompt
length x admission order."""
import numpy as np
import pytest

from repro.config import ServingConfig
from repro.models import build_model
from repro.serving.engine import SlotPayload, TierEngine
from repro.serving.paged import PagePool, pages_needed

FAMILY_PARAMS = [
    "dense",
    # the heavier families ride the slow mark to keep the smoke lane fast
    pytest.param("vlm", marks=pytest.mark.slow),
    pytest.param("moe", marks=pytest.mark.slow),
    pytest.param("ssm", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
]


def make_engine(cfg, params, max_batch=2, max_seq=256, paged=False, **sv_kw):
    sv = ServingConfig(max_batch=max_batch, max_seq=max_seq, paged=paged,
                       **({"kv_page_size": 32} if paged else {}), **sv_kw)
    return TierEngine(build_model(cfg), params, sv, eos_id=-1)


def _family_inputs(cfg, base_len=40, ext_len=10, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.integers(4, 200, size=base_len).astype(np.int32)
    ext = rng.integers(4, 200, size=ext_len).astype(np.int32)
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["patches"] = rng.standard_normal(
            (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
    return base, ext, extras


def _drain(eng):
    done = {s.rid: list(s.generated) for s in eng.run_until_drained()}
    eng.finished.clear()
    return done


# ---------------------------------------------------------------------------
# allocator unit behavior (pure host logic)
# ---------------------------------------------------------------------------


def test_pages_needed_ceil_and_cap():
    assert pages_needed(0, 32, 256) == 0
    assert pages_needed(1, 32, 256) == 1
    assert pages_needed(32, 32, 256) == 1
    assert pages_needed(33, 32, 256) == 2
    assert pages_needed(10_000, 32, 256) == 8  # capped at a full sequence


def test_pool_alloc_free_refcount():
    pool = PagePool(4, 32)
    a = pool.alloc(3)
    assert pool.pages_free == 1 and pool.pages_used == 3
    assert pool.alloc(2) is None  # short: nothing handed out
    assert pool.pages_free == 1
    pool.incref(a[:2])
    assert pool.pages_shared == 2
    assert pool.decref(a) == 1  # only the unshared page frees
    assert pool.pages_free == 2
    assert pool.decref(a[:2]) == 2  # last readers drop -> pages free
    assert pool.pages_free == 4
    pool.check()


def test_pool_null_page_pinned():
    pool = PagePool(2, 32)
    pool.incref([0])
    pool.decref([0])  # both are no-ops on the null page
    assert int(pool.refcnt[0]) == 1
    assert 0 not in pool.free_list
    pool.check()


def test_pool_reown_rebuilds_from_references():
    pool = PagePool(4, 32)
    pool.alloc(4)
    pool.reown([1, 1, 3])  # page 1 shared twice, 3 once; 2 and 4 free
    assert int(pool.refcnt[1]) == 2 and int(pool.refcnt[3]) == 1
    assert sorted(pool.free_list) == [2, 4]
    pool.check()


def test_config_validation():
    with pytest.raises(ValueError):
        ServingConfig(kv_page_size=48)  # not a power of two
    with pytest.raises(ValueError):
        ServingConfig(max_seq=192, paged=True, kv_page_size=256)  # > max_seq
    with pytest.raises(ValueError):
        ServingConfig(max_seq=192, paged=True, kv_page_size=128)  # no divide
    with pytest.raises(ValueError):
        ServingConfig(max_seq=256, paged=True, kv_page_size=32,
                      kv_pool_pages=4)  # pool below one full sequence
    sv = ServingConfig(max_batch=3, max_seq=256, paged=True, kv_page_size=32)
    assert sv.pages_per_slot == 8
    assert sv.pool_pages == 24


# ---------------------------------------------------------------------------
# paged vs dense token parity (cold / warm prefix-hit / resumed session)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_paged_matches_dense_all_paths(family, family_model):
    cfg, params = family_model(family)
    base, ext, extras = _family_inputs(cfg)

    def serve(paged):
        eng = make_engine(cfg, params, paged=paged, prefix_cache_mb=64,
                          session_cache_mb=64)
        out = {}
        # cold
        eng.submit(0, base, max_new=8, extras=dict(extras), session="s")
        out.update(_drain(eng))
        # warm: extends the stored prefix / parked session
        t2 = np.concatenate([base, ext]).astype(np.int32)
        eng.submit(1, t2, max_new=8, extras=dict(extras), session="s")
        out.update(_drain(eng))
        # resumed session: extends turn 1's full conversation
        t3 = np.concatenate([t2, np.asarray(out[1][:-1], np.int32),
                             ext[:5]]).astype(np.int32)
        eng.submit(2, t3, max_new=8, extras=dict(extras), session="s")
        out.update(_drain(eng))
        return out, eng

    dense, _ = serve(paged=False)
    paged, eng = serve(paged=True)
    assert dense == paged
    assert eng.resumed_sessions >= 1
    eng.pool.check()


def test_paged_warm_hit_is_copy_free(family_model):
    """A warm prefix hit maps the store's full pages CoW-shared (refcount >
    1) instead of duplicating rows."""
    cfg, params = family_model("dense")
    base, ext, _ = _family_inputs(cfg, base_len=64)
    eng = make_engine(cfg, params, paged=True, prefix_cache_mb=64)
    eng.submit(0, base, max_new=4)
    _drain(eng)
    assert eng.pool.pages_shared > 0  # store deposit shares the slot's pages
    eng.submit(1, np.concatenate([base, ext]).astype(np.int32), max_new=4)
    eng.step()
    assert eng.prefix_hits == 1
    # the hit's full pages are mapped by BOTH the store and the live slot
    assert eng.pool.pages_shared >= 64 // 32
    _drain(eng)
    eng.pool.check()


# ---------------------------------------------------------------------------
# back-pressure & continuous admission
# ---------------------------------------------------------------------------


def test_page_exhaustion_backpressures(family_model):
    """With pages for ~one full sequence, six concurrent long requests must
    all finish (admissions defer, never fail) with dense-identical tokens,
    and every page must return to the free list."""
    cfg, params = family_model("dense")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(4, 200, size=30).astype(np.int32)
               for _ in range(6)]

    def serve(sv):
        eng = TierEngine(build_model(cfg), params, sv, eos_id=-1)
        for r, p in enumerate(prompts):
            eng.submit(r, p, max_new=100)
        return _drain(eng), eng

    dense, _ = serve(ServingConfig(max_batch=4, max_seq=256))
    tight = ServingConfig(max_batch=4, max_seq=256, paged=True,
                          kv_page_size=32, kv_pool_pages=8)
    paged, eng = serve(tight)
    assert dense == paged
    assert eng.pool.pages_free == eng.pool.num_pages  # all pages returned
    eng.pool.check()


def test_store_pages_reclaimed_under_pressure(family_model):
    """Prefix-store pages are spare capacity: a reservation that cannot be
    served from the free list evicts store entries rather than starving."""
    cfg, params = family_model("dense")
    rng = np.random.default_rng(1)
    sv = ServingConfig(max_batch=2, max_seq=256, paged=True, kv_page_size=32,
                       kv_pool_pages=8, prefix_cache_mb=64)
    eng = TierEngine(build_model(cfg), params, sv, eos_id=-1)
    eng.submit(0, rng.integers(4, 200, size=40).astype(np.int32), max_new=4)
    _drain(eng)
    held = eng.pool.num_pages - eng.pool.pages_free
    assert held > 0  # the store holds pages after the request finished
    # a request needing more than the free list forces store eviction
    eng.submit(1, rng.integers(4, 200, size=60).astype(np.int32), max_new=150)
    out = _drain(eng)
    assert 1 in out
    assert eng.prefix_store.evictions > 0
    eng.pool.check()


def test_refcount_frees_on_last_reader(family_model):
    """Pages shared between a finished depositor, the store, and a warm
    reader free exactly when the LAST reference drops."""
    cfg, params = family_model("dense")
    base, ext, _ = _family_inputs(cfg, base_len=64)
    eng = make_engine(cfg, params, paged=True, prefix_cache_mb=64)
    eng.submit(0, base, max_new=4)
    _drain(eng)
    eng.submit(1, np.concatenate([base, ext]).astype(np.int32), max_new=4)
    eng.step()  # admitted: slot + store both reference the shared pages
    shared_before = eng.pool.pages_shared
    assert shared_before > 0
    _drain(eng)  # reader finished -> its references dropped
    # store still holds its entries; drain it and every page must free
    while eng.prefix_store.evict_oldest() is not None:
        pass
    assert eng.pool.pages_free == eng.pool.num_pages
    eng.pool.check()


# ---------------------------------------------------------------------------
# migration & snapshot round-trips across pool designs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("src_paged,dst_paged",
                         [(True, True), (True, False), (False, True)])
def test_migration_roundtrip_across_pool_designs(src_paged, dst_paged,
                                                 family_model):
    cfg, params = family_model("dense")
    base, _, _ = _family_inputs(cfg, base_len=25)

    def decode_after_move(a_paged, b_paged):
        src = make_engine(cfg, params, paged=a_paged)
        src.submit(7, base, max_new=40)
        src.step()
        wire = src.extract_slot(7, remove=True).to_bytes()
        if a_paged:
            src.pool.check()
        dst = make_engine(cfg, params, paged=b_paged)
        dst.inject_slot(SlotPayload.from_bytes(wire))
        out = _drain(dst)
        if b_paged:
            dst.pool.check()
        return out[7]

    moved = decode_after_move(src_paged, dst_paged)
    ref = decode_after_move(False, False)
    assert moved == ref


def test_paged_snapshot_restore_midflight(family_model):
    cfg, params = family_model("dense")
    rng = np.random.default_rng(2)
    sv = ServingConfig(max_batch=4, max_seq=256, paged=True, kv_page_size=32,
                       kv_pool_pages=8)
    eng = TierEngine(build_model(cfg), params, sv, eos_id=-1)
    for r in range(3):
        eng.submit(r, rng.integers(4, 200, size=20 + r).astype(np.int32),
                   max_new=30)
    eng.step()
    eng.step()
    snap = eng.snapshot()
    ref = _drain(eng)
    eng2 = TierEngine(build_model(cfg), params, sv, eos_id=-1)
    eng2.restore(snap)
    eng2.pool.check()
    assert _drain(eng2) == ref
    eng2.pool.check()


def test_inject_rejects_when_pool_exhausted(family_model):
    from repro.serving.engine import MigrationError
    cfg, params = family_model("dense")
    base, _, _ = _family_inputs(cfg, base_len=30)
    src = make_engine(cfg, params, paged=False)
    src.submit(1, base, max_new=200)
    src.step()
    payload = src.extract_slot(1, remove=True)
    dst = TierEngine(build_model(cfg), params,
                     ServingConfig(max_batch=4, max_seq=256, paged=True,
                                   kv_page_size=32, kv_pool_pages=8),
                     eos_id=-1)
    dst.submit(2, base, max_new=190)
    dst.step()  # the resident request reserved the whole pool
    with pytest.raises(MigrationError):
        dst.inject_slot(payload)
    assert all(s is None or s.rid == 2 for s in dst.slots)
    dst.pool.check()


# ---------------------------------------------------------------------------
# scheduler gauges
# ---------------------------------------------------------------------------


def test_kv_gauges_and_headroom(family_model):
    cfg, params = family_model("dense")
    base, _, _ = _family_inputs(cfg, base_len=30)
    eng = make_engine(cfg, params, paged=True)
    assert eng.kv_headroom() == 1.0
    eng.submit(0, base, max_new=60)
    eng.step()
    g = eng.kv_gauges()
    assert g["pages_free"] < g["pages_total"]
    assert 0.0 <= eng.kv_headroom() < 1.0
    assert g["pages_high_water"] > 0 and g["page_bytes"] > 0
    # dense engines synthesize slot-granular numbers from the same API
    d = make_engine(cfg, params, paged=False)
    assert d.kv_headroom() == 1.0
    d.submit(0, base, max_new=64)
    d.step()  # still mid-decode: one of two slots occupied
    assert d.kv_headroom() < 1.0


def test_runtime_observes_kv_headroom(family_model):
    """The live cluster runtime feeds per-tier KV headroom into the
    scheduler's SystemState."""
    from repro.config import PolicyConfig, ServingConfig, get_topology
    from repro.core.baselines import make_policy
    from repro.core.scheduler import MoAOffScheduler
    from repro.serving.tiers import ClusterServer, build_cluster_engines

    topo = get_topology("edge-cloud")
    sv = ServingConfig(max_batch=2, max_seq=192, paged=True, kv_page_size=32)
    server = ClusterServer(
        build_cluster_engines(topo, sv), topology=topo,
        scheduler=MoAOffScheduler(policy=make_policy(
            "moa-off", PolicyConfig(adaptive_tau=False), topology=topo)))
    server.submit("tell me about paging " * 3, max_new=4)
    server.run()
    st = server.scheduler.estimator.state
    assert set(st.kv_headroom) == set(topo.names)
    for h in st.kv_headroom.values():
        assert 0.0 <= h <= 1.0


# ---------------------------------------------------------------------------
# property fuzzing: page size x prompt length x admission order
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_fuzz_page_size_prompt_length_admission_order(family_model):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    cfg, params = family_model("dense")
    model = build_model(cfg)

    @settings(max_examples=6, deadline=None)
    @given(data=st.data())
    def prop(data):
        page = data.draw(st.sampled_from([8, 16, 32, 64]), label="page")
        pool_pages = data.draw(
            st.integers(min_value=128 // page, max_value=4 * 128 // page),
            label="pool_pages")
        lens = data.draw(st.lists(
            st.integers(min_value=4, max_value=60), min_size=1, max_size=5),
            label="prompt_lens")
        order = data.draw(st.permutations(range(len(lens))), label="order")
        rng = np.random.default_rng(data.draw(
            st.integers(min_value=0, max_value=2**16), label="seed"))
        prompts = [rng.integers(4, 200, size=n).astype(np.int32)
                   for n in lens]

        def serve(sv):
            eng = TierEngine(model, params, sv, eos_id=-1)
            for r in order:
                eng.submit(r, prompts[r], max_new=10)
            return _drain(eng)

        dense = serve(ServingConfig(max_batch=2, max_seq=128))
        sv = ServingConfig(max_batch=2, max_seq=128, paged=True,
                           kv_page_size=page, kv_pool_pages=pool_pages,
                           prefix_cache_mb=8)
        eng = TierEngine(model, params, sv, eos_id=-1)
        for r in order:
            eng.submit(r, prompts[r], max_new=10)
        paged = _drain(eng)
        assert dense == paged
        eng.pool.check()
        while eng.prefix_store.evict_oldest() is not None:
            pass
        assert eng.pool.pages_free == eng.pool.num_pages
        eng.pool.check()

    prop()
