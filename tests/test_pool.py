"""Replicated engine pools: transport wire framing, deterministic
least-loaded balancing, replica affinity (parked sessions + cached
prefixes), intra-tier slot re-homing, replica-granular fault recovery
through the live server, and process-transport parity."""
import pickle
import time

import numpy as np
import pytest

from repro.config import ServingConfig, two_tier_topology
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.pool import EnginePool, build_engine_pools
from repro.serving.prefix import extras_fingerprint
from repro.serving.tiers import ClusterServer
from repro.serving.transport import (TRANSPORT_WIRE_VERSION, LocalTransport,
                                     ProcessTransport, ReplicaSpec,
                                     TransportError, msg_from_bytes,
                                     msg_to_bytes)
from tests.conftest import FAMILY_ARCHS

NO_EXTRAS = extras_fingerprint({})


# ---------------------------------------------------------------------------
# wire framing


def test_wire_roundtrip():
    for kind, payload in [("submit", {"rid": 3, "tokens": [1, 2, 3]}),
                          ("stats", None),
                          ("fin", (7, list(range(40)), 12.5))]:
        k, p = msg_from_bytes(msg_to_bytes(kind, payload))
        assert (k, p) == (kind, payload)


def test_wire_rejects_bad_frames():
    bad = [
        pickle.dumps((TRANSPORT_WIRE_VERSION + 1, "submit", None)),  # version
        pickle.dumps("not a tuple"),
        pickle.dumps((TRANSPORT_WIRE_VERSION, "submit")),  # arity
        pickle.dumps((TRANSPORT_WIRE_VERSION, 42, None)),  # non-str kind
        msg_to_bytes("submit", {"rid": 1})[:5],  # truncated
        b"",
    ]
    for raw in bad:
        with pytest.raises(TransportError):
            msg_from_bytes(raw)


# ---------------------------------------------------------------------------
# replica selection (duck-typed fakes: selection logic only)


class FakeReplica:
    kind = "fake"
    supports_restore = True

    def __init__(self, occ=0, kv=1.0, hit=0, sessions=()):
        self.alive = True
        self._occ, self._kv, self._hit = occ, kv, hit
        self._sessions = set(sessions)

    def occupancy(self):
        return self._occ

    def kv_headroom(self):
        return self._kv

    def free_slots(self):
        return max(0, 2 - self._occ)

    def total_slots(self):
        return 2

    def prefix_hit_len(self, tokens, fp):
        return self._hit

    def has_session(self, sid):
        return sid in self._sessions


TOKENS = np.arange(24, dtype=np.int32)


def test_choose_least_loaded_deterministic_tie_break():
    pool = EnginePool("edge", [FakeReplica(), FakeReplica()])
    assert pool.choose(TOKENS, NO_EXTRAS) == 0  # tie -> lowest index
    pool = EnginePool("edge", [FakeReplica(occ=2), FakeReplica(occ=1)])
    assert pool.choose(TOKENS, NO_EXTRAS) == 1
    # occupancy tie: more KV headroom wins
    pool = EnginePool("edge", [FakeReplica(occ=1, kv=0.2),
                               FakeReplica(occ=1, kv=0.9)])
    assert pool.choose(TOKENS, NO_EXTRAS) == 1


def test_choose_single_replica_is_pass_through():
    # a 1-replica pool short-circuits (no prefix probe, no rng anywhere)
    assert EnginePool("edge", [FakeReplica(occ=5)]).choose(
        TOKENS, NO_EXTRAS) == 0


def test_choose_raises_when_no_live_replica():
    r0, r1 = FakeReplica(), FakeReplica()
    r0.alive = r1.alive = False
    with pytest.raises(TransportError):
        EnginePool("edge", [r0, r1]).choose(TOKENS, NO_EXTRAS)


def test_choose_prefers_session_home_over_load():
    # replica 1 is busier AND holds the parked session: affinity wins
    pool = EnginePool("edge", [FakeReplica(occ=0),
                               FakeReplica(occ=2, sessions={"s"})])
    assert pool.choose(TOKENS, NO_EXTRAS, session="s") == 1
    # no parked home anywhere: falls through to least-loaded
    assert pool.choose(TOKENS, NO_EXTRAS, session="zzz") == 0


def test_choose_prefers_longest_prefix_over_load():
    pool = EnginePool("edge", [FakeReplica(occ=0, hit=0),
                               FakeReplica(occ=2, hit=16)])
    assert pool.choose(TOKENS, NO_EXTRAS) == 1
    # equal hits resolve by load key
    pool = EnginePool("edge", [FakeReplica(occ=2, hit=16),
                               FakeReplica(occ=0, hit=16)])
    assert pool.choose(TOKENS, NO_EXTRAS) == 1


# ---------------------------------------------------------------------------
# live replicas: token identity, affinity and re-homing on real engines


def _local_pool(cfg, params, n=2, sv=None):
    sv = sv or ServingConfig(max_batch=2, max_seq=96)
    model = build_model(cfg)
    return EnginePool("edge", [LocalTransport(TierEngine(model, params, sv))
                               for _ in range(n)])


def _drain(pool, timeout_s=600.0):
    # wall-clock bounded: process replicas compile in their worker for
    # tens of seconds before the first token arrives
    fins = []
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        f, active, lost = pool.poll()
        fins.extend(f)
        assert not lost
        if not active and not f:
            return {s.rid: s.generated for s in fins}
        if not f:
            time.sleep(0.002)
    raise AssertionError("pool did not drain")


def _prompt(n, base=0):
    return ((np.arange(n) + base) % 300 + 4).astype(np.int32)


def test_move_slot_midstream_token_identity(family_model):
    cfg, params = family_model("dense")
    sv = ServingConfig(max_batch=2, max_seq=96)
    ref = TierEngine(build_model(cfg), params, sv)
    ref.submit(0, _prompt(12), max_new=24)
    want = {s.rid: s.generated for s in ref.run_until_drained()}

    pool = _local_pool(cfg, params, sv=sv)
    pool.submit_to(0, 0, _prompt(12), max_new=24, extras={}, deadline=None,
                   session=None)
    for _ in range(2):  # prefill + a couple of decode blocks on replica 0
        pool.poll()
    assert pool.replica_of(0) == 0
    dst = pool.move_slot(0, 0)
    assert dst == 1 and pool.replica_of(0) == 1
    got = _drain(pool)
    assert got[0] == want[0]
    # the receiving replica continued from shipped KV rows: no re-prefill
    assert pool.transports[1].counters()["prefill_tokens"] == 0
    # unknown rid: nothing to move, nothing lost
    assert pool.move_slot(999, 0) is None


def test_move_slot_without_capacity_leaves_slot_in_place(family_model):
    cfg, params = family_model("dense")
    sv = ServingConfig(max_batch=1, max_seq=96)
    pool = _local_pool(cfg, params, sv=sv)
    for rid in (0, 1):
        pool.submit_to(rid, rid, _prompt(8 + rid), max_new=16, extras={},
                       deadline=None, session=None)
    pool.poll()
    # the sibling's only slot is occupied: no destination, slot stays home
    assert pool.move_slot(0, 0) is None
    assert pool.replica_of(0) == 0
    got = _drain(pool)
    assert set(got) == {0, 1}


@pytest.mark.parametrize("family", [
    "dense",
    pytest.param("vlm", marks=pytest.mark.slow),
    pytest.param("moe", marks=pytest.mark.slow),
    pytest.param("ssm", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
])
def test_replicated_serving_token_identical_to_single_engine(
        family_model, family):
    """Cold, warm-prefix-hit and resumed-session decoding through a
    2-replica pool is token-identical to the single-engine path, with the
    warm submissions landing on the replica that holds the cached state
    (affinity beating the least-loaded tie-break)."""
    cfg, params = family_model(family)
    sv = ServingConfig(max_batch=2, max_seq=96, prefix_cache_mb=64,
                       session_cache_mb=64, prefix_min_tokens=16)
    base = _prompt(32)
    ext = np.concatenate([base, _prompt(6, base=100)])
    base2 = _prompt(24, base=7)

    def turns(submit, drain):
        submit(0, base, None)
        out = drain()  # deposit the base prefix before extending it
        submit(1, ext, None)
        submit(2, base2, "s")
        out.update(drain())
        turn2 = np.concatenate(
            [base2, np.asarray(out[2], np.int32), _prompt(5, base=200)])
        submit(3, turn2, "s")
        out.update(drain())
        return out

    ref = TierEngine(build_model(cfg), params, sv)

    def ref_submit(rid, toks, session):
        ref.submit(rid, toks, max_new=8, session=session)

    def ref_drain():
        return {s.rid: s.generated for s in ref.run_until_drained()}

    want = turns(ref_submit, ref_drain)
    assert ref.prefix_hits >= 1 and ref.resumed_sessions == 1

    # pool twin: warm state is FORCED onto replica 1, so the tie-break
    # (which favors replica 0) would miss it — affinity must route there
    pool = _local_pool(cfg, params, sv=sv)

    def pool_submit(rid, toks, session):
        if rid in (0, 2):
            r = 1  # pin the cold deposits away from the tie-break pick
        else:
            r = pool.choose(toks, NO_EXTRAS, session=session)
            assert r == 1, f"warm rid {rid} routed off its cached replica"
        pool.submit_to(r, rid, toks, max_new=8, extras={}, deadline=None,
                       session=session)

    got = turns(pool_submit, lambda: _drain(pool))
    assert got == want
    warm = pool.transports[1].counters()
    assert warm["prefix_hits"] >= 1 and warm["resumed_sessions"] == 1
    assert pool.transports[0].counters()["resumed_sessions"] == 0
    assert pool.counters()["resumed_sessions"] == 1


# ---------------------------------------------------------------------------
# replica-granular fault handling through the live server


def test_live_replicated_fault_recovery_terminal_failures():
    # a permanently dead edge tier with TWO replicas: every attempt
    # faults, each crashed replica restores from ITS snapshot, and spent
    # retry budgets resolve into terminal failures (no livelock)
    sv = ServingConfig(max_batch=2, max_seq=64, heartbeat_timeout_s=0.0)
    topo = two_tier_topology()
    pools = build_engine_pools(topo, sv, replicas={"edge": 2, "cloud": 1})
    assert len(pools["edge"]) == 2
    srv = ClusterServer(pools, topology=topo, fail_rate=1.0)
    for i in range(2):
        srv.submit(f"hello there {i}", max_new=4, complexity={"text": 0.05})
    res = srv.run(timeout_s=60.0)
    assert len(res) == 2
    assert srv.backend.restores >= 1
    for r in res:
        assert r.failed and r.fail_reason == "retries"
        assert r.retries == sv.retry_limit


def test_chaos_requires_restorable_transports():
    sv = ServingConfig(max_batch=2, max_seq=64)
    topo = two_tier_topology()
    pools = build_engine_pools(topo, sv, replicas={"edge": 2, "cloud": 1})
    # one non-restorable replica poisons the tier for chaos injection
    pools["edge"].transports[0].supports_restore = False
    assert not pools["edge"].supports_restore
    with pytest.raises(ValueError, match="snapshot/restore"):
        ClusterServer(pools, topology=topo, fail_rate=0.5)
    # without chaos the same pools serve fine
    srv = ClusterServer(pools, topology=topo)
    srv.submit("hello", max_new=4, complexity={"text": 0.05})
    assert len(srv.run(timeout_s=60.0)) == 1


def test_build_engine_pools_serving_overrides():
    sv = ServingConfig(max_batch=4, max_seq=64)
    sv_edge = ServingConfig(max_batch=1, max_seq=64)
    pools = build_engine_pools(two_tier_topology(), sv,
                               serving_overrides={"edge": sv_edge})
    assert pools["edge"].serving.max_batch == 1
    assert pools["cloud"].serving.max_batch == 4
    with pytest.raises(ValueError, match="transport"):
        build_engine_pools(two_tier_topology(), sv, transport="carrier-pigeon")


# ---------------------------------------------------------------------------
# process transport (spawned workers: slow lane)


@pytest.mark.slow
def test_process_transport_parity_with_local_engine(family_model):
    cfg, params = family_model("dense")
    sv = ServingConfig(max_batch=2, max_seq=96)
    ref = TierEngine(build_model(cfg), params, sv)
    jobs = [(0, _prompt(12), 20), (1, _prompt(18, base=3), 16)]
    for rid, toks, max_new in jobs:
        ref.submit(rid, toks, max_new=max_new)
    want = {s.rid: s.generated for s in ref.run_until_drained()}

    tr = ProcessTransport(ReplicaSpec(model=FAMILY_ARCHS["dense"],
                                      serving=sv, param_seed=0,
                                      name="edge/0"))
    try:
        pool = EnginePool("edge", [tr])
        for rid, toks, max_new in jobs:
            pool.submit_to(0, rid, toks, max_new=max_new, extras={},
                           deadline=None, session=None)
        got = _drain(pool)
    finally:
        tr.close()
    assert got == want


@pytest.mark.slow
def test_worker_crash_reports_lost_rids_and_sibling_rescues(family_model):
    cfg, params = family_model("dense")
    sv = ServingConfig(max_batch=2, max_seq=96)
    ref = TierEngine(build_model(cfg), params, sv)
    ref.submit(0, _prompt(10), max_new=12)
    want = {s.rid: s.generated for s in ref.run_until_drained()}

    proc = ProcessTransport(ReplicaSpec(model=FAMILY_ARCHS["dense"],
                                        serving=sv, param_seed=0,
                                        name="edge/0"))
    sibling = LocalTransport(TierEngine(build_model(cfg), params, sv))
    pool = EnginePool("edge", [proc, sibling])
    try:
        pool.submit_to(0, 0, _prompt(10), max_new=12, extras={},
                       deadline=None, session=None)
        proc._proc.kill()
        lost = []
        for _ in range(2_000):
            _, _, l = pool.poll()
            lost.extend(l)
            if lost:
                break
        assert lost == [0]
        assert not proc.alive and pool.n_alive == 1
        # cold resubmit on the survivor completes with identical tokens
        pool.submit_to(1, 0, _prompt(10), max_new=12, extras={},
                       deadline=None, session=None)
        got = _drain(pool)
    finally:
        pool.close()
    assert got == want
