"""Fused-engine hot-path tests: token-for-token parity between the
device-resident K-step path (``fused_steps=8``: on-device sampling, donated
cache, bucketed prefill, context buckets, unrolled decode layers) and the
legacy per-token path (``fused_steps=1``), plus the kernel dispatch knobs."""
import jax
import numpy as np
import pytest

from repro.config import ServingConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.serving.engine import TierEngine


@pytest.fixture(scope="module")
def dense_setup():
    cfg = reduced_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    return cfg, model.init(jax.random.PRNGKey(0))


def _engine(cfg, params, fused, eos=2, max_batch=3, max_seq=64, **kw):
    sv = ServingConfig(max_batch=max_batch, max_seq=max_seq,
                       fused_steps=fused, **kw)
    return TierEngine(build_model(cfg), params, sv, eos_id=eos)


def _drain(eng, jobs):
    for rid, toks, max_new, extras in jobs:
        eng.submit(rid, toks, max_new=max_new, extras=extras)
    done = eng.run_until_drained()
    return {s.rid: s.generated for s in done}


def _jobs(n=7, extras=None):
    """More requests than slots -> exercises slot refill mid-stream."""
    return [(rid, (np.arange(4 + 3 * rid) % 300 + 4).astype(np.int32),
             5 + rid % 4, dict(extras or {})) for rid in range(n)]


def test_fused_token_parity_with_refill(dense_setup):
    cfg, params = dense_setup
    legacy = _drain(_engine(cfg, params, 1), _jobs())
    fused = _drain(_engine(cfg, params, 8), _jobs())
    assert sorted(legacy) == sorted(fused) == list(range(7))
    for rid in legacy:
        assert legacy[rid] == fused[rid], rid


def test_fused_token_parity_midstream_eos(dense_setup):
    """Pick a token the model actually emits mid-stream and make it EOS:
    both paths must truncate at the same point with identical tokens."""
    cfg, params = dense_setup
    probe = _drain(_engine(cfg, params, 1), [(0, np.asarray(
        [4, 5, 6], np.int32), 12, {})])
    assert len(probe[0]) == 12  # default eos never fired
    eos = probe[0][3]  # mid-stream token -> becomes EOS below
    legacy = _drain(_engine(cfg, params, 1, eos=eos), _jobs())
    fused = _drain(_engine(cfg, params, 8, eos=eos), _jobs())
    for rid in legacy:
        assert legacy[rid] == fused[rid], rid
    stopped = [r for r, t in fused.items() if t and t[-1] == eos]
    assert stopped, "EOS never fired mid-stream; probe token choice broken"


def test_fused_parity_vlm_bucketed_extras():
    """VLM engine: padded-bucket prefill with batched patch extras."""
    cfg = reduced_config("qwen2-vl-2b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    patches = [rng.standard_normal(
        (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        for _ in range(5)]
    jobs = [(rid, (np.arange(3 + 2 * rid) % 300 + 4).astype(np.int32), 6,
             {"patches": patches[rid]} if rid % 2 == 0 else {})
            for rid in range(5)]
    legacy = _drain(_engine(cfg, params, 1, max_seq=96), list(jobs))
    fused = _drain(_engine(cfg, params, 8, max_seq=96), list(jobs))
    for rid in legacy:
        assert legacy[rid] == fused[rid], rid


def test_fused_snapshot_restore_roundtrip(dense_setup):
    """Standby restores a fused engine mid-flight; the finished tokens must
    equal an uninterrupted fused run (temp=0 determinism incl. key state)."""
    cfg, params = dense_setup
    jobs = [(rid, np.asarray([4, 5, 6, 7], np.int32), 9, {})
            for rid in range(4)]
    ref = _drain(_engine(cfg, params, 4, max_batch=2), list(jobs))

    eng = _engine(cfg, params, 4, max_batch=2)
    for rid, toks, max_new, ex in jobs:
        eng.submit(rid, toks, max_new=max_new, extras=ex)
    eng.step()
    snap = eng.snapshot()
    survivors = ({s.rid for s in eng.slots if s}
                 | {w["rid"] for w in eng.waiting})
    standby = _engine(cfg, params, 4, max_batch=2)
    standby.restore(snap)
    done = {s.rid: s.generated for s in standby.run_until_drained()}
    assert survivors <= set(done)
    for rid, toks in done.items():
        assert ref[rid] == toks, rid


def test_snapshot_isolated_from_live_engine(dense_setup):
    """A snapshot must not alias live SeqState token lists: stepping the
    source engine after snapshotting may not mutate the snapshot."""
    cfg, params = dense_setup
    eng = _engine(cfg, params, 8, max_batch=2)
    for rid in range(2):
        eng.submit(rid, np.asarray([4, 5, 6], np.int32), max_new=20)
    eng.step()
    snap = eng.snapshot()
    before = [list(s.generated) for s in snap["slots"] if s]
    eng.step()  # source keeps generating post-snapshot
    after = [list(s.generated) for s in snap["slots"] if s]
    assert before == after


def test_fused_temperature_sampling_drains(dense_setup):
    """temp>0: on-device categorical sampling with per-slot keys finishes
    every request and stays inside the vocab."""
    cfg, params = dense_setup
    sv = ServingConfig(max_batch=3, max_seq=64, fused_steps=8)
    eng = TierEngine(build_model(cfg), params, sv, sample_temp=0.8, seed=3)
    for rid in range(5):
        eng.submit(rid, np.asarray([4, 5, 6], np.int32), max_new=6)
    done = eng.run_until_drained()
    assert sorted(s.rid for s in done) == list(range(5))
    for s in done:
        assert 1 <= len(s.generated) <= 6
        assert all(0 <= t < cfg.vocab_size for t in s.generated)


def test_fused_max_new_one_single_token(dense_setup):
    """max_new=1 finishes at admit with exactly one token on both paths."""
    cfg, params = dense_setup
    for fused in (1, 8):
        done = _drain(_engine(cfg, params, fused),
                      [(0, np.asarray([4, 5], np.int32), 1, {})])
        assert len(done[0]) == 1


def test_decode_impl_pallas_matches_xla(dense_setup):
    """Forcing the Pallas decode kernel (interpret on CPU) reproduces the
    XLA path token-for-token."""
    cfg, params = dense_setup
    jobs = [(rid, np.asarray([4, 5, 6], np.int32), 4, {}) for rid in range(2)]
    xla = _drain(_engine(cfg, params, 4, max_batch=2, max_seq=32,
                         decode_impl="xla"), list(jobs))
    pallas = _drain(_engine(cfg, params, 4, max_batch=2, max_seq=32,
                            decode_impl="pallas"), list(jobs))
    for rid in xla:
        assert xla[rid] == pallas[rid], rid


def test_fused_journal_and_counters(dense_setup):
    cfg, params = dense_setup
    eng = _engine(cfg, params, 8)
    done = _drain(eng, _jobs(4))
    ops = [op for op, _ in eng.journal]
    assert ops.count("admit") == ops.count("finish") == 4
    assert eng.decode_tokens == sum(len(t) for t in done.values())
    assert eng.prefill_tokens == sum(4 + 3 * rid for rid in range(4))
