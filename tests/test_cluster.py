"""Multi-tier cluster runtime tests: N-tier simulator routing invariants,
two-tier backward compatibility, the live ClusterServer, and regressions for
the simulator accounting/hedging fixes."""
import jax
import numpy as np
import pytest

from repro.config import (ClusterTopology, PolicyConfig, ServingConfig,
                          SimConfig, TierSpec, get_topology,
                          two_tier_topology)
from repro.configs import reduced_config
from repro.core import SystemState, make_policy
from repro.core.request import Job, RequestRecord
from repro.data.synthetic import RequestGenerator
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.simulator import ClusterSimulator, EdgeCloudSimulator
from repro.serving.tiers import ClusterServer


def _run_topology_sim(topology, policy="moa-off", n=150, rate=4.0, seed=0,
                      **kw):
    sim = ClusterSimulator(SimConfig(seed=seed), policy_name=policy,
                           topology=topology, **kw)
    for r in RequestGenerator(seed=seed, arrival_rate=rate).generate(n):
        sim.submit(r)
    sim.run()
    return sim


# ---------------------------------------------------------------------------
# topology plumbing
# ---------------------------------------------------------------------------


def test_topology_helpers():
    topo = get_topology("edge-regional-cloud")
    assert topo.names == ("edge", "regional", "cloud")
    assert [t.name for t in topo.local_tiers] == ["edge"]
    assert {t.name for t in topo.remote_tiers} == {"regional", "cloud"}
    assert topo.default_local.name == "edge"
    assert topo.default_remote.name == "cloud"  # max capability remote
    # fusion: most capable routed tier; all-local stays local
    assert topo.fusion_tier({"image": "cloud", "text": "edge"}) == "cloud"
    assert topo.fusion_tier({"image": "regional", "text": "edge"}) == "regional"
    assert topo.fusion_tier({"image": "edge", "text": "edge"}) == "edge"


def test_topology_rejects_duplicate_tier_names():
    t = TierSpec("edge", "qwen2-vl-2b", 1, 1e12, 1e9)
    with pytest.raises(ValueError):
        ClusterTopology("bad", (t, t))


def test_policy_multi_tier_splits_by_complexity():
    topo = get_topology("edge-regional-cloud")
    pol = make_policy("moa-off", PolicyConfig(adaptive_tau=False),
                      topology=topo)
    state = SystemState(edge_load=0.1, bandwidth_bps=3e8)
    from repro.core.request import Request

    req = Request(rid=0, arrival_s=0.0, modalities={})
    d = pol.decide(req, {"image": 0.95, "text": 0.05}, state)
    assert d.routes["text"] == "edge"  # easy stays local
    assert d.routes["image"] == "cloud"  # beyond the regional's capability
    d2 = pol.decide(req, {"image": 0.7}, state)
    # mid complexity: offloaded, but the regional tier is eligible
    assert d2.routes["image"] in ("regional", "cloud")


# ---------------------------------------------------------------------------
# N-tier simulator invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["edge-edge-cloud", "edge-regional-cloud"])
def test_three_tier_sim_routing_invariants(name):
    topo = get_topology(name)
    sim = _run_topology_sim(topo, n=150, rate=4.0)
    assert len(sim.outcomes) == 150
    declared = set(topo.names)
    for o in sim.outcomes:
        assert set(o.routes.values()) <= declared  # every modality routed
        assert o.served_tier in declared
    m = sim.metrics()
    # per-tier metrics sum to the totals
    assert sum(m[f"{t}_flops"] for t in topo.names) == pytest.approx(
        m["total_flops"])
    assert sum(m[f"{t}_mem_byte_s"] for t in topo.names) == pytest.approx(
        m["total_mem_byte_s"])
    # outcome-level attribution matches the aggregate
    assert sum(v for o in sim.outcomes for v in o.tier_flops.values()) == \
        pytest.approx(m["total_flops"])


def test_three_tier_uses_more_than_two_tiers():
    sim = _run_topology_sim(get_topology("edge-regional-cloud"),
                            n=250, rate=3.0)
    served = {o.served_tier for o in sim.outcomes}
    assert len(served) >= 3  # the middle tier genuinely takes traffic


def test_all_policies_run_on_three_tiers():
    topo = get_topology("edge-edge-cloud")
    for pol in ("moa-off", "cloud-only", "edge-only", "perllm",
                "moa-off-no-modality", "moa-off-no-collab"):
        sim = _run_topology_sim(topo, policy=pol, n=40, rate=2.0)
        assert len(sim.outcomes) == 40, pol
    # baselines anchor on the declared tiers
    sim_c = _run_topology_sim(topo, policy="cloud-only", n=20, rate=2.0)
    assert {o.served_tier for o in sim_c.outcomes} == {"cloud"}
    sim_e = _run_topology_sim(topo, policy="edge-only", n=20, rate=2.0)
    assert {o.served_tier for o in sim_e.outcomes} == {"edge"}


# ---------------------------------------------------------------------------
# two-tier backward compatibility
# ---------------------------------------------------------------------------

LEGACY_METRIC_KEYS = {
    "accuracy", "mean_latency_s", "p50_latency_s", "p95_latency_s",
    "p99_latency_s", "edge_flops", "cloud_flops", "total_flops",
    "edge_mem_byte_s", "cloud_mem_byte_s", "total_mem_byte_s",
    "edge_util", "cloud_util", "frac_edge", "hedged", "retries",
}


def test_two_tier_default_keeps_metric_keys_and_routes():
    sim = EdgeCloudSimulator(SimConfig(bandwidth_bps=300e6, seed=0),
                             policy_name="moa-off",
                             cloud_servers=1, edge_servers=1)
    for r in RequestGenerator(seed=0, arrival_rate=2.0).generate(100):
        sim.submit(r)
    sim.run()
    m = sim.metrics()
    assert LEGACY_METRIC_KEYS <= set(m)
    assert sim.topology.names == ("edge", "cloud")
    for o in sim.outcomes:
        assert set(o.routes.values()) <= {"edge", "cloud"}
        # legacy Outcome scalars still read through to the tier dicts
        assert o.edge_flops + o.cloud_flops == pytest.approx(
            sum(o.tier_flops.values()))
    assert m["edge_flops"] + m["cloud_flops"] == pytest.approx(
        m["total_flops"])
    assert 0.0 < m["frac_edge"] < 1.0


def test_two_tier_decisions_match_literal_eq5():
    """On the default topology the N-tier policy must reduce to Eq. 5."""
    from repro.core.policy import OffloadingPolicy, decide_modality
    from repro.core.request import Request

    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=False))
    req = Request(rid=0, arrival_s=0.0, modalities={})
    rng = np.random.default_rng(0)
    for _ in range(200):
        state = SystemState(edge_load=rng.uniform(0, 1),
                            bandwidth_bps=rng.uniform(1e6, 1e9),
                            cloud_load=rng.uniform(0, 1))
        c = float(rng.uniform(0, 1))
        d = pol.decide(req, {"image": c}, state)
        assert d.routes["image"] == decide_modality(
            c, pol.taus["image"], state, pol.cfg)


# ---------------------------------------------------------------------------
# accounting + hedging regressions (simulator fixes)
# ---------------------------------------------------------------------------


def test_service_request_is_side_effect_free():
    sim = EdgeCloudSimulator(SimConfig(seed=0), cloud_servers=1,
                             edge_servers=1)
    req = RequestGenerator(seed=3, arrival_rate=1.0).generate(1)[0]
    decision = sim.scheduler.route(req)
    job = Job(request=req, decision=decision, fusion="cloud", tier="cloud",
              t_start=0.0, record=RequestRecord(rid=req.rid))
    before = {n: (st.flops, st.mem_byte_s) for n, st in sim.stations.items()}
    a = sim.backend._service_request(job)
    b = sim.backend._service_request(job)
    assert a == b  # deterministic
    after = {n: (st.flops, st.mem_byte_s) for n, st in sim.stations.items()}
    assert before == after  # no accounting side effects


def test_encode_charges_applied_once():
    """Partial-offload encode work lands on the routed tier exactly once,
    no matter how often the job's cost is (re)evaluated."""
    sim = EdgeCloudSimulator(SimConfig(seed=0), policy_name="moa-off",
                             cloud_servers=1, edge_servers=1)
    for r in RequestGenerator(seed=0, arrival_rate=2.0).generate(60):
        sim.submit(r)
    sim.run()
    assert sim.encode_flops.get("edge", 0.0) > 0  # partial offload happened
    # station counters == outcome service attribution + one encode charge
    for name, st in sim.stations.items():
        attributed = sum(o.tier_flops.get(name, 0.0) for o in sim.outcomes)
        assert st.flops == pytest.approx(
            attributed + sim.encode_flops.get(name, 0.0))


def test_hedge_skips_jobs_already_in_service():
    sim = EdgeCloudSimulator(SimConfig(seed=0), hedge_after_s=1.0,
                             cloud_servers=1, edge_servers=1)
    req = RequestGenerator(seed=1).generate(1)[0]
    job = Job(request=req, decision=sim.scheduler.route(req), fusion="edge",
              tier="edge", t_start=0.0, record=RequestRecord(rid=req.rid))
    sim.backend.start_service(0.0, sim.stations["edge"], job)
    assert job.in_service
    n_events = len(sim.events)

    class Ev:
        payload = {"job": job}
        t = 1.0

    sim.runtime._on_hedge_check(Ev())
    assert not job.hedged  # in-service job is left alone
    assert len(sim.events) == n_events


def test_hedged_straggler_produces_single_outcome():
    sim = EdgeCloudSimulator(SimConfig(seed=0), policy_name="edge-only",
                             hedge_after_s=0.5, cloud_servers=1,
                             edge_servers=1)
    n = 60
    for r in RequestGenerator(seed=0, arrival_rate=8.0).generate(n):
        sim.submit(r)
    sim.run()
    rids = [o.rid for o in sim.outcomes]
    assert len(rids) == len(set(rids)) == n  # no duplicated outcomes
    assert any(o.hedged for o in sim.outcomes)  # queued jobs were hedged


# ---------------------------------------------------------------------------
# live ClusterServer smoke (3 reduced-model engines)
# ---------------------------------------------------------------------------


def test_cluster_server_three_tiers_live():
    sv = ServingConfig(max_batch=2, max_seq=96)
    topo = get_topology("edge-regional-cloud")
    engines = {}
    for i, tier in enumerate(topo.tiers):
        cfg = reduced_config(tier.model).replace(dtype="float32")
        model = build_model(cfg)
        engines[tier.name] = TierEngine(
            model, model.init(jax.random.PRNGKey(i)), sv)
    srv = ClusterServer(engines, topology=topo)
    rng = np.random.default_rng(0)
    from repro.data.synthetic import make_image

    for i, u in enumerate([0.05, 0.95, 0.5]):
        srv.submit(f"Describe {i}. " + "pad " * int(u * 60),
                   image=make_image(rng, u, 48, 48), max_new=4)
    res = srv.run()
    assert len(res) == 3
    for r in res:
        assert r.tier in topo.names
        assert set(r.routes.values()) <= set(topo.names)
        assert len(r.tokens) >= 1
    tiers = {r.rid: r.tier for r in res}
    assert tiers[0] == "edge"  # easy request stays local
    assert tiers[1] != "edge"  # complex image offloads


def test_cluster_server_requires_engine_per_tier():
    topo = two_tier_topology()
    with pytest.raises(ValueError):
        ClusterServer({"edge": None}, topology=topo)


# ---------------------------------------------------------------------------
# return-path modeling: embedding uplink + response downlink
# ---------------------------------------------------------------------------


def _split_request(decode_tokens=64):
    from repro.core.request import ModalityInput, Request

    # easy image (stays on edge) + hard text (goes to cloud): fusion is the
    # remote cloud, so the edge-encoded image's embeddings must ride the
    # cloud uplink
    return Request(rid=0, arrival_s=0.0, modalities={
        "image": ModalityInput("image", size_bytes=100_000, complexity=0.05,
                               meta={"h": 64, "w": 64}),
        "text": ModalityInput("text", size_bytes=128, complexity=0.95,
                              meta={"tokens": 32, "entities": 2,
                                    "sentences": 1}),
    }, decode_tokens=decode_tokens, slo_s=30.0)


def test_embeddings_ride_the_remote_fusion_uplink():
    from repro.serving import cost_model as cm

    sim = EdgeCloudSimulator(SimConfig(seed=0), cloud_servers=1,
                             edge_servers=1)
    sim.submit(_split_request())
    (out,) = sim.run()
    assert out.routes == {"image": "edge", "text": "cloud"}
    assert out.served_tier == "cloud"
    # uplink carries the text payload plus the compact image embeddings in
    # the fusion model's geometry — NOT the 100 kB raw image
    want = 128.0 + cm.embedding_bytes(sim.models["cloud"])
    assert out.transfer_bytes == pytest.approx(want)


def test_response_tokens_ride_the_downlink():
    import dataclasses as dc

    from repro.serving import cost_model as cm

    def run(downlink_bps):
        topo = two_tier_topology()
        topo = dc.replace(topo, tiers=tuple(
            dc.replace(t, downlink_bps=downlink_bps) if t.is_remote else t
            for t in topo.tiers))
        sim = ClusterSimulator(SimConfig(seed=0), topology=topo)
        sim.submit(_split_request(decode_tokens=64))
        (out,) = sim.run()
        return out, sim.topology.tier("cloud")

    fast, spec_fast = run(0.0)  # 0 -> symmetric with the uplink
    slow, spec_slow = run(64 * cm.RESPONSE_BYTES_PER_TOKEN)  # 8 s of payload
    want = (cm.downlink_seconds(64, spec_slow)
            - cm.downlink_seconds(64, spec_fast))
    assert want > 1.0  # the constriction is what we measure
    assert slow.latency_s - fast.latency_s == pytest.approx(want)
