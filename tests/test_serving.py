"""Serving-layer tests: engine continuous batching, fault tolerance, the
discrete-event simulator, and the live two-tier server."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import PolicyConfig, ServingConfig, SimConfig
from repro.configs import reduced_config
from repro.data.synthetic import RequestGenerator, make_image
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.simulator import EdgeCloudSimulator
from repro.serving.tiers import EdgeCloudServer


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = reduced_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return TierEngine(model, params, ServingConfig(max_batch=3, max_seq=64))


def test_engine_continuous_batching(tiny_engine):
    eng = tiny_engine
    for rid in range(7):  # more requests than slots
        toks = np.arange(4 + rid % 3, dtype=np.int32) + 4
        eng.submit(rid, toks, max_new=5)
    done = eng.run_until_drained()
    assert sorted(s.rid for s in done) == list(range(7))
    assert all(1 <= len(s.generated) <= 5 for s in done)
    eng.finished.clear()


def test_engine_no_request_lost_or_duplicated(tiny_engine):
    eng = tiny_engine
    for rid in range(10, 16):
        eng.submit(rid, np.asarray([4, 5, 6], np.int32), max_new=3)
    done = eng.run_until_drained()
    rids = [s.rid for s in done]
    assert len(rids) == len(set(rids)) == 6
    eng.finished.clear()


def test_engine_snapshot_restore_failover(tiny_engine):
    """Standby takes over mid-flight from a snapshot and finishes the work."""
    eng = tiny_engine
    for rid in range(20, 24):
        eng.submit(rid, np.asarray([4, 5, 6, 7], np.int32), max_new=6)
    eng.step()
    snap = eng.snapshot()
    survivors = {s.rid for s in eng.slots if s} | {w["rid"] for w in eng.waiting}
    # simulate crash: wipe state, restore on the "standby"
    eng.slots = [None] * len(eng.slots)
    eng.waiting.clear()
    eng.restore(snap)
    done = eng.run_until_drained()
    assert survivors <= {s.rid for s in done}
    eng.finished.clear()


def test_live_two_tier_server_routes_and_finishes():
    sv = ServingConfig(max_batch=2, max_seq=96)
    ecfg = reduced_config("qwen2-vl-2b").replace(dtype="float32")
    ccfg = reduced_config("qwen2.5-vl-7b").replace(dtype="float32")
    em, cm = build_model(ecfg), build_model(ccfg)
    edge = TierEngine(em, em.init(jax.random.PRNGKey(0)), sv)
    cloud = TierEngine(cm, cm.init(jax.random.PRNGKey(1)), sv)
    srv = EdgeCloudServer(edge, cloud)
    rng = np.random.default_rng(0)
    for i, u in enumerate([0.05, 0.95]):
        srv.submit(f"Describe {i}. " + "pad " * int(u * 100),
                   image=make_image(rng, u, 48, 48), max_new=4)
    res = srv.run()
    assert len(res) == 2
    tiers = {r.rid: r.tier for r in res}
    assert tiers[1] == "cloud"  # complex image must offload
    routes1 = next(r.routes for r in res if r.rid == 1)
    assert routes1["text"] == "edge"  # short text stays local (per-modality)


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------


def _run_sim(policy, n=150, rate=2.0, fail=0.0, hedge=0.0, seed=0):
    gen = RequestGenerator(seed=seed, arrival_rate=rate)
    sim = EdgeCloudSimulator(SimConfig(bandwidth_bps=300e6, seed=seed),
                             policy_name=policy, fail_rate=fail,
                             hedge_after_s=hedge,
                             cloud_servers=1, edge_servers=1)
    for r in gen.generate(n):
        sim.submit(r)
    sim.run()
    return sim.metrics()


def test_simulator_conservation():
    m = _run_sim("moa-off")
    assert m["accuracy"] > 0.3
    assert m["mean_latency_s"] > 0


def test_simulator_policy_ordering():
    """Cloud-only burns the most resources; MoA-Off sits between tiers."""
    mc = _run_sim("cloud-only")
    me = _run_sim("edge-only")
    mm = _run_sim("moa-off")
    assert mc["cloud_flops"] > mm["cloud_flops"]  # MoA-Off offloads less
    assert mm["accuracy"] > me["accuracy"]        # and is more accurate than edge
    assert 0.0 < mm["frac_edge"] < 1.0            # genuinely splits traffic


def test_simulator_fault_injection_retries_complete():
    m = _run_sim("moa-off", n=80, fail=0.1)
    assert m["retries"] > 0  # failures happened and were retried
    # every request still completed (metrics computed over all outcomes)
    assert m["accuracy"] > 0.2


def test_simulator_hedging_marks_stragglers():
    m = _run_sim("edge-only", n=60, rate=6.0, hedge=1.0)
    assert m["hedged"] > 0


def test_request_generator_deterministic():
    a = RequestGenerator(seed=7).generate(20)
    b = RequestGenerator(seed=7).generate(20)
    assert [r.difficulty for r in a] == [r.difficulty for r in b]
    assert all(r.modalities["text"].meta["tokens"] ==
               s.modalities["text"].meta["tokens"] for r, s in zip(a, b))
