"""Property-based (hypothesis) round trips for the per-slot cache wire
format: for every model family, any mix of prompt lengths / decode budgets /
extraction depths yields a token-for-token identical continued decode after
``extract_slot`` -> wire bytes -> ``inject_slot`` into a fresh engine.

The extraction is read-only (``remove=False``), so the DONOR's own
uninterrupted completion is the reference the migrated continuation must
match — no third engine needed."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.serving.engine import SlotPayload  # noqa: E402

from test_migration import FAMILIES, make_engine  # noqa: E402


def _jobs(cfg, lengths, max_new):
    jobs = []
    for rid, n in enumerate(lengths):
        toks = (np.arange(n) % 300 + 4).astype(np.int32)
        extras = {}
        if cfg.frontend == "vision_stub" and rid % 2 == 0:
            extras["patches"] = np.random.default_rng(rid).standard_normal(
                (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        jobs.append((rid, toks, max_new, extras))
    return jobs


@pytest.mark.slow
@pytest.mark.parametrize("family", FAMILIES)
@given(data=st.data())
@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_property_roundtrip_continued_decode(family, data, family_model):
    cfg, params = family_model(family)
    lengths = data.draw(st.lists(st.integers(2, 20), min_size=1, max_size=3),
                        label="prompt_lengths")
    max_new = data.draw(st.integers(6, 24), label="max_new")
    steps = data.draw(st.integers(1, 3), label="decode_blocks")
    jobs = _jobs(cfg, lengths, max_new)

    donor = make_engine(cfg, params, fused=4)
    for rid, toks, mx, extras in jobs:
        donor.submit(rid, toks, max_new=mx, extras=extras)
    for _ in range(steps):
        donor.step()
    live = [s.rid for s in donor.slots if s is not None]
    if not live:  # everything finished before extraction: trivially true
        return
    rid = data.draw(st.sampled_from(sorted(live)), label="migrated_rid")
    payload = SlotPayload.from_bytes(donor.extract_slot(rid).to_bytes())

    target = make_engine(cfg, params, fused=4)
    target.inject_slot(payload)
    migrated = {s.rid: s.generated for s in target.run_until_drained()}[rid]
    assert target.prefill_tokens == 0  # the rows shipped; no second prefill

    reference = {s.rid: s.generated
                 for s in donor.run_until_drained()}[rid]
    assert migrated == reference
