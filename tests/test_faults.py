"""FaultPlan: event validation, flap compilation, point-in-time queries,
JSON round-trip, seeded storm determinism — and the legacy ``fail_rate``
shim's bit-identical rng stream through the analytic backend."""
import json

import numpy as np
import pytest

from repro.serving.faults import FaultEvent, FaultPlan


def test_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor", "edge")
    with pytest.raises(ValueError, match="before 0"):
        FaultEvent("crash", "edge", t=-1.0)
    with pytest.raises(ValueError, match="flap"):
        FaultEvent("flap", "edge", period=0.0, duration=2.0)
    with pytest.raises(ValueError, match="flap"):
        FaultEvent("flap", "edge", magnitude=1.5, duration=2.0)
    with pytest.raises(ValueError, match="bandwidth multiplier"):
        FaultEvent("degrade", "edge", magnitude=-0.1)
    with pytest.raises(ValueError, match="finite duration"):
        FaultPlan([FaultEvent("flap", "edge", magnitude=0.5)])


def test_crash_window_queries():
    plan = FaultPlan([FaultEvent("crash", "edge", t=1.0, duration=2.0)])
    assert plan.has_crashes
    assert not plan.crashed("edge", 0.99)
    assert plan.crashed("edge", 1.0)
    assert plan.crashed("edge", 2.999)
    assert not plan.crashed("edge", 3.0)  # half-open window
    assert not plan.crashed("cloud", 1.5)
    assert not FaultPlan().has_crashes


def test_flap_compiles_to_duty_cycle_crash_windows():
    plan = FaultPlan([FaultEvent("flap", "edge", t=0.0, duration=4.0,
                                 magnitude=0.5, period=2.0)])
    # down for the first half of each 2 s period, up for the second
    for t, want in [(0.0, True), (0.9, True), (1.0, False), (1.9, False),
                    (2.0, True), (2.9, True), (3.0, False), (4.5, False)]:
        assert plan.crashed("edge", t) == want, t


def test_slow_and_link_multipliers_stack():
    plan = FaultPlan([
        FaultEvent("slow", "edge", t=0.0, duration=10.0, magnitude=2.0),
        FaultEvent("slow", "edge", t=5.0, duration=10.0, magnitude=3.0),
        FaultEvent("degrade", "cloud", t=1.0, duration=2.0, magnitude=0.25),
        FaultEvent("degrade", "cloud", t=2.0, duration=2.0, magnitude=0.0),
    ])
    assert plan.slow_multiplier("edge", 1.0) == 2.0
    assert plan.slow_multiplier("edge", 6.0) == 6.0  # overlap multiplies
    assert plan.slow_multiplier("edge", 12.0) == 3.0
    assert plan.slow_multiplier("edge", 20.0) == 1.0
    assert plan.link_multiplier("cloud", 1.5) == 0.25
    assert plan.link_multiplier("cloud", 2.5) == 0.0  # partition dominates
    assert plan.link_multiplier("cloud", 5.0) == 1.0
    assert plan.slow_multiplier("cloud", 1.0) == 1.0  # kinds don't bleed


def test_json_round_trip_including_infinite_windows():
    plan = FaultPlan([
        FaultEvent("crash", "edge", t=0.5),  # infinite duration
        FaultEvent("slow", "edge1", t=1.0, duration=3.0, magnitude=4.0),
        FaultEvent("flap", "cloud", t=0.0, duration=6.0, magnitude=0.25,
                   period=2.0),
    ], fail_rate=0.05)
    back = FaultPlan.from_json(plan.to_json())
    assert back.events == plan.events
    assert back.fail_rate == plan.fail_rate
    assert back.crashed("edge", 1e12)  # the infinity survived the trip
    raw = json.loads(plan.to_json())
    assert any(e["duration"] == "inf" for e in raw["events"])


def test_storm_is_deterministic_and_pure_at_query_time():
    a = FaultPlan.storm(seed=3, tiers=["edge", "cloud"], duration=10.0)
    b = FaultPlan.storm(seed=3, tiers=["edge", "cloud"], duration=10.0)
    assert a.events == b.events  # same seed, same storm
    assert a.events != FaultPlan.storm(seed=4, tiers=["edge", "cloud"],
                                       duration=10.0).events
    assert sorted(e.kind for e in a.events) == ["crash", "degrade", "slow"]

    def probe(p):
        return [(p.crashed("edge", t), p.slow_multiplier("cloud", t),
                 p.link_multiplier("cloud", t))
                for t in np.linspace(0.0, 10.0, 13)]

    assert probe(a) == probe(a)  # queries never draw: stable under repeat


def test_fail_rate_shim_is_bit_identical_to_bare_fail_rate():
    """``FaultPlan.from_fail_rate(p)`` drives the analytic backend through
    the exact rng stream the scalar ``fail_rate=p`` always used: every
    outcome (latency, retries, accuracy draw) is bit-identical."""
    from repro.config import SimConfig
    from repro.data.synthetic import RequestGenerator
    from repro.serving.simulator import EdgeCloudSimulator

    def run(**kw):
        sim = EdgeCloudSimulator(SimConfig(bandwidth_bps=300e6, seed=0),
                                 cloud_servers=1, edge_servers=1, **kw)
        for r in RequestGenerator(seed=0, arrival_rate=4.0).generate(40):
            sim.submit(r)
        sim.run()
        return sim

    bare = run(fail_rate=0.1)
    shim = run(fault_plan=FaultPlan.from_fail_rate(0.1))
    key = [(o.rid, o.latency_s, o.retries, o.correct, o.served_tier,
            o.failed) for o in bare.outcomes]
    assert key == [(o.rid, o.latency_s, o.retries, o.correct, o.served_tier,
                    o.failed) for o in shim.outcomes]
    assert any(o.retries > 0 for o in bare.outcomes)  # faults really fired
    mb, ms = bare.metrics(), shim.metrics()
    # the shim may add the (gated) resilience keys; every shared metric is
    # bit-identical
    for k, v in mb.items():
        assert ms[k] == v, k


# ---------------------------------------------------------------------------
# byzantine message-fault kinds (corrupt / msg_drop / msg_dup / msg_reorder)
# ---------------------------------------------------------------------------


def test_msg_kind_validation():
    with pytest.raises(ValueError, match="per-message probability"):
        FaultEvent("corrupt", "*", magnitude=0.0)
    with pytest.raises(ValueError, match="per-message probability"):
        FaultEvent("msg_drop", "*", magnitude=1.5)
    FaultEvent("msg_dup", "events:edge/0", magnitude=1.0)  # bound included


def test_unknown_kind_fails_loudly_from_json():
    """A stale plan file with a kind this build doesn't know must raise,
    not silently skip injection."""
    raw = json.dumps({"events": [{"kind": "msg_scramble", "tier": "*",
                                  "magnitude": 0.5}]})
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.from_json(raw)


def test_msg_prob_windows_and_selectors():
    plan = FaultPlan([
        FaultEvent("corrupt", "migrate:edge1", t=1.0, duration=2.0,
                   magnitude=0.5),
        FaultEvent("msg_drop", "edge", magnitude=0.25),        # bare tier
        FaultEvent("msg_dup", "events:cloud", magnitude=0.125),  # proto:tier
        FaultEvent("msg_reorder", "*", t=0.0, duration=10.0, magnitude=0.1),
    ])
    assert plan.has_msg_faults and not plan.has_crashes
    # timed window: closed outside, open inside
    assert plan.msg_prob("corrupt", "migrate:edge1", 0.5) == 0.0
    assert plan.msg_prob("corrupt", "migrate:edge1", 1.5) == 0.5
    assert plan.msg_prob("corrupt", "migrate:edge1", 3.0) == 0.0
    # bare tier selector covers every protocol/replica on that tier
    assert plan.msg_prob("msg_drop", "events:edge/0", 0.0) == 0.25
    assert plan.msg_prob("msg_drop", "frame:edge/1", 0.0) == 0.25
    assert plan.msg_prob("msg_drop", "events:cloud/0", 0.0) == 0.0
    # proto:tier prefix covers that tier's replicas on that protocol only
    assert plan.msg_prob("msg_dup", "events:cloud/3", 0.0) == 0.125
    assert plan.msg_prob("msg_dup", "migrate:cloud", 0.0) == 0.0
    # wildcard matches everything inside its window
    assert plan.msg_prob("msg_reorder", "anything:else", 5.0) == 0.1
    assert plan.msg_prob("msg_reorder", "anything:else", 11.0) == 0.0


def test_msg_faults_json_round_trip_with_links_and_wire_seed():
    plan = FaultPlan([
        FaultEvent("corrupt", "migrate:edge1", magnitude=0.9),  # infinite
        FaultEvent("msg_drop", "events:edge/0", t=2.0, duration=5.0,
                   magnitude=0.25),
        FaultEvent("crash", "cloud", t=1.0, duration=2.0),
    ], wire_seed=42)
    back = FaultPlan.from_json(plan.to_json())
    assert back.events == plan.events
    assert back.wire_seed == 42
    assert back.msg_prob("corrupt", "migrate:edge1", 1e12) == 0.9
    assert back.msg_prob("msg_drop", "events:edge/0", 3.0) == 0.25


def test_byzantine_storm_builder():
    plan = FaultPlan.byzantine_storm(seed=7, corrupt=0.4, dup=0.3,
                                     drop=0.2, reorder=0.1)
    assert plan.wire_seed == 7
    assert sorted(e.kind for e in plan.events) == [
        "corrupt", "msg_drop", "msg_dup", "msg_reorder"]
    assert all(e.tier == "*" and e.duration == float("inf")
               for e in plan.events)
    # zeroed kinds drop out of the plan entirely
    assert not FaultPlan.byzantine_storm(seed=0, corrupt=0.0, dup=0.0,
                                         drop=0.0, reorder=0.0).has_msg_faults


def test_wire_chaos_counters_are_per_link_and_seeded():
    from repro.serving.faults import WireChaos

    plan = FaultPlan.byzantine_storm(seed=13, corrupt=0.5)
    a, b = WireChaos(plan), WireChaos(plan)
    # same per-link query sequence -> identical fates, independent of the
    # interleaving with OTHER links (the cross-backend parity property)
    fates_a = [a.decide("corrupt", "migrate:edge", 0.0) for _ in range(32)]
    for _ in range(32):
        b.decide("corrupt", "migrate:cloud", 50.0)  # noise on another link
    fates_b = [b.decide("corrupt", "migrate:edge", 99.0) for _ in range(32)]
    assert fates_a == fates_b
    assert any(fates_a) and not all(fates_a)
    # a different wire_seed reshuffles the fates
    c = WireChaos(FaultPlan.byzantine_storm(seed=14, corrupt=0.5))
    assert fates_a != [c.decide("corrupt", "migrate:edge", 0.0)
                       for _ in range(32)]


def test_wire_chaos_tamper_always_changes_bytes():
    from repro.serving.faults import WireChaos

    chaos = WireChaos(FaultPlan.byzantine_storm(seed=1))
    data = bytes(range(64))
    seen = set()
    for _ in range(16):
        out = chaos.tamper(data, "migrate:edge")
        assert out != data and len(out) == len(data)
        seen.add(out)
    assert len(seen) > 1  # the flip position/mask advances with the counter
    assert chaos.tamper(b"", "migrate:edge") == b""
