"""Cross-tier KV migration: per-slot extract/inject round trips for every
model family, the versioned wire format's negative paths, and the three
runtime migrate edges (hedged clones, load-triggered preemption, fault
re-homing) against live engines — plus the fault-rng redraw regression."""
import numpy as np
import pytest

from repro.config import (PolicyConfig, ServingConfig, SimConfig,
                          get_topology, two_tier_topology)
from repro.models import build_model
from repro.serving.engine import (MIGRATION_WIRE_VERSION, MigrationError,
                                  SlotPayload, TierEngine)
from repro.serving.simulator import ClusterSimulator, EdgeCloudSimulator
from repro.serving.tiers import ClusterServer, build_cluster_engines

FAMILIES = ("dense", "vlm", "moe", "ssm", "hybrid")


def make_engine(cfg, params, max_batch=3, max_seq=64, fused=8, eos=2):
    sv = ServingConfig(max_batch=max_batch, max_seq=max_seq,
                       fused_steps=fused)
    return TierEngine(build_model(cfg), params, sv, eos_id=eos)


def family_jobs(cfg, n=3, max_new=20, seed=0):
    """n jobs with staggered prompt lengths (bucket-prefill groups them);
    VLM jobs alternate patch extras to exercise the vision prefix."""
    rng = np.random.default_rng(seed)
    jobs = []
    for rid in range(n):
        toks = (np.arange(4 + 3 * rid) % 300 + 4).astype(np.int32)
        extras = {}
        if cfg.frontend == "vision_stub" and rid % 2 == 0:
            extras["patches"] = rng.standard_normal(
                (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        jobs.append((rid, toks, max_new, extras))
    return jobs


def run_reference(cfg, params, jobs, **kw):
    eng = make_engine(cfg, params, **kw)
    for rid, toks, max_new, extras in jobs:
        eng.submit(rid, toks, max_new=max_new, extras=extras)
    return {s.rid: s.generated for s in eng.run_until_drained()}


def roundtrip_tokens(cfg, params, jobs, rid, steps=1, via_bytes=True, **kw):
    """Admit ``jobs`` on a donor engine (bucket prefill), run ``steps``
    decode blocks, extract ``rid``'s slot, inject it into a FRESH engine and
    drain. Returns (continued tokens, donor payload)."""
    donor = make_engine(cfg, params, **kw)
    for j, toks, max_new, extras in jobs:
        donor.submit(j, toks, max_new=max_new, extras=extras)
    for _ in range(steps):
        donor.step()
    payload = donor.extract_slot(rid)
    if via_bytes:
        wire = payload.to_bytes()
        assert payload.nbytes == len(wire)
        payload = SlotPayload.from_bytes(wire)
    target = make_engine(cfg, params, **kw)
    target.inject_slot(payload)
    done = {s.rid: s.generated for s in target.run_until_drained()}
    assert target.prefill_tokens == 0  # no second prefill: rows shipped
    return done[rid], payload


# ---------------------------------------------------------------------------
# round trips: every family, token-for-token identical continued decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", [
    "dense",
    # the heavier families ride the slow mark to keep the smoke lane fast
    pytest.param("vlm", marks=pytest.mark.slow),
    pytest.param("moe", marks=pytest.mark.slow),
    pytest.param("ssm", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
])
def test_roundtrip_every_family(family, family_model):
    """extract -> wire bytes -> inject into a fresh engine continues the
    decode token-for-token at temp=0, for a slot that was bucket-prefilled
    alongside neighbours (dense/vlm pad buckets, ssm/hybrid exact-length
    groups, moe per-request groups)."""
    cfg, params = family_model(family)
    jobs = family_jobs(cfg, n=3)
    ref = run_reference(cfg, params, jobs)
    toks, payload = roundtrip_tokens(cfg, params, jobs, rid=1)
    assert toks == ref[1]
    assert payload.model == cfg.name and payload.family == cfg.family
    assert payload.nbytes > 0


@pytest.mark.slow
@pytest.mark.parametrize("steps", [0, 2])
def test_roundtrip_after_more_blocks(steps, family_model):
    """Extraction is exact no matter how deep into the decode it happens
    (0 extra blocks = straight out of the bucketed prefill)."""
    cfg, params = family_model("dense")
    jobs = family_jobs(cfg, n=4, max_new=30)
    ref = run_reference(cfg, params, jobs)
    toks, _ = roundtrip_tokens(cfg, params, jobs, rid=2, steps=1 + steps)
    assert toks == ref[2]


@pytest.mark.slow
def test_roundtrip_midstream_eos(family_model):
    """A migrated slot hits mid-stream EOS at exactly the same token as an
    uninterrupted run."""
    cfg, params = family_model("dense")
    jobs = family_jobs(cfg, n=3, max_new=16)
    plain = run_reference(cfg, params, jobs)
    # choose an EOS rid 1 emits AFTER the extraction point (1 admit token +
    # one fused block of 2) but before its budget — greedy decode repeats
    # the same prefix, so with that EOS the run truncates exactly there
    seen = set(plain[1][:4])
    eos = next(t for t in plain[1][4:] if t not in seen)
    ref = run_reference(cfg, params, jobs, eos=eos, fused=2)
    assert ref[1][-1] == eos and len(ref[1]) < 16  # genuinely mid-stream
    donor = make_engine(cfg, params, eos=eos, fused=2)
    for rid, toks, max_new, extras in jobs:
        donor.submit(rid, toks, max_new=max_new, extras=extras)
    donor.step()  # rid 1 has 3 tokens: still ahead of its EOS
    payload = SlotPayload.from_bytes(donor.extract_slot(1).to_bytes())
    target = make_engine(cfg, params, eos=eos, fused=2)
    target.inject_slot(payload)
    done = {s.rid: s.generated for s in target.run_until_drained()}
    assert done[1] == ref[1]
    assert done[1][-1] == eos and len(done[1]) < 16


def test_extract_remove_frees_slot(family_model):
    cfg, params = family_model("dense")
    eng = make_engine(cfg, params, max_batch=2)
    eng.submit(0, np.asarray([4, 5, 6], np.int32), max_new=20)
    eng.step()
    eng.extract_slot(0, remove=True)
    assert all(s is None for s in eng.slots)
    with pytest.raises(MigrationError):
        eng.extract_slot(0)  # gone
    assert eng.run_until_drained() == []  # nothing resurrects


# ---------------------------------------------------------------------------
# negative paths: clear MigrationError, target engine untouched
# ---------------------------------------------------------------------------


@pytest.fixture()
def dense_payload(family_model):
    cfg, params = family_model("dense")
    eng = make_engine(cfg, params)
    eng.submit(0, np.asarray([4, 5, 6, 7], np.int32), max_new=20)
    eng.step()
    return cfg, params, eng.extract_slot(0)


def _assert_untouched(eng):
    assert all(s is None for s in eng.slots)
    assert not any(op == "inject" for op, _ in eng.journal)


def test_inject_rejects_wrong_wire_version(dense_payload):
    cfg, params, payload = dense_payload
    bad = SlotPayload.from_bytes(payload.to_bytes())
    bad.version = MIGRATION_WIRE_VERSION + 1
    eng = make_engine(cfg, params)
    with pytest.raises(MigrationError, match="wire format version"):
        eng.inject_slot(bad)
    _assert_untouched(eng)


def test_inject_rejects_wrong_model(dense_payload, family_model):
    _, _, payload = dense_payload
    vcfg, vparams = family_model("vlm")
    eng = make_engine(vcfg, vparams)
    with pytest.raises(MigrationError, match="model-specific"):
        eng.inject_slot(payload)
    _assert_untouched(eng)


def test_inject_rejects_mismatched_cache_axes(dense_payload):
    """A payload from a different max_seq has differently-shaped cache rows:
    rejected up front with the shapes in the message, not a scan crash."""
    cfg, params, payload = dense_payload
    eng = make_engine(cfg, params, max_seq=128)  # donor used 64
    with pytest.raises(MigrationError, match="row shape"):
        eng.inject_slot(payload)
    _assert_untouched(eng)


def test_inject_rejects_when_full_and_duplicate(dense_payload):
    cfg, params, payload = dense_payload
    eng = make_engine(cfg, params, max_batch=1)
    eng.inject_slot(payload)
    with pytest.raises(MigrationError, match="already occupies"):
        eng.inject_slot(payload)
    other = SlotPayload.from_bytes(payload.to_bytes())
    other.seq.rid = 7
    with pytest.raises(MigrationError, match="no free"):
        eng.inject_slot(other)


def test_wire_rejects_garbage_and_truncation(dense_payload):
    _, _, payload = dense_payload
    wire = payload.to_bytes()
    with pytest.raises(MigrationError, match="magic"):
        SlotPayload.from_bytes(b"NOTKV" + wire[5:])
    with pytest.raises(MigrationError, match="truncated"):
        SlotPayload.from_bytes(wire[:len(wire) // 2])
    with pytest.raises(MigrationError, match="truncated"):
        SlotPayload.from_bytes(wire[:7])  # cut inside the fixed header
    # every header malformation surfaces as MigrationError (re-prefill
    # fallback), never a stray KeyError/ValueError/AttributeError. The
    # forged header carries a VALID checksum — these are malformed-sender
    # bugs, not wire corruption, and must still fail closed
    import json as _json
    import struct as _struct
    import zlib as _zlib
    for mutate in (lambda h: h.pop("key"),
                   lambda h: h["leaves"][0].update(shape=[-2, 4]),
                   lambda h: h["leaves"][0].update(dtype="float77")):
        hlen = _struct.unpack_from("<HII", wire, 5)[1]
        head = _json.loads(wire[15:15 + hlen])
        mutate(head)
        blob = _json.dumps(head).encode()
        bad = (wire[:5] + _struct.pack("<HII", MIGRATION_WIRE_VERSION,
                                       len(blob), _zlib.crc32(blob))
               + blob + wire[15 + hlen:])
        with pytest.raises(MigrationError):
            SlotPayload.from_bytes(bad)


# ---------------------------------------------------------------------------
# live runtime: the three migrate edges
# ---------------------------------------------------------------------------


from conftest import make_twin_edge_server as _twin_edge_server  # noqa: E402


@pytest.mark.slow
def test_live_hedged_migration_no_second_prefill():
    """THE acceptance path: a hedged in-service straggler ships its slot to
    the compatible twin tier; the winning side finishes every token and the
    receiving engine's prefill counter proves no second prefill happened."""
    server = _twin_edge_server(hedge_after_s=0.05, migrate=True)
    base = {t: e.prefill_tokens for t, e in server.engines.items()}
    server.submit("please describe this Scene in depth. " * 3,
                  max_new=100, complexity={"text": 0.05})
    (res,) = [r for r in server.run() if r.rid == 0]
    assert res.migrated and res.hedged
    assert res.migration_bytes > 0
    assert len(res.tokens) == 100
    trace = server.runtime.records[0].trace()
    assert ("migrate", "edge1") in trace  # compatible twin, never cloud
    # the receiving engine decoded the migrated slot without prefilling
    assert server.engines["edge1"].prefill_tokens == base["edge1"]
    assert any(op == "inject" for op, _ in
               server.engines["edge1"].journal)


@pytest.mark.slow
def test_live_preemption_moves_longest_remaining_slot():
    sv = ServingConfig(max_batch=1, max_seq=192)
    server = _twin_edge_server(sv=sv, migrate_threshold=2)
    server.submit("first long request please run. " * 2, max_new=120,
                  complexity={"text": 0.05})
    server.submit("second request queued now. " * 2, max_new=6,
                  complexity={"text": 0.05}, delay_s=0.12)
    server.submit("third request triggers preemption. " * 2, max_new=6,
                  complexity={"text": 0.05}, delay_s=0.24)
    res = {r.rid: r for r in server.run()}
    assert len(res) == 3
    assert res[0].migrated and res[0].tier == "edge1"
    assert len(res[0].tokens) == 120  # nothing lost crossing tiers
    trace = server.runtime.records[0].trace()
    assert ("preempt", "edge") in trace and ("migrate", "edge1") in trace
    # the freed slot served the queued requests locally
    assert not res[1].migrated and not res[2].migrated


@pytest.mark.slow
def test_live_fault_rehomes_inflight_slot():
    """A node fault re-homes the snapshot's in-flight slots onto the
    surviving compatible tier instead of replaying them on the standby.
    A FaultPlan crash window opens AFTER the long request is decoding but
    before the second arrives: the second submission faults, the restore
    rescues the first request's slot onto edge1 where it completes, and
    the faulted request (edge stays down) fails terminally once its retry
    budget is spent."""
    from repro.serving.faults import FaultEvent, FaultPlan

    plan = FaultPlan([FaultEvent("crash", "edge", t=0.1)])  # down forever
    sv = ServingConfig(max_batch=2, max_seq=96, heartbeat_timeout_s=0.0,
                       retry_limit=1)
    server = _twin_edge_server(sv=sv, fault_plan=plan, migrate=True,
                               snapshot_every=0)
    server.submit("long running request one. " * 2, max_new=60,
                  complexity={"text": 0.05})
    server.submit("short follow-up request. " * 2, max_new=6,
                  complexity={"text": 0.05}, delay_s=0.2)
    res = {r.rid: r for r in server.run(timeout_s=60.0)}
    assert len(res) == 2
    assert res[0].migrated and res[0].tier == "edge1"
    assert len(res[0].tokens) == 60  # the rescued slot completed in full
    assert server.backend.restores >= 1
    # the faulted submission retried on the still-crashed tier until its
    # budget ran out, then resolved terminally — never a silent hang
    assert res[1].failed and res[1].fail_reason == "retries"
    assert res[1].retries == sv.retry_limit


@pytest.mark.slow
def test_live_dead_donor_falls_back_to_reprefill():
    """Donor engine dies between the hedge decision and the extract: the
    clone re-prefills on the alternate tier and the request still finishes."""
    server = _twin_edge_server(hedge_after_s=0.05, migrate=True)
    base = {t: e.prefill_tokens for t, e in server.engines.items()}
    orig_extract = server.engines["edge"].extract_slot

    def dying_extract(rid, **kw):
        server.engines["edge"].healthy = False
        raise MigrationError("donor died mid-extract")

    server.engines["edge"].extract_slot = dying_extract
    server.submit("please describe this Scene in depth. " * 3,
                  max_new=100, complexity={"text": 0.05})
    (res,) = [r for r in server.run() if r.rid == 0]
    server.engines["edge"].extract_slot = orig_extract
    assert res.hedged and not res.migrated
    assert len(res.tokens) == 100
    # the fallback clone paid a real prefill on the alternate tier
    deltas = {t: e.prefill_tokens - base[t]
              for t, e in server.engines.items() if t != "edge"}
    assert res.tier != "edge1" or deltas["edge1"] > 0


def test_live_inject_capacity_fallback(family_model):
    """A migrated payload arriving at a full engine falls back to a normal
    (re-prefill) submission via MigrationError — exercised engine-level."""
    cfg, params = family_model("dense")
    eng = make_engine(cfg, params, max_batch=1)
    eng.submit(5, np.asarray([4, 5, 6], np.int32), max_new=30)
    eng.step()  # slot occupied
    donor = make_engine(cfg, params, max_batch=1)
    donor.submit(9, np.asarray([4, 5, 6, 7], np.int32), max_new=30)
    donor.step()
    with pytest.raises(MigrationError, match="no free"):
        eng.inject_slot(donor.extract_slot(9))


# ---------------------------------------------------------------------------
# fault-rng redraw regression (ROADMAP item): draws per SUBMISSION
# ---------------------------------------------------------------------------


def test_live_fault_redraw_per_submission():
    """Retried submissions re-draw the fault rng (they used to be replayed
    engine-side without a draw): with fail_rate=1 EVERY submission faults —
    initial + retry_limit retries = retry_limit + 1 draws — and the request
    then resolves into a terminal failed Outcome (analytic parity)."""
    sv = ServingConfig(max_batch=2, max_seq=64, heartbeat_timeout_s=0.0)
    topo = two_tier_topology()
    server = ClusterServer(build_cluster_engines(topo, sv), topology=topo,
                           fail_rate=1.0)
    server.submit("hello there friend", max_new=4,
                  complexity={"text": 0.05})
    (res,) = server.run(timeout_s=60.0)
    limit = sv.retry_limit
    assert res.retries == limit
    assert server.backend.fault_draws == limit + 1
    assert res.failed and res.fail_reason == "retries"


def test_analytic_fault_draw_per_submission():
    """The analytic backend draws exactly once per service start — the
    invariant the live fix aligns with."""
    from repro.data.synthetic import RequestGenerator

    sim = EdgeCloudSimulator(SimConfig(seed=0), cloud_servers=1,
                             edge_servers=1, fail_rate=0.4)
    for r in RequestGenerator(seed=0, arrival_rate=2.0).generate(20):
        sim.submit(r)
    sim.run()
    serves = sum(1 for rec in sim.runtime.records.values()
                 for s, _ in rec.events if s == "serve")
    assert sim.backend.fault_draws == serves
    assert any(o.retries > 0 for o in sim.outcomes)  # faults actually fired


# ---------------------------------------------------------------------------
# analytic migration: hedge-migrate populates outcomes + gated metrics
# ---------------------------------------------------------------------------


def test_analytic_preempt_then_hedge_keeps_stations_consistent():
    """Regression: a preempt-migrated request that later reaches its hedge
    check must NOT be migrated again (ping-pong) — and whatever happens,
    every station's busy count returns to zero and every request completes
    exactly once (the stale-completion markers are per-release, and hedge
    clones never inherit them)."""
    from repro.core.request import ModalityInput, Request

    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=get_topology("edge-edge-cloud"),
                           hedge_after_s=0.3, migrate_threshold=2)

    def req(rid, t, dec):
        return Request(rid=rid, arrival_s=t, decode_tokens=dec, modalities={
            "text": ModalityInput("text", complexity=0.05, size_bytes=256,
                                  meta={"tokens": 64})})

    sim.submit(req(0, 0.0, 2000))  # long: preempted when rid 2 lands
    sim.submit(req(1, 0.05, 8))
    sim.submit(req(2, 0.10, 8))
    out = sim.run()
    rids = sorted(o.rid for o in out)
    assert rids == [0, 1, 2]  # each exactly once, none lost
    (o0,) = [o for o in out if o.rid == 0]
    assert o0.migrated  # the preemption actually fired
    trace = sim.runtime.records[0].trace()
    assert trace.count(("migrate", "edge1")) == 1  # moved once, no bounce
    for st in sim.backend.stations.values():
        assert st.busy == 0 and not st.queue  # no leaked servers


def test_analytic_hedge_migration_and_gated_metrics():
    from repro.data.synthetic import RequestGenerator

    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=get_topology("edge-edge-cloud"),
                           hedge_after_s=0.2, migrate=True)
    for r in RequestGenerator(seed=0, arrival_rate=5.0).generate(30):
        sim.submit(r)
    sim.run()
    assert len(sim.outcomes) == 30
    assert sim.runtime.migrations > 0
    migrated = [o for o in sim.outcomes if o.migrated]
    assert migrated and all(o.migration_bytes > 0 for o in migrated)
    m = sim.metrics()
    assert m["migrated"] == pytest.approx(len(migrated) / 30)
    # migration keys appear ONLY when the edge is enabled (golden key set)
    off = ClusterSimulator(SimConfig(seed=0),
                           topology=get_topology("edge-edge-cloud"))
    for r in RequestGenerator(seed=0, arrival_rate=5.0).generate(5):
        off.submit(r)
    off.run()
    assert "migrated" not in off.metrics()
