"""The unified cluster runtime: pre-refactor golden-metric regression for
the analytic backend (which also locks that DISABLED migration leaves every
metric exact to 1e-12), sim-vs-live lifecycle parity — including migration
lifecycle traces: the same hedged/preempted workload produces identical
routing + migrate decisions through AnalyticBackend and LiveBackend — and
the live-only capabilities the runtime brings (executed partial offload,
streaming TTFT/EDF admission, hedging, snapshot/restore fault recovery,
prompt truncation accounting)."""
import copy
import json
import os
import warnings

import jax
import numpy as np
import pytest

from repro.config import (PolicyConfig, ServingConfig, SimConfig,
                          get_topology, two_tier_topology)
from repro.configs import reduced_config
from repro.core.baselines import make_policy
from repro.core.scheduler import MoAOffScheduler
from repro.data.synthetic import RequestGenerator, make_image
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.pool import build_engine_pools
from repro.serving.simulator import ClusterSimulator, EdgeCloudSimulator
from repro.serving.tiers import ClusterServer, build_cluster_engines

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_sim_metrics.json")


# ---------------------------------------------------------------------------
# analytic backend: pre-refactor golden values (exact regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("key", ["moa-off", "cloud-only", "edge-only-hedge",
                                 "moa-off-fail"])
def test_simulator_matches_prerefactor_golden(key):
    """ClusterSimulator metric keys AND values are unchanged for the default
    two-tier config — captured at the pre-refactor commit, including the
    hedged and fault-injected configurations (locks the rng stream too)."""
    entry = json.load(open(GOLDEN))[key]
    cfg = dict(entry["config"])
    policy, n, rate = cfg.pop("policy"), cfg.pop("n"), cfg.pop("rate")
    sim = EdgeCloudSimulator(SimConfig(bandwidth_bps=300e6, seed=0),
                             policy_name=policy, cloud_servers=1,
                             edge_servers=1, **cfg)
    for r in RequestGenerator(seed=0, arrival_rate=rate).generate(n):
        sim.submit(r)
    sim.run()
    m = sim.metrics()
    assert set(m) == set(entry["metrics"])  # keys exactly preserved
    for k, want in entry["metrics"].items():
        assert m[k] == pytest.approx(want, rel=1e-12, abs=1e-12), k


def test_simulator_records_lifecycle_traces():
    sim = EdgeCloudSimulator(SimConfig(seed=0), cloud_servers=1,
                             edge_servers=1)
    reqs = RequestGenerator(seed=0, arrival_rate=2.0).generate(30)
    for r in reqs:
        sim.submit(r)
    sim.run()
    for r in reqs:
        trace = sim.runtime.records[r.rid].trace()
        states = [s for s, _ in trace]
        assert states[0] == "arrival" and states[1] == "routed"
        assert states[-1] == "complete"
        assert "enqueue" in states and "serve" in states


# ---------------------------------------------------------------------------
# live engines fixture
# ---------------------------------------------------------------------------


def _make_server(max_batch=2, max_seq=64, sv=None, **server_kw):
    sv = sv or ServingConfig(max_batch=max_batch, max_seq=max_seq)
    topo = two_tier_topology()
    return ClusterServer(build_cluster_engines(topo, sv), topology=topo,
                         **server_kw)


# ---------------------------------------------------------------------------
# sim-vs-live parity: same workload, same decisions, same lifecycle
# ---------------------------------------------------------------------------


def test_sim_and_live_agree_on_routing_and_lifecycle():
    """The same workload through the AnalyticBackend and the LiveBackend
    produces identical scoring + routing decisions and the same lifecycle
    event sequence per request (timing aside)."""
    pol_cfg = PolicyConfig(adaptive_tau=False)
    topo = two_tier_topology()
    server = _make_server(scheduler=MoAOffScheduler(
        policy=make_policy("moa-off", pol_cfg, topology=topo)))
    sim = ClusterSimulator(SimConfig(seed=0), policy_cfg=pol_cfg,
                           topology=two_tier_topology())
    rng = np.random.default_rng(0)
    live_reqs, sim_reqs = [], []
    for i, u in enumerate([0.05, 0.95, 0.4, 0.8, 0.15]):
        req = server.build_request(
            f"Describe scene {i}. " + "and explain the Details here. "
            * int(u * 20), image=make_image(rng, u, 48, 48), max_new=4)
        sim_req = copy.deepcopy(req)
        sim_req.arrival_s = 1000.0 * (i + 1)  # idle at every virtual arrival
        live_reqs.append(req)
        sim_reqs.append(sim_req)
        # live requests run one at a time so both backends see an idle
        # cluster at each arrival (identical EWMA state => decisions are
        # comparable rather than load-path-dependent)
        server.submit_request(req)
        server.run()
    for r in sim_reqs:
        sim.submit(r)
    sim.run()

    assert len(server.results) == len(sim.outcomes) == 5
    sim_out = {o.rid: o for o in sim.outcomes}
    for res in server.results:
        assert res.routes == sim_out[res.rid].routes  # identical decisions
        assert res.tier == sim_out[res.rid].served_tier
    for r in live_reqs:
        live_trace = server.runtime.records[r.rid].trace()
        sim_trace = sim.runtime.records[r.rid].trace()
        assert live_trace == sim_trace  # identical lifecycle, timing aside
    # streaming bookkeeping exists on the live side
    assert all(res.ttft_s > 0 for res in server.results)
    assert {r.tier for r in server.results} == {"edge", "cloud"}


def test_sim_and_live_agree_on_replicated_topology():
    """A topology whose edge tier declares servers=2 runs as a TWO-replica
    engine pool on the live side and as two parallel FIFO servers in the
    analytic backend — same workload, identical routing decisions and
    lifecycle traces (replication changes capacity, never decisions)."""
    pol_cfg = PolicyConfig(adaptive_tau=False)
    topo = two_tier_topology(edge_servers=2)
    pools = build_engine_pools(topo, ServingConfig(max_batch=2, max_seq=64))
    assert len(pools["edge"]) == 2 and len(pools["cloud"]) == 1
    server = ClusterServer(pools, topology=topo, scheduler=MoAOffScheduler(
        policy=make_policy("moa-off", pol_cfg, topology=topo)))
    sim = ClusterSimulator(SimConfig(seed=0), policy_cfg=pol_cfg,
                           topology=two_tier_topology(edge_servers=2))
    rng = np.random.default_rng(0)
    live_reqs, sim_reqs = [], []
    for i, u in enumerate([0.05, 0.95, 0.4, 0.8, 0.15]):
        req = server.build_request(
            f"Describe scene {i}. " + "and explain the Details here. "
            * int(u * 20), image=make_image(rng, u, 48, 48), max_new=4)
        sim_req = copy.deepcopy(req)
        sim_req.arrival_s = 1000.0 * (i + 1)  # idle at every virtual arrival
        live_reqs.append(req)
        sim_reqs.append(sim_req)
        server.submit_request(req)
        server.run()
    for r in sim_reqs:
        sim.submit(r)
    sim.run()

    sim_out = {o.rid: o for o in sim.outcomes}
    for res in server.results:
        assert res.routes == sim_out[res.rid].routes
        assert res.tier == sim_out[res.rid].served_tier
    for r in live_reqs:
        assert (server.runtime.records[r.rid].trace()
                == sim.runtime.records[r.rid].trace())
    assert {r.tier for r in server.results} == {"edge", "cloud"}


def test_sim_and_live_agree_on_speculative_lifecycle():
    """Cross-tier speculative decoding through both backends: the same
    cloud-fused request speculates (edge drafts, cloud verifies) and emits
    the SAME draft/verify/accept lifecycle marks, with non-trivial
    drafted/accepted token accounting on both sides."""
    from repro.config import SpecConfig

    pol_cfg = PolicyConfig(adaptive_tau=False)
    topo = two_tier_topology()
    spec = SpecConfig(draft_tier="edge", target_tier="cloud", draft_k=4)
    server = _make_server(
        max_seq=96,
        scheduler=MoAOffScheduler(
            policy=make_policy("moa-off", pol_cfg, topology=topo)),
        spec=spec)
    sim = ClusterSimulator(SimConfig(seed=0), policy_cfg=pol_cfg,
                           topology=two_tier_topology(), spec=spec)
    # heavy text complexity forces cloud fusion => the speculate gate opens
    req = server.build_request("please Summarize this corpus now. " * 3,
                               max_new=12,
                               complexity={"text": 0.95})
    sim_req = copy.deepcopy(req)
    sim_req.arrival_s = 5.0
    server.submit_request(req)
    server.run()
    sim.submit(sim_req)
    sim.run()

    (live,) = server.runtime.outcomes
    (ana,) = sim.outcomes
    assert live.routes == ana.routes == {"text": "cloud"}
    assert live.served_tier == ana.served_tier == "cloud"
    lt = server.runtime.records[req.rid].trace()
    at = sim.runtime.records[req.rid].trace()
    assert lt == at  # identical lifecycle incl. speculation, timing aside
    for mark in (("draft", "edge"), ("verify", "cloud"),
                 ("accept", "cloud")):
        assert mark in lt
    # both backends account real draft traffic and the scheduler heard it
    for out in (live, ana):
        assert out.drafted_tokens > 0
        assert 0 <= out.accepted_tokens <= out.drafted_tokens
    assert server.scheduler.estimator.snapshot().spec_accept is not None
    assert sim.scheduler.estimator.snapshot().spec_accept is not None
    # the edge drafted for real on the live side: counters moved there
    assert server.pools["edge"].counters()["drafted_tokens"] > 0
    assert server.pools["cloud"].counters()["drafted_tokens"] == 0


# ---------------------------------------------------------------------------
# migration lifecycle parity: same workload, same migrate decisions
# ---------------------------------------------------------------------------


from conftest import make_twin_edge_server as _twin_server  # noqa: E402


def _twin_sim(**kw):
    return ClusterSimulator(SimConfig(seed=0),
                            policy_cfg=PolicyConfig(adaptive_tau=False),
                            topology=get_topology("edge-edge-cloud"), **kw)


def _until(trace, state):
    """Trace prefix through the first occurrence of ``state`` (timing after
    the migrate decision — who wins the race — is clock-dependent)."""
    out = []
    for ev in trace:
        out.append(ev)
        if ev[0] == state:
            break
    return tuple(out)


@pytest.mark.slow
def test_sim_and_live_agree_on_hedge_migration():
    """One straggling all-edge request through both backends: identical
    routing, and both decide to hedge-migrate its in-service slot to the
    SAME compatible twin tier (never the incompatible cloud)."""
    sv = ServingConfig(max_batch=2, max_seq=192)
    server = _twin_server(sv, hedge_after_s=0.05, migrate=True)
    req = server.build_request("please describe this Scene in depth. " * 3,
                               max_new=100,
                               complexity={"text": 0.05})
    sim_req = copy.deepcopy(req)
    sim_req.arrival_s = 5.0
    server.submit_request(req)
    server.run()
    sim = _twin_sim(hedge_after_s=0.05, migrate=True)
    sim.submit(sim_req)
    sim.run()

    (live,) = [r for r in server.results if r.rid == req.rid]
    (ana,) = sim.outcomes
    assert live.routes == ana.routes == {"text": "edge"}
    assert live.migrated and ana.migrated
    assert live.migration_bytes > 0 and ana.migration_bytes > 0
    lt = server.runtime.records[req.rid].trace()
    at = sim.runtime.records[req.rid].trace()
    assert _until(lt, "migrate") == _until(at, "migrate")
    assert ("migrate", "edge1") in lt  # compatible twin on BOTH backends


@pytest.mark.slow
def test_sim_and_live_agree_on_preemption_decision():
    """Three staggered all-edge requests: when the third lands, both
    backends observe occupancy 2 on edge, preempt the long in-service
    request and migrate it to the idle twin tier."""
    delays = (0.0, 0.12, 0.24)
    sv = ServingConfig(max_batch=1, max_seq=192)
    server = _twin_server(sv, migrate_threshold=2)
    live_reqs, sim_reqs = [], []
    for i, d in enumerate(delays):
        req = server.build_request(
            f"request number {i} please run now. " * 2,
            max_new=120 if i == 0 else 6, complexity={"text": 0.05},
            delay_s=d)
        sim_req = copy.deepcopy(req)
        sim_req.arrival_s = 5.0 + d
        live_reqs.append(req)
        sim_reqs.append(sim_req)
        server.submit_request(req)
    server.run()
    sim = _twin_sim(migrate_threshold=2)
    for r in sim_reqs:
        sim.submit(r)
    sim.run()

    assert len(server.results) == len(sim.outcomes) == 3
    sim_out = {o.rid: o for o in sim.outcomes}
    for res in server.results:
        assert res.routes == sim_out[res.rid].routes
        assert res.migrated == sim_out[res.rid].migrated
    rid0 = live_reqs[0].rid
    lt = server.runtime.records[rid0].trace()
    at = sim.runtime.records[rid0].trace()
    for trace in (lt, at):
        assert ("preempt", "edge") in trace
        assert ("migrate", "edge1") in trace
    assert _until(lt, "migrate") == _until(at, "migrate")
    # only the long request moved
    assert server.runtime.migrations == sim.runtime.migrations == 1


# ---------------------------------------------------------------------------
# chaos parity: same FaultPlan, same resilience lifecycle on both backends
# ---------------------------------------------------------------------------

# The analytic backend marks "serve" before its fault draw while a live
# crash kills the attempt before any serve mark, so raw traces differ on
# faulted attempts by construction. The resilience machinery itself —
# routing, circuit breaking, retries, degradation, shedding, terminal
# failure — must make IDENTICAL decisions; compare traces filtered to it.
RESILIENCE_STATES = ("arrival", "routed", "degraded", "enqueue", "retry",
                     "quarantine", "shed", "failed", "complete")


def _resil(trace):
    return [ev for ev in trace if ev[0] in RESILIENCE_STATES]


@pytest.mark.slow
def test_sim_and_live_agree_on_chaos_lifecycle():
    """A permanently crashed edge tier under the breaker: both backends
    quarantine edge on the first failure, retry its victim degraded onto
    the same fallback tier, and steer the later arrival around the open
    circuit — identical filtered lifecycle traces."""
    from repro.config import ResilienceConfig
    from repro.serving.faults import FaultEvent, FaultPlan

    plan = FaultPlan([FaultEvent("crash", "edge", t=0.0)])
    res = ResilienceConfig(health=True, quarantine_after=1,
                           probe_after_s=1e9)
    sv = ServingConfig(max_batch=2, max_seq=192, heartbeat_timeout_s=0.0)
    server = _twin_server(sv, fault_plan=plan, resilience=res)
    live_reqs, sim_reqs = [], []
    for i, d in enumerate((0.0, 0.5)):
        req = server.build_request(f"describe scene {i} please now. " * 2,
                                   max_new=6, complexity={"text": 0.05},
                                   delay_s=d)
        sim_req = copy.deepcopy(req)
        sim_req.arrival_s = 5.0 + d
        live_reqs.append(req)
        sim_reqs.append(sim_req)
        server.submit_request(req)
    live = {r.rid: r for r in server.run(timeout_s=60.0)}
    sim = _twin_sim(fault_plan=plan, resilience=res, serving_cfg=sv)
    for r in sim_reqs:
        sim.submit(r)
    ana = {o.rid: o for o in sim.run()}

    assert len(live) == len(ana) == 2
    for rid in live:
        assert not live[rid].failed and not ana[rid].failed
        assert live[rid].routes == ana[rid].routes
        assert live[rid].tier == ana[rid].served_tier
        assert live[rid].retries == ana[rid].retries
        assert live[rid].degraded == ana[rid].degraded
        lt = _resil(server.runtime.records[rid].trace())
        at = _resil(sim.runtime.records[rid].trace())
        assert lt == at, rid
    rid0, rid1 = live_reqs[0].rid, live_reqs[1].rid
    t0 = server.runtime.records[rid0].trace()
    assert ("quarantine", "edge") in t0 and ("retry", "edge") in t0
    assert live[rid0].degraded and live[rid0].tier != "edge"
    # the later arrival is steered around the open circuit: no retries
    assert live[rid1].retries == 0 and live[rid1].tier != "edge"
    assert server.runtime.health.quarantine_count == 1
    assert sim.runtime.health.quarantine_count == 1


@pytest.mark.slow
def test_sim_and_live_agree_on_terminal_failure_lifecycle():
    """Every tier crashed: both backends spend the full retry budget and
    resolve the SAME terminal failed Outcome with identical filtered
    lifecycle traces ending in ``failed``."""
    from repro.serving.faults import FaultEvent, FaultPlan

    plan = FaultPlan([FaultEvent("crash", "edge", t=0.0),
                      FaultEvent("crash", "cloud", t=0.0)])
    sv = ServingConfig(max_batch=2, max_seq=64, heartbeat_timeout_s=0.0)
    pol_cfg = PolicyConfig(adaptive_tau=False)
    topo = two_tier_topology()
    server = _make_server(sv=sv, fault_plan=plan,
                          scheduler=MoAOffScheduler(policy=make_policy(
                              "moa-off", pol_cfg, topology=topo)))
    req = server.build_request("hello there friend.", max_new=4,
                               complexity={"text": 0.05})
    sim_req = copy.deepcopy(req)
    sim_req.arrival_s = 5.0
    server.submit_request(req)
    (live,) = server.run(timeout_s=60.0)
    sim = ClusterSimulator(SimConfig(seed=0), policy_cfg=pol_cfg,
                           topology=two_tier_topology(), fault_plan=plan,
                           serving_cfg=sv)
    sim.submit(sim_req)
    (ana,) = sim.run()

    for out in (live, ana):
        assert out.failed and out.fail_reason == "retries"
        assert out.retries == sv.retry_limit
    lt = _resil(server.runtime.records[req.rid].trace())
    at = _resil(sim.runtime.records[req.rid].trace())
    assert lt == at
    assert lt[-1][0] == "failed"
    assert [s for s, _ in lt].count("retry") == sv.retry_limit


# ---------------------------------------------------------------------------
# live-only capabilities
# ---------------------------------------------------------------------------


def test_live_partial_offload_executes_remote_encode():
    """An image routed off the fusion tier is REALLY encoded by the routed
    tier's engine and its embeddings feed the fusion prefill — generated
    tokens are identical to encoding on the fusion tier itself."""
    # A: image stays on edge, text forces cloud fusion -> edge encodes,
    # cloud fuses with shipped embeddings
    srv_a = _make_server()
    img = make_image(np.random.default_rng(0), 0.5, 48, 48)
    srv_a.submit("Analyze the Chart now please.", image=img, max_new=4,
                 complexity={"image": 0.05, "text": 0.95})
    (res_a,) = srv_a.run()
    assert res_a.routes == {"image": "edge", "text": "cloud"}
    assert res_a.tier == "cloud"
    assert srv_a.engines["edge"].encode_tokens > 0  # encode ran on edge
    assert ("encode:image", "edge") in srv_a.runtime.records[0].trace()
    # the fusion prefill consumed the vision prefix (patches + prompt)
    ncloud = srv_a.engines["cloud"].cfg.num_patches
    assert srv_a.engines["cloud"].prefill_tokens > ncloud
    assert srv_a.engines["cloud"].encode_tokens == 0

    # B: everything on cloud -> fusion-local encode; tokens must match A
    srv_b = _make_server()
    srv_b.submit("Analyze the Chart now please.", image=img, max_new=4,
                 complexity={"image": 0.95, "text": 0.95})
    (res_b,) = srv_b.run()
    assert res_b.routes == {"image": "cloud", "text": "cloud"}
    assert res_b.tokens == res_a.tokens  # embeddings shipped bit-exact


def test_live_hedging_clones_stragglers_and_single_result():
    srv = _make_server(max_batch=1, hedge_after_s=0.01)
    img = make_image(np.random.default_rng(3), 0.2, 48, 48)
    for i in range(3):
        srv.submit(f"please describe this {i}", image=img, max_new=24,
                   complexity={"image": 0.05, "text": 0.05})  # all -> edge
    res = srv.run()
    rids = [r.rid for r in res]
    assert len(rids) == len(set(rids)) == 3  # exactly one result/request
    assert any(r.hedged for r in res)  # queued jobs were hedged
    hedged_rids = [r.rid for r in res if r.hedged]
    for rid in hedged_rids:
        trace = srv.runtime.records[rid].trace()
        assert any(s == "hedged" for s, _ in trace)
    # the losing twin was cancelled (or never ran), not double-reported
    assert all(len(r.tokens) >= 1 for r in res)
    # a hedge clone must NOT drop the image: every engine-side submission of
    # these image-carrying requests carries patch embeddings (a clone with
    # nothing shipped re-encodes on its own engine, like the analytic
    # backend's full-prefill clone accounting)
    for eng in srv.engines.values():
        for op, payload in eng.journal:
            if op == "submit":
                assert "patches" in payload["extras"]


def test_live_fault_recovery_restores_engine_snapshot():
    # a permanently dead node: every attempt faults, the engine is rebuilt
    # from its snapshot each time, and once the retry budget is spent the
    # request resolves into a terminal failed Outcome instead of
    # livelocking the server
    sv = ServingConfig(max_batch=2, max_seq=64, heartbeat_timeout_s=0.0)
    srv = _make_server(sv=sv, fail_rate=1.0)
    for i in range(2):
        srv.submit(f"hello there {i}", max_new=4,
                   complexity={"text": 0.05})
    res = srv.run(timeout_s=60.0)
    assert len(res) == 2
    assert srv.backend.restores >= 1  # recovered via snapshot()/restore()
    for r in res:
        assert r.failed and r.fail_reason == "retries"
        assert r.retries == sv.retry_limit  # budget fully spent first
        trace = srv.runtime.records[r.rid].trace()
        assert any(s == "retry" for s, _ in trace)
        assert trace[-1][0] == "failed"


def test_live_prompt_truncation_is_recorded_not_silent():
    srv = _make_server(max_batch=1, max_seq=48)
    long_text = "word " * 200  # way past the 48-token budget
    srv.submit(long_text, max_new=8, complexity={"text": 0.05})
    srv.submit("short prompt", max_new=8, complexity={"text": 0.05})
    res = {r.rid: r for r in srv.run()}
    assert res[0].truncated and not res[1].truncated
    # the kept prompt uses the REAL budget (max_seq - max_new), not the old
    # silent max_seq // 2 clip
    eng = srv.engines["edge"]
    admitted = [p for op, p in eng.journal if op == "submit"]
    assert len(admitted[0]["tokens"]) == 48 - 8


def test_engine_edf_admission_order():
    cfg = reduced_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    eng = TierEngine(model, model.init(jax.random.PRNGKey(0)),
                     ServingConfig(max_batch=1, max_seq=64))
    prompt = (np.arange(8) % 50 + 4).astype(np.int32)
    eng.submit(0, prompt, max_new=2, deadline=3.0)
    eng.submit(1, prompt, max_new=2, deadline=1.0)
    eng.submit(2, prompt, max_new=2, deadline=2.0)
    eng.run_until_drained()
    admits = [p["rid"] for op, p in eng.journal if op == "admit"]
    assert admits == [1, 2, 0]  # earliest deadline first


def test_engine_cancel_frees_waiting_and_slot():
    cfg = reduced_config("qwen3-0.6b").replace(dtype="float32")
    model = build_model(cfg)
    eng = TierEngine(model, model.init(jax.random.PRNGKey(0)),
                     ServingConfig(max_batch=1, max_seq=64))
    prompt = (np.arange(8) % 50 + 4).astype(np.int32)
    eng.submit(0, prompt, max_new=32)
    eng.submit(1, prompt, max_new=32)
    eng.step()  # admits rid 0 into the slot; rid 1 waits
    assert eng.cancel(1)  # waiting
    assert eng.cancel(0)  # mid-decode slot
    assert not eng.cancel(7)
    assert eng.waiting == [] and all(s is None for s in eng.slots)
    assert eng.run_until_drained() == []  # nothing resurrects


# ---------------------------------------------------------------------------
# scheduler.observe: dict API + deprecated scalar shim
# ---------------------------------------------------------------------------


def test_observe_scalar_shim_is_deprecated_but_equivalent():
    new = MoAOffScheduler()
    old = MoAOffScheduler()
    new.observe(loads={"edge": 0.6, "cloud": 0.2}, bandwidth_bps=2e8)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        old.observe(edge_load=0.6, cloud_load=0.2, bandwidth_bps=2e8)
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    sn, so = new.estimator.snapshot(), old.estimator.snapshot()
    assert sn.loads == so.loads
    assert sn.bandwidth_bps == so.bandwidth_bps


def test_observe_dict_api_feeds_all_estimator_fields():
    s = MoAOffScheduler()
    s.observe(loads={"edge": 1.0, "regional": 0.5},
              queue_depths={"edge": 3},
              bandwidths={"cloud": 1e8}, bandwidth_bps=2e8, latency_s=0.5)
    st = s.estimator.snapshot()
    assert st.loads["edge"] > 0 and st.loads["regional"] > 0
    assert st.queue_depth("edge") == 3
    assert st.bandwidths["cloud"] == pytest.approx(1e8)
    assert s.estimator.p95_latency() == pytest.approx(0.5)


@pytest.mark.slow
def test_sim_and_live_agree_on_byzantine_storm():
    """Byzantine wires through BOTH backends: every migration payload is
    corrupted in flight (p=1.0 — the CRC rejects it and the clone falls
    back to a fresh prefill) while the live replicas' event streams
    additionally suffer dup/drop chaos the analytic backend has no wires
    for. Per-(kind, link) counter hashing keeps the shared migrate-link
    decisions identical even though the live side draws extra per-frame
    fates on its events links — so the resilience-filtered lifecycle
    traces match event for event, both runs audit clean, and both count
    the SAME detected corruption."""
    from repro.serving.faults import FaultEvent, FaultPlan

    plan = FaultPlan([
        FaultEvent("corrupt", "migrate:edge1", magnitude=1.0),
        FaultEvent("msg_dup", "events:edge", magnitude=0.3),
        FaultEvent("msg_drop", "events:edge", magnitude=0.2),
    ], wire_seed=21)
    sv = ServingConfig(max_batch=2, max_seq=192)
    server = _twin_server(sv, hedge_after_s=0.05, migrate=True,
                          fault_plan=plan, audit=True)
    req = server.build_request("please describe this Scene in depth. " * 3,
                               max_new=100, complexity={"text": 0.05})
    sim_req = copy.deepcopy(req)
    sim_req.arrival_s = 5.0
    server.submit_request(req)
    server.run(timeout_s=120.0)
    sim = _twin_sim(hedge_after_s=0.05, migrate=True, fault_plan=plan,
                    audit=True)
    sim.submit(sim_req)
    sim.run()

    (live,) = [r for r in server.results if r.rid == req.rid]
    (ana,) = sim.outcomes
    # the corrupted migration was rejected on both sides: the request
    # still completes, but NOT as a migration (re-prefill fallback)
    assert not live.failed and not ana.failed
    assert not live.migrated and not ana.migrated
    lt = _resil(server.runtime.records[req.rid].trace())
    at = _resil(sim.runtime.records[req.rid].trace())
    assert lt == at
    for ws in (server.runtime.wire_stats, sim.runtime.wire_stats):
        assert ws.get("corrupt_injected", 0) >= 1
        assert ws.get("corrupt_detected", 0) == ws.get("corrupt_injected")
        assert ws.get("corrupt_undetected", 0) == 0
    # identical migrate-link decisions despite the live-only event chaos
    assert (server.runtime.wire_stats["corrupt_detected"]
            == sim.runtime.wire_stats["corrupt_detected"])
    # the live event streams really were attacked — and healed
    assert server.runtime.wire_stats.get("dups_suppressed", 0) > 0
    for rt in (server.runtime, sim.runtime):
        verdict = rt.auditor.last
        assert verdict["clean"], verdict["violations"]
