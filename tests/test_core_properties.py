"""Hypothesis property tests on the MoA-Off core invariants."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.config import ComplexityConfig, PolicyConfig
from repro.core import (CLOUD, EDGE, MoAOffScheduler, ModalityInput,
                        OffloadingPolicy, Request, SystemState,
                        decide_modality, make_policy,
                        text_complexity_from_counts)

# ---------------------------------------------------------------------------
# complexity invariants
# ---------------------------------------------------------------------------


@given(tokens=st.integers(0, 100_000), ents=st.integers(0, 10_000),
       sents=st.integers(1, 1_000))
@settings(max_examples=200, deadline=None)
def test_text_complexity_bounded(tokens, ents, sents):
    out = text_complexity_from_counts(tokens, ents, sents)
    for k in ("c_len", "c_ner", "c_text"):
        assert 0.0 <= float(out[k]) <= 1.0


@given(tokens=st.integers(0, 5_000), extra=st.integers(1, 5_000))
@settings(max_examples=100, deadline=None)
def test_text_complexity_monotone_in_length(tokens, extra):
    a = float(text_complexity_from_counts(tokens, 0, 1)["c_text"])
    b = float(text_complexity_from_counts(tokens + extra, 0, 1)["c_text"])
    assert b >= a - 1e-9


@given(ents=st.integers(0, 100), extra=st.integers(1, 100),
       sents=st.integers(1, 50))
@settings(max_examples=100, deadline=None)
def test_text_complexity_monotone_in_entities(ents, extra, sents):
    a = float(text_complexity_from_counts(512, ents, sents)["c_text"])
    b = float(text_complexity_from_counts(512, ents + extra, sents)["c_text"])
    assert b >= a - 1e-9


# ---------------------------------------------------------------------------
# Eq. 5 policy invariants
# ---------------------------------------------------------------------------

_state = st.builds(
    SystemState,
    edge_load=st.floats(0, 1),
    bandwidth_bps=st.floats(1e6, 1e9),
    cloud_load=st.floats(0, 1),
)


@given(c=st.floats(0, 1), tau=st.floats(0, 1), state=_state)
@settings(max_examples=300, deadline=None)
def test_eq5_literal_semantics(c, tau, state):
    pol = PolicyConfig(paper_faithful_bandwidth=True)
    d = decide_modality(c, tau, state, pol)
    expect_edge = (c <= tau and state.edge_load <= pol.edge_load_max
                   and state.bandwidth_bps <= pol.bandwidth_beta)
    assert d == (EDGE if expect_edge else CLOUD)


@given(c=st.floats(0, 1), state=_state)
@settings(max_examples=200, deadline=None)
def test_eq5_complexity_monotone(c, state):
    """If c routes to cloud at threshold τ, any c' > c also routes cloud."""
    pol = PolicyConfig()
    tau = 0.5
    d1 = decide_modality(c, tau, state, pol)
    if d1 == CLOUD and c <= tau:
        # cloud due to system state: all complexities go cloud
        assert decide_modality(min(1.0, c + 0.3), tau, state, pol) == CLOUD
    if d1 == EDGE:
        assert decide_modality(max(0.0, c - 0.3), tau, state, pol) == EDGE


@given(scores=st.dictionaries(
    st.sampled_from(["image", "text", "audio"]),
    st.floats(0, 1), min_size=1, max_size=3), state=_state)
@settings(max_examples=200, deadline=None)
def test_decision_vector_complete_and_valid(scores, state):
    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=False))
    req = Request(rid=0, arrival_s=0.0, modalities={})
    d = pol.decide(req, scores, state)
    assert set(d.routes) == set(scores)
    assert all(r in (EDGE, CLOUD) for r in d.routes.values())


@given(state=_state)
@settings(max_examples=50, deadline=None)
def test_policy_determinism(state):
    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=False))
    req = Request(rid=0, arrival_s=0.0, modalities={})
    scores = {"image": 0.7, "text": 0.2}
    d1 = pol.decide(req, scores, state)
    d2 = pol.decide(req, scores, state)
    assert d1.routes == d2.routes


def test_adaptive_tau_balances_queues():
    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=True))
    edge_hot = SystemState(edge_load=0.5, bandwidth_bps=3e8,
                           queue_depth_edge=12, queue_depth_cloud=0)
    t0 = dict(pol.taus)
    for _ in range(10):
        pol.update(edge_hot)
    assert all(pol.taus[m] < t0[m] for m in t0)  # shed load from edge
    cloud_hot = SystemState(edge_load=0.1, bandwidth_bps=3e8,
                            queue_depth_edge=0, queue_depth_cloud=12)
    t1 = dict(pol.taus)
    for _ in range(10):
        pol.update(cloud_hot)
    assert all(pol.taus[m] > t1[m] for m in t1)  # pull load back


def test_adaptive_tau_steady_at_balance():
    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=True))
    steady = SystemState(edge_load=0.4, bandwidth_bps=3e8,
                         queue_depth_edge=2, queue_depth_cloud=2)
    t0 = dict(pol.taus)
    for _ in range(10):
        pol.update(steady)
    assert pol.taus == t0  # balanced queues: no thrash


def test_tau_stays_clamped():
    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=True))
    hot = SystemState(edge_load=1.0, bandwidth_bps=3e8,
                      queue_depth_edge=50, queue_depth_cloud=0)
    for _ in range(200):
        pol.update(hot)
    assert all(0.05 <= v <= 0.95 for v in pol.taus.values())


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


@given(scores=st.dictionaries(st.sampled_from(["image", "text"]),
                              st.floats(0, 1), min_size=1), state=_state)
@settings(max_examples=100, deadline=None)
def test_single_tier_baselines(scores, state):
    req = Request(rid=0, arrival_s=0.0, modalities={})
    assert all(r == CLOUD for r in make_policy("cloud-only")
               .decide(req, scores, state).routes.values())
    assert all(r == EDGE for r in make_policy("edge-only")
               .decide(req, scores, state).routes.values())


@given(scores=st.dictionaries(st.sampled_from(["image", "text"]),
                              st.floats(0, 1), min_size=2, max_size=2),
       state=_state)
@settings(max_examples=100, deadline=None)
def test_perllm_and_ablation_are_uniform(scores, state):
    """Modality-blind policies must give the SAME route to all modalities."""
    req = Request(rid=0, arrival_s=0.0, modalities={})
    for name in ("perllm", "moa-off-no-modality"):
        routes = make_policy(name).decide(req, scores, state).routes
        assert len(set(routes.values())) == 1, name


def test_moa_off_splits_heterogeneous_request():
    """The paper's Fig. 2 example: complex image -> cloud, short text -> edge."""
    pol = OffloadingPolicy(PolicyConfig(adaptive_tau=False))
    st_ = SystemState(edge_load=0.3, bandwidth_bps=3e8)
    req = Request(rid=0, arrival_s=0.0, modalities={})
    d = pol.decide(req, {"image": 0.9, "text": 0.1}, st_)
    assert d.routes["image"] == CLOUD and d.routes["text"] == EDGE


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------


def test_scheduler_scores_real_payloads():
    rng = np.random.default_rng(0)
    sched = MoAOffScheduler(use_kernel=True)
    img = rng.uniform(0, 255, (48, 48)).astype(np.float32)
    req = Request(rid=1, arrival_s=0.0, modalities={
        "image": ModalityInput("image", data=img),
        "text": ModalityInput("text",
                              meta={"tokens": 900, "entities": 40,
                                    "sentences": 10}),
    })
    scores = sched.score(req)
    assert set(scores) == {"image", "text"}
    assert all(0 <= v <= 1 for v in scores.values())
    d = sched.route(req)
    assert set(d.routes) == {"image", "text"}
