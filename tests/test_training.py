"""Training substrate: optimizer math, checkpoint round-trip (incl. elastic
restore), trainer resume, gradient accumulation equivalence, preemption."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.config import OptimizerConfig, ShardingConfig, TrainConfig
from repro.configs import get_config
from repro.models import build_model
from repro.training import (Trainer, adamw_update, init_opt_state,
                            lr_schedule, make_train_step)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 * (1 + 1e-5)  # warmup rises to peak
    assert lrs[99] < lrs[50] < lrs[12]  # cosine decays
    assert all(l > 0 for l in lrs)


def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_grad_clip_applied():
    cfg = OptimizerConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params, cfg)
    _, _, m = adamw_update(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_grad_accum_matches_full_batch():
    cfg = get_config("tiny-dense").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=0)
    opt = init_opt_state(params, ocfg)
    rng = np.random.default_rng(0)
    tok = rng.integers(4, cfg.vocab_size, (8, 33)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok[:, :-1]), "labels": jnp.asarray(tok[:, 1:])}
    p1, _, m1 = make_train_step(model, ocfg, accum=1)(params, opt, batch)
    p4, _, m4 = make_train_step(model, ocfg, accum=4)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-4)
    # gradients agree to fp32 reduction-order noise (a full-batch backprop
    # sums dW inside one matmul; accumulation sums 4 partial matmuls)...
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-4)
    # ...but step-1 Adam normalizes each update toward lr*sign(g), so for
    # eps-scale gradient entries that fp noise is amplified to a few percent
    # of the update. Compare params with atol = 10% of one lr-sized step
    # instead of a bare rtol — tight enough to catch any real accumulation
    # bug (wrong scaling is a >=25% error), immune to reduction order.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


@pytest.fixture()
def ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")


def test_checkpoint_roundtrip_dtypes(ckpt_dir):
    ck = Checkpointer(ckpt_dir)
    tree = {"a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.asarray(2.5, jnp.float32)}}
    ck.save(3, tree, async_=False)
    step, out = ck.restore(tree)
    assert step == 3
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_gc_keeps_latest(ckpt_dir):
    ck = Checkpointer(ckpt_dir, keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, async_=False)
    assert ck.list_steps() == [3, 4]


def test_checkpoint_async_then_wait(ckpt_dir):
    ck = Checkpointer(ckpt_dir)
    ck.save(1, {"x": jnp.ones(128)}, async_=True)
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_atomic_no_partial_on_existing(ckpt_dir):
    """A re-save of the same step replaces atomically (rename semantics)."""
    ck = Checkpointer(ckpt_dir)
    ck.save(5, {"x": jnp.zeros(4)}, async_=False)
    ck.save(5, {"x": jnp.ones(4)}, async_=False)
    _, out = ck.restore({"x": jnp.zeros(4)}, step=5)
    np.testing.assert_array_equal(out["x"], np.ones(4))


def test_trainer_resume_continues(ckpt_dir):
    cfg = TrainConfig(model="tiny-dense", batch_size=4, seq_len=32, steps=12,
                      log_every=6, checkpoint_every=6, checkpoint_dir=ckpt_dir,
                      optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                                total_steps=50))
    t1 = Trainer(cfg)
    t1.train()
    assert t1.step == 12
    t2 = Trainer(cfg)
    t2.initialize()
    assert t2.step == 12
    t2.train(steps=6)
    assert t2.step == 18


def test_trainer_deterministic_data_skip(ckpt_dir):
    """Resume consumes exactly the batches an uninterrupted run would."""
    cfg = TrainConfig(model="tiny-dense", batch_size=2, seq_len=16, steps=4,
                      log_every=100, checkpoint_every=100,
                      checkpoint_dir=ckpt_dir)
    t = Trainer(cfg)
    b2 = t._batch(2)
    b2_again = Trainer(cfg)._batch(2)
    np.testing.assert_array_equal(b2["tokens"], b2_again["tokens"])


# ---------------------------------------------------------------------------
# elastic restore (different "mesh" = plain single-device here)
# ---------------------------------------------------------------------------


def test_elastic_restore_onto_current_devices(ckpt_dir):
    from repro.checkpoint import elastic_restore_tree

    cfg = get_config("tiny-dense").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    ck = Checkpointer(ckpt_dir)
    ck.save(7, {"params": params}, async_=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step, out = elastic_restore_tree(ck, {"params": params},
                                     {"params": model.specs()}, mesh)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault-tolerance coordination
# ---------------------------------------------------------------------------


def test_heartbeat_recovery_policy():
    from repro.distributed.fault_tolerance import (HeartbeatRegistry,
                                                   RecoveryCoordinator)

    clock = [0.0]
    reg = HeartbeatRegistry(timeout_s=5.0, clock=lambda: clock[0])
    for w in ("pod0", "pod1", "pod2"):
        reg.register(w)
    coord = RecoveryCoordinator(reg, min_workers=2, spares=["spare0"])
    clock[0] = 3.0
    reg.beat("pod0")
    reg.beat("pod1")
    clock[0] = 6.0  # pod2 missed deadline
    evs = coord.tick()
    assert len(evs) == 1 and evs[0].action == "spare_swap"
    clock[0] = 20.0  # everyone stale now; no spares left
    evs = coord.tick()
    actions = {e.action for e in evs}
    assert "elastic_downsize" in actions or "restart" in actions
