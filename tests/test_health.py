"""Tier health & graceful degradation: the circuit-breaker state machine,
deterministic retry backoff, the scheduler/policy health plumbing, and the
runtime behaviors they gate — terminal failures at the retry budget,
degraded re-routing off quarantined tiers, deadline-aware shedding,
transfer timeouts under a partitioned link, partial results, and parked-
session rescue — on the analytic backend (the live mirrors live in
``test_runtime_parity.py`` / ``test_migration.py``)."""
import numpy as np
import pytest

from repro.config import (PolicyConfig, ResilienceConfig, ServingConfig,
                          SimConfig, get_topology, two_tier_topology)
from repro.core.baselines import make_policy
from repro.core.request import ModalityInput, Request
from repro.core.scheduler import MoAOffScheduler
from repro.core.state import SystemState
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.health import (HEALTHY, PROBING, QUARANTINED, SUSPECT,
                                  HealthMonitor, retry_backoff_s)
from repro.serving.simulator import ClusterSimulator

CFG = ResilienceConfig(health=True, suspect_after=1, quarantine_after=3,
                       probe_after_s=5.0)


# ---------------------------------------------------------------------------
# the state machine itself
# ---------------------------------------------------------------------------


def test_state_machine_walk():
    hm = HealthMonitor(["edge", "cloud"], CFG)
    assert hm.state("edge") == HEALTHY
    assert not hm.record_failure("edge", 0.0)
    assert hm.state("edge") == SUSPECT
    assert not hm.record_failure("edge", 0.1)
    assert hm.record_failure("edge", 0.2)  # third failure opens the circuit
    assert hm.state("edge") == QUARANTINED and hm.quarantine_count == 1
    assert hm.state("cloud") == HEALTHY  # untouched
    # during the cool-down: refused, no probe slot consumed
    assert not hm.available("edge", 1.0) and not hm.admit("edge", 1.0)
    # past the cool-down: available is pure, admit consumes THE probe
    assert hm.available("edge", 6.0) and hm.available("edge", 6.0)
    assert hm.admit("edge", 6.0)
    assert hm.state("edge") == PROBING and hm.probe_count == 1
    assert not hm.admit("edge", 6.1)  # one probe at a time
    assert not hm.available("edge", 6.1)
    hm.record_success("edge")  # the probe came back: circuit closes
    assert hm.state("edge") == HEALTHY


def test_failed_probe_reopens_circuit_and_restarts_cooldown():
    hm = HealthMonitor(["edge"], CFG)
    for _ in range(3):
        hm.record_failure("edge", 0.0)
    assert hm.admit("edge", 10.0)  # the probe
    assert hm.record_failure("edge", 10.0)  # probe died: re-open (rescue cue)
    assert hm.state("edge") == QUARANTINED and hm.quarantine_count == 2
    assert not hm.admit("edge", 11.0)  # cool-down restarted at t=10
    assert hm.admit("edge", 15.0)


def test_success_heals_suspect_and_decays_ewma():
    hm = HealthMonitor(["edge"], CFG)
    hm.record_failure("edge", 0.0)
    ewma = hm.tiers["edge"].failure_ewma
    assert hm.state("edge") == SUSPECT and ewma > 0
    hm.record_success("edge")
    assert hm.state("edge") == HEALTHY
    assert hm.tiers["edge"].failure_ewma < ewma
    assert hm.tiers["edge"].consecutive_failures == 0
    # quarantined tiers are NOT healed by unrelated successes
    for _ in range(3):
        hm.record_failure("edge", 1.0)
    hm.record_success("edge")
    assert hm.state("edge") == QUARANTINED


def test_stale_heartbeat_marks_suspect_but_never_quarantines():
    hm = HealthMonitor(["edge"], CFG)
    for _ in range(10):
        hm.heartbeat("edge", ok=False)
    assert hm.state("edge") == SUSPECT
    assert hm.quarantine_count == 0  # only real failures open the circuit
    hm.heartbeat("edge", ok=True)
    hm.record_success("edge")
    assert hm.state("edge") == HEALTHY


def test_unknown_tier_is_harmless():
    hm = HealthMonitor(["edge"], CFG)
    assert not hm.record_failure("ghost", 0.0)
    hm.record_success("ghost")
    hm.heartbeat("ghost", ok=False)
    assert hm.state("ghost") == HEALTHY
    assert hm.available("ghost", 0.0) and hm.admit("ghost", 0.0)


def test_retry_backoff_is_deterministic_exponential_capped():
    cfg = ResilienceConfig(backoff_base_s=0.25, backoff_cap_s=4.0,
                           backoff_jitter=0.25)
    d1 = retry_backoff_s(cfg, rid=7, attempt=1)
    assert d1 == retry_backoff_s(cfg, rid=7, attempt=1)  # pure, no rng
    assert 0.25 <= d1 <= 0.25 * 1.25  # base * (1 + jitter)
    assert retry_backoff_s(cfg, rid=7, attempt=2) > d1  # exponential
    assert retry_backoff_s(cfg, rid=7, attempt=12) <= 4.0 * 1.25  # capped
    assert retry_backoff_s(cfg, rid=8, attempt=1) != d1  # per-rid jitter
    flat = ResilienceConfig(backoff_base_s=0.5, backoff_jitter=0.0)
    assert retry_backoff_s(flat, rid=99, attempt=1) == 0.5


# ---------------------------------------------------------------------------
# scheduler & policy plumbing
# ---------------------------------------------------------------------------


def test_system_state_health_gate_and_estimator_plumbing():
    s = SystemState()
    s.health = {"edge": "quarantined", "cloud": "probing",
                "edge1": "suspect"}
    assert not s.healthy("edge") and not s.healthy("cloud")
    assert s.healthy("edge1")  # suspect stays routable
    assert s.healthy("unknown")
    sched = MoAOffScheduler()
    sched.observe(health={"edge": "quarantined"})
    assert sched.estimator.snapshot().health == {"edge": "quarantined"}


def test_policy_routes_around_quarantined_tier():
    topo = get_topology("edge-edge-cloud")
    sched = MoAOffScheduler(policy=make_policy(
        "moa-off", PolicyConfig(adaptive_tau=False), topology=topo))
    req = _easy_req(0, 0.0)
    before = sched.route(req).routes["text"]
    assert before in ("edge", "edge1")  # easy text stays local
    sched.observe(health={before: "quarantined"})
    after = sched.route(req).routes["text"]
    assert after != before  # steered around the open circuit
    # everything quarantined: routing falls back to the full pool rather
    # than deadlocking
    sched.observe(health={t.name: "quarantined" for t in topo.tiers})
    assert sched.route(req).routes["text"] in topo.names


# ---------------------------------------------------------------------------
# runtime behaviors (analytic backend: virtual clock, deterministic)
# ---------------------------------------------------------------------------


def _easy_req(rid, t, tokens=32, decode=8, slo=30.0, session=None, cx=0.05):
    return Request(rid=rid, arrival_s=t, decode_tokens=decode, slo_s=slo,
                   session=session, modalities={
                       "text": ModalityInput("text", complexity=cx,
                                             size_bytes=tokens * 4,
                                             meta={"tokens": tokens,
                                                   "entities": 0,
                                                   "sentences": 1})})


def test_analytic_terminal_failure_at_retry_budget():
    sim = ClusterSimulator(SimConfig(seed=0), fail_rate=1.0,
                           cloud_servers=1, edge_servers=1,
                           serving_cfg=ServingConfig(retry_limit=2))
    sim.submit(_easy_req(0, 0.0))
    (out,) = sim.run()
    assert out.failed and out.fail_reason == "retries"
    assert out.retries == 2 and not out.correct and not out.on_time
    states = [s for s, _ in sim.runtime.records[0].trace()]
    assert states.count("retry") == 2
    assert states[-1] == "failed"
    m = sim.metrics()
    assert m["failed"] == 1.0 and m["goodput"] == 0.0


def test_analytic_quarantine_reroutes_and_degrades():
    """Permanently crashed edge tier with the breaker on: the first failure
    opens the circuit, its victim retries degraded on the best surviving
    tier, and LATER arrivals are steered around the quarantined tier by the
    health-aware policy — the storm is fully survivable."""
    plan = FaultPlan([FaultEvent("crash", "edge", t=0.0)])
    res = ResilienceConfig(health=True, quarantine_after=1,
                           probe_after_s=1e9)
    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=get_topology("edge-edge-cloud"),
                           fault_plan=plan, resilience=res)
    for i in range(4):
        sim.submit(_easy_req(i, 1.0 + 10.0 * i))
    outs = {o.rid: o for o in sim.run()}
    assert len(outs) == 4
    assert all(not o.failed for o in outs.values())
    first = next(o for o in outs.values() if o.retries > 0)
    assert first.degraded and first.served_tier != "edge"
    tr = sim.runtime.records[first.rid].trace()
    assert ("quarantine", "edge") in tr and ("retry", "edge") in tr
    assert ("degraded", first.served_tier) in tr
    # arrivals after the quarantine never touch edge and pay no retries
    late = [o for o in outs.values() if o.rid > first.rid]
    assert late and all(o.retries == 0 and o.served_tier != "edge"
                        for o in late)
    assert sim.runtime.health.quarantine_count == 1
    m = sim.metrics()
    assert m["quarantines"] == 1.0 and m["degraded"] >= 0.25
    assert m["failed"] == 0.0


def test_analytic_shed_on_hopeless_retry():
    res = ResilienceConfig(shed=True)
    sim = ClusterSimulator(SimConfig(seed=0), fail_rate=1.0,
                           cloud_servers=1, edge_servers=1, resilience=res)
    # the fault is detected after the 2 s heartbeat — already past this SLO,
    # so the first retry is provably hopeless and the request sheds
    sim.submit(_easy_req(0, 0.0, slo=1.0))
    (out,) = sim.run()
    assert out.failed and out.fail_reason == "shed"
    assert sim.runtime.records[0].trace()[-1][0] == "shed"
    m = sim.metrics()
    assert m["shed"] == 1.0 and m["failed"] == 0.0  # shed ≠ retry-exhausted


def test_backoff_delays_analytic_retries():
    def failed_latency(backoff):
        res = ResilienceConfig(retry_backoff=backoff, backoff_base_s=0.5,
                               backoff_jitter=0.0)
        sim = ClusterSimulator(SimConfig(seed=0), fail_rate=1.0,
                               cloud_servers=1, edge_servers=1,
                               resilience=res,
                               serving_cfg=ServingConfig(retry_limit=2))
        sim.submit(_easy_req(0, 0.0))
        (out,) = sim.run()
        assert out.failed
        return out.latency_s

    # two retries back off 0.5 s then 1.0 s; the jitter-free delta is exact
    assert failed_latency(True) == pytest.approx(
        failed_latency(False) + 1.5)


def test_analytic_partition_transfer_timeout_spends_retry():
    plan = FaultPlan([FaultEvent("degrade", "cloud", t=0.0, magnitude=0.0)])
    res = ResilienceConfig(transfer_timeout_s=0.5)
    sim = ClusterSimulator(SimConfig(seed=0), cloud_servers=1,
                           edge_servers=1, fault_plan=plan, resilience=res)
    # hard request: routed to cloud, its payload crosses the dead link
    sim.submit(_easy_req(0, 0.0, tokens=96, cx=0.95))
    (out,) = sim.run()
    tr = sim.runtime.records[0].trace()
    assert ("timeout", "cloud") in tr
    assert out.retries >= 1
    # the wedged link server was released (no leak)
    assert sim.links["cloud"].busy == 0


def test_analytic_partition_without_timeout_returns_partial_results():
    plan = FaultPlan([FaultEvent("degrade", "cloud", t=0.0, magnitude=0.0)])
    sim = ClusterSimulator(SimConfig(seed=0), cloud_servers=1,
                           edge_servers=1, fault_plan=plan)
    sim.submit(_easy_req(0, 0.0))  # edge-local: completes
    sim.submit(_easy_req(1, 0.0, tokens=96, cx=0.95))  # black-holed
    outs = sim.run()
    assert [o.rid for o in outs] == [0]  # partial, not a hang


def test_analytic_session_rescue_off_quarantined_tier():
    """A parked chat session survives its tier's quarantine: the circuit-
    open transition ships the parked KV to the compatible twin, and the
    next turn resumes warm THERE."""
    plan = FaultPlan([FaultEvent("crash", "edge", t=5.0)])
    res = ResilienceConfig(health=True, quarantine_after=1,
                           probe_after_s=1e9)
    sim = ClusterSimulator(SimConfig(seed=0),
                           policy_cfg=PolicyConfig(adaptive_tau=False),
                           topology=get_topology("edge-edge-cloud"),
                           sessions=True, fault_plan=plan, resilience=res)
    sim.submit(_easy_req(0, 1.0, tokens=32, session="s"))  # parks on edge
    sim.submit(_easy_req(1, 10.0))  # crashes -> quarantines edge
    sim.submit(_easy_req(2, 50.0, tokens=96, session="s"))  # warm turn 2
    outs = {o.rid: o for o in sim.run()}
    assert outs[0].served_tier == "edge"
    assert ("quarantine", "edge") in sim.runtime.records[1].trace()
    assert sim.runtime.rescued_sessions == 1
    t2 = sim.runtime.records[2].trace()
    assert outs[2].warm == "resume"
    assert outs[2].served_tier == "edge1"  # resumed where the KV was shipped
    assert ("sticky", "edge1") in t2 and ("resume", "edge1") in t2
    assert sim.metrics()["rescued_sessions"] == 1.0
