"""Cross-tier speculative decoding: the target-side verify protocol commits
exactly the target-only stream for ANY draft (perfect, garbage, partially
right) at temp=0 AND temp>0 (per-slot key-stream discipline: a rejected
draft must not desync the slot's jax.random stream), mid-draft EOS and k=1
behave, rejected drafts leak no paged refcounts and never inflate
decode_tokens, the draft-side shadow (quiet admission + scan drafting +
commit sync) round-trips the full two-engine co-drive, and a hypothesis
fuzz sweeps k x acceptance position."""
import numpy as np
import pytest

from repro.config import ServingConfig
from repro.models import build_model
from repro.serving.engine import TierEngine

FAMILY_PARAMS = [
    "dense",
    # the heavier families ride the slow mark to keep the smoke lane fast
    pytest.param("vlm", marks=pytest.mark.slow),
    pytest.param("moe", marks=pytest.mark.slow),
    pytest.param("ssm", marks=pytest.mark.slow),
    pytest.param("hybrid", marks=pytest.mark.slow),
]


def _make(cfg, params, temp=0.0, paged=False, eos=-1, seed=0, max_seq=192,
          **sv_kw):
    sv = ServingConfig(max_batch=2, max_seq=max_seq, paged=paged,
                       **({"kv_page_size": 32} if paged else {}), **sv_kw)
    return TierEngine(build_model(cfg), params, sv, eos_id=eos,
                      sample_temp=temp, seed=seed)


def _inputs(cfg, n=10, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(4, 200, size=n).astype(np.int32)
    extras = {}
    if cfg.frontend == "vision_stub":
        extras["patches"] = rng.standard_normal(
            (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
    return toks, extras


def _plain(eng, toks, max_new, extras, rid=0):
    eng.submit(rid, toks, max_new=max_new, extras=extras)
    done = {s.rid: list(s.generated) for s in eng.run_until_drained()}
    eng.finished.clear()
    return done[rid]


def _spec_target(eng, toks, max_new, draft_for, rid=0, extras=None):
    """Drive ONE request through the target-side verify protocol.

    ``draft_for(generated)`` proposes the next block given the tokens
    generated so far (the pending token is ``generated[-1]``). Empty
    proposal -> stop speculating; the fused ``step()`` path finishes the
    remainder, exactly like the runtime's co-drive fallback."""
    eng.submit(rid, toks, max_new=max_new, extras=extras or {})
    eng._admit()
    rounds = 0
    if eng.spec_slot(rid) is not None:
        eng.spec_begin(rid)
        while eng.spec_slot(rid) is not None and rounds < 500:
            slot = eng.spec_slot(rid)
            draft = draft_for(list(eng.slots[slot].generated))
            if len(draft) == 0:
                break
            res = eng.spec_verify(rid, draft)
            rounds += 1
            if res is None or res["finished"]:
                break
        if eng.spec_slot(rid) is not None:
            eng.spec_release(rid)
    done = {s.rid: list(s.generated) for s in eng.run_until_drained()}
    eng.finished.clear()
    return done[rid], rounds


def _perfect(ref, k):
    return lambda gen: ref[len(gen):len(gen) + k]


def _garbage(ref, k, vocab):
    """Mismatch guaranteed at EVERY position: each proposal is the true
    token + 1 (mod vocab)."""
    return lambda gen: [(t + 1) % vocab
                       for t in ref[len(gen):len(gen) + k]]


def _corrupt_at(ref, k, vocab, j):
    """True continuation with position ``j`` (0-based, within the block)
    flipped: exactly min(j, remaining) proposals accepted per round."""
    def f(gen):
        blk = list(ref[len(gen):len(gen) + k])
        if j < len(blk):
            blk[j] = (blk[j] + 1) % vocab
        return blk
    return f


# ---------------------------------------------------------------------------
# per-family accept / rollback parity at temp=0
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_spec_perfect_draft_matches_plain(family, family_model):
    cfg, params = family_model(family)
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params), toks, 12, extras)
    eng = _make(cfg, params)
    out, rounds = _spec_target(eng, toks, 12, _perfect(ref, 4),
                               extras=extras)
    assert out == ref
    assert rounds >= 1 and eng.spec_rounds == rounds
    assert eng.accepted_tokens > 0
    assert eng.decode_tokens == len(out)


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_spec_garbage_draft_matches_plain(family, family_model):
    """Worst case: every proposal rejected. The correction token per round
    still reproduces the target-only stream, and the rejected tails never
    count toward decode_tokens."""
    cfg, params = family_model(family)
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params), toks, 10, extras)
    eng = _make(cfg, params)
    out, rounds = _spec_target(eng, toks, 10, _garbage(ref, 4,
                                                       cfg.vocab_size),
                               extras=extras)
    assert out == ref
    assert eng.accepted_tokens == 0
    assert eng.decode_tokens == len(out)
    # one correction commit per round (the first token came from admission)
    assert rounds == len(ref) - 1


def test_spec_partial_accept_rollback(family_model):
    """Mismatch planted mid-block: the agreeing prefix + the correction
    commit, the tail rolls back, every round."""
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params), toks, 12, extras)
    eng = _make(cfg, params)
    out, rounds = _spec_target(eng, toks, 12,
                               _corrupt_at(ref, 5, cfg.vocab_size, 2),
                               extras=extras)
    assert out == ref
    # every round commits its accepted prefix + one bonus/correction token,
    # and the admission token preceded all rounds
    assert eng.accepted_tokens == len(out) - 1 - rounds
    assert eng.accepted_tokens > 0  # the planted prefix really was accepted
    assert eng.decode_tokens == len(out)


def test_spec_k1_single_token_blocks(family_model):
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params), toks, 8, extras)
    eng = _make(cfg, params)
    out, rounds = _spec_target(eng, toks, 8, _perfect(ref, 1),
                               extras=extras)
    assert out == ref
    # every full round accepts its single proposal (the last round finishes
    # on the bonus token before comparing): commits = accepted + rounds
    assert eng.accepted_tokens == len(out) - 1 - rounds


def test_spec_mid_draft_eos(family_model):
    """EOS sampled mid-block: the commit loop stops AT the EOS token and
    the rest of the block is discarded, matching the plain run."""
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    probe = _plain(_make(cfg, params), toks, 12, extras)
    eos = probe[3]  # make a mid-stream token the stop token
    ref = _plain(_make(cfg, params, eos=eos), toks, 12, extras)
    assert len(ref) < 12 and ref[-1] == eos
    eng = _make(cfg, params, eos=eos)
    out, _ = _spec_target(eng, toks, 12, _perfect(probe, 8), extras=extras)
    assert out == ref


def test_spec_max_new_one_no_spec_round(family_model):
    """max_new=1 finishes at admission; the protocol degrades to nothing."""
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params), toks, 1, extras)
    eng = _make(cfg, params)
    out, rounds = _spec_target(eng, toks, 1, _perfect(ref, 4),
                               extras=extras)
    assert out == ref and len(out) == 1 and rounds == 0


# ---------------------------------------------------------------------------
# sampling key-stream discipline at temp > 0
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("draft_kind", ["perfect", "garbage"])
def test_spec_temp_key_stream_parity(draft_kind, family_model):
    """temp>0: spec_verify consumes EXACTLY one key split per committed
    token — the same stream the fused on-device sampler would have drawn —
    so both a fully accepted and a fully rejected draft reproduce the
    plain stochastic stream token-for-token under the same seed."""
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params, temp=0.8, seed=11), toks, 10, extras)
    eng = _make(cfg, params, temp=0.8, seed=11)
    mk = _perfect if draft_kind == "perfect" else (
        lambda r, k: _garbage(r, k, cfg.vocab_size))
    out, _ = _spec_target(eng, toks, 10, mk(ref, 4), extras=extras)
    assert out == ref
    if draft_kind == "garbage":
        assert eng.accepted_tokens == 0


def test_spec_temp_key_survives_fallback(family_model):
    """Stopping mid-request (draft source dries up) must leave the slot's
    key stream positioned so the fused path finishes with the SAME tokens
    the uninterrupted plain run produces."""
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params, temp=0.8, seed=7), toks, 10, extras)
    eng = _make(cfg, params, temp=0.8, seed=7)
    half = _perfect(ref, 3)
    out, _ = _spec_target(
        eng, toks, 10,
        lambda gen: half(gen) if len(gen) < 5 else [], extras=extras)
    assert out == ref


# ---------------------------------------------------------------------------
# rejected-draft accounting: paged refcounts + allocator invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_spec_paged_rollback_refcounts(family, family_model):
    """Every round grows pages for the speculative tail and decrefs the
    rejected part; ``_spec_resize_pages`` asserts pool.check() throughout,
    and after the request finishes every page is back in the free list."""
    cfg, params = family_model(family)
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params, paged=True, prefix_cache_mb=0,
                       session_cache_mb=0), toks, 10, extras)
    eng = _make(cfg, params, paged=True, prefix_cache_mb=0,
                session_cache_mb=0)
    free0 = eng.pool.pages_free
    out, _ = _spec_target(eng, toks, 10, _garbage(ref, 4, cfg.vocab_size),
                          extras=extras)
    assert out == ref
    assert eng.pool.pages_free == free0  # no refcount leaks
    eng.pool.check()


def test_spec_paged_release_restores_reservation(family_model):
    """spec_begin trims to the frontier, spec_release regrows the full
    decode budget — the fused path then finishes without page faults."""
    cfg, params = family_model("dense")
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params, paged=True, prefix_cache_mb=0,
                       session_cache_mb=0), toks, 12, extras)
    eng = _make(cfg, params, paged=True, prefix_cache_mb=0,
                session_cache_mb=0)
    stop = _perfect(ref, 4)
    out, _ = _spec_target(
        eng, toks, 12,
        lambda gen: stop(gen) if len(gen) < 6 else [], extras=extras)
    assert out == ref
    assert eng.pool.pages_free == eng.pool.num_pages  # all pages back
    eng.pool.check()


# ---------------------------------------------------------------------------
# draft side: quiet shadow admission + scan drafting + commit sync
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILY_PARAMS)
def test_spec_two_engine_codrive(family, family_model):
    """Full protocol with a REAL draft engine of the same model: every
    proposal matches the target's greedy choice, so acceptance is 100%
    and the committed stream equals the plain target-only run."""
    cfg, params = family_model(family)
    toks, extras = _inputs(cfg)
    ref = _plain(_make(cfg, params), toks, 12, extras)
    teng = _make(cfg, params)
    deng = _make(cfg, params)
    rid, k = 0, 4
    teng.submit(rid, toks, max_new=12, extras=extras)
    teng._admit()
    assert teng.spec_slot(rid) is not None
    dslot = deng.spec_admit_quiet(rid, toks, max_new=12 + k + 2,
                                  extras=extras)
    assert dslot is not None
    assert not deng.finished  # quiet: no finished record, no hook calls
    slot_t = teng.spec_slot(rid)
    deng.spec_set_pending(rid, teng.slots[slot_t].generated[-1])
    teng.spec_begin(rid)
    drafted = accepted = 0
    while True:
        d = deng.spec_draft(rid, k)
        if d is None or len(d) == 0:
            break
        res = teng.spec_verify(rid, d)
        assert res is not None
        drafted += res["drafted"]
        accepted += res["accepted"]
        if res["finished"]:
            break
        assert deng.spec_sync(rid, res["committed"])
    deng.cancel(rid)
    if teng.spec_slot(rid) is not None:
        teng.spec_release(rid)
    done = {s.rid: list(s.generated) for s in teng.run_until_drained()}
    assert done[rid] == ref
    # same model, temp=0: every proposal agrees — only the final round can
    # truncate its block when max_new lands mid-draft
    assert drafted > 0
    assert accepted >= drafted - k
    assert teng.drafted_tokens == 0 and deng.drafted_tokens == drafted
    assert teng.accepted_tokens == accepted and deng.accepted_tokens == 0


def test_spec_admit_quiet_mutes_hooks(family_model):
    cfg, params = family_model("dense")
    toks, _ = _inputs(cfg)
    eng = _make(cfg, params)
    calls = []
    eng.on_admit = lambda rid, t: calls.append(("admit", rid))
    eng.on_token = lambda rid, tok, t: calls.append(("token", rid))
    assert eng.spec_admit_quiet(5, toks, max_new=8) is not None
    assert calls == []  # the shadow is invisible to the runtime's hooks
    eng.cancel(5)


# ---------------------------------------------------------------------------
# hypothesis fuzz: k x acceptance position
# ---------------------------------------------------------------------------

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @given(k=st.integers(1, 6), j=st.integers(0, 6), paged=st.booleans())
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    def test_spec_fuzz_k_by_mismatch_position(k, j, paged, family_model):
        """Any (block size, mismatch position, pool design): committed
        stream == plain stream, counters exact, invariants hold."""
        cfg, params = family_model("dense")
        toks, extras = _inputs(cfg)
        kw = dict(paged=True, prefix_cache_mb=0, session_cache_mb=0) \
            if paged else {}
        ref = _plain(_make(cfg, params, **kw), toks, 9, extras)
        eng = _make(cfg, params, **kw)
        out, _ = _spec_target(eng, toks, 9,
                              _corrupt_at(ref, k, cfg.vocab_size, j),
                              extras=extras)
        assert out == ref
        assert eng.decode_tokens == len(out)
        if paged:
            eng.pool.check()
else:
    @pytest.mark.slow
    @pytest.mark.skip(reason="hypothesis not installed (optional dev dep)")
    def test_spec_fuzz_k_by_mismatch_position():
        pass
