"""Per-assigned-architecture smoke tests: reduced config of the same family,
one forward/train step on CPU, asserting output shapes and no NaNs.
(The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_config, reduced_config
from repro.models import build_model
from repro.models.layers import pad_vocab

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    tok = jax.random.randint(RNG, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": tok[:, :s], "labels": tok[:, 1:]}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jax.random.normal(
            RNG, (b, cfg.num_patches, cfg.frontend_dim))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            RNG, (b, cfg.encoder_seq, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + PAPER_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    b, s = batch["tokens"].shape

    hidden, aux, _, prefix = model.forward(params, batch, "train")
    expect_s = s + (cfg.num_patches if cfg.frontend == "vision_stub" else 0)
    assert hidden.shape == (b, expect_s, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(hidden)))

    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # one full train step (grads + adamw)
    from repro.config import OptimizerConfig
    from repro.training import init_opt_state, make_train_step

    step = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3)))
    opt = init_opt_state(params, OptimizerConfig())
    new_params, new_opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(new_params), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_prefill_decode_consistency(arch):
    cfg = reduced_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(RNG)
    b, s, cap = 2, 16, 48
    batch = _batch(cfg, b, s)
    batch.pop("labels")
    from repro.models.layers import unembed

    fb = dict(batch)
    fb["tokens"] = jnp.concatenate(
        [batch["tokens"], batch["tokens"][:, :1]], axis=1)
    hidden, _, _, prefix = model.forward(params, fb, "train")
    logits_p, cache = model.prefill(params, batch, cap)
    assert logits_p.shape[0] == b
    assert not bool(jnp.any(jnp.isnan(logits_p)))

    db = {"tokens": fb["tokens"][:, s:s + 1],
          "positions": jnp.full((b,), prefix + s, jnp.int32)}
    logits_d, cache2 = model.decode_step(params, cache, db)
    tab = (params["embed"] if (cfg.family == "encdec" or cfg.tie_embeddings)
           else params["unembed"])
    want = unembed(hidden[:, prefix + s:prefix + s + 1].astype(jnp.float32),
                   tab, cfg.vocab_size)[:, 0]
    np.testing.assert_allclose(logits_d, want, rtol=1e-4, atol=1e-4)


def test_full_configs_param_counts_match_literature():
    expect = {
        "nemotron-4-340b": 340e9, "qwen3-0.6b": 0.6e9,
        "deepseek-coder-33b": 33e9, "yi-34b": 34e9,
        "kimi-k2-1t-a32b": 1000e9, "qwen3-moe-235b-a22b": 235e9,
        "mamba2-2.7b": 2.7e9,
    }
    for arch, want in expect.items():
        n = get_config(arch).param_count()
        assert 0.85 * want <= n <= 1.2 * want, (arch, n)


def test_moe_active_params_match():
    cfg = get_config("qwen3-moe-235b-a22b")
    na = cfg.active_param_count()
    assert 18e9 <= na <= 26e9, na  # A22B
    cfg = get_config("kimi-k2-1t-a32b")
    na = cfg.active_param_count()
    assert 28e9 <= na <= 40e9, na  # A32B
