"""Per-kernel allclose vs the pure-jnp oracle: shape/dtype sweeps in
interpret mode (the kernel body executes in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32, scale=1.0, seed=None):
    x = RNG.standard_normal(shape).astype(np.float32) * scale
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# image complexity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,w", [(16, 16), (33, 65), (64, 128), (128, 96)])
def test_image_stats_matches_ref(h, w):
    imgs = jnp.asarray(RNG.uniform(0, 255, (2, h, w)), jnp.float32)
    got = ops.image_stats(imgs, interpret=True)
    want = ref.image_stats_batch_ref(imgs)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=2e-5, atol=1e-3)


def test_image_stats_histogram_counts_pixels():
    imgs = jnp.asarray(RNG.uniform(0, 255, (3, 40, 56)), jnp.float32)
    got = ops.image_stats(imgs, interpret=True)
    np.testing.assert_allclose(got["hist"].sum(axis=-1), 40 * 56)


def test_image_complexity_flat_vs_textured():
    flat = jnp.full((1, 64, 64), 128.0)
    tex = jnp.asarray(RNG.uniform(0, 255, (1, 64, 64)), jnp.float32)
    c_flat = ops.image_complexity(flat, interpret=True)["c_img"][0]
    c_tex = ops.image_complexity(tex, interpret=True)["c_img"][0]
    assert float(c_tex) > float(c_flat)


def test_image_complexity_components_in_unit_interval():
    imgs = jnp.asarray(RNG.uniform(0, 255, (4, 48, 48)), jnp.float32)
    out = ops.image_complexity(imgs, interpret=True)
    for k, v in out.items():
        assert jnp.all(v >= 0.0) and jnp.all(v <= 1.0), k


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,h,kh,hd", [
    (128, 4, 4, 32),   # MHA
    (128, 8, 2, 64),   # GQA
    (256, 4, 1, 64),   # MQA
    (128, 4, 4, 80),   # non-128 head dim (padding path)
])
def test_flash_attention_shapes(s, h, kh, hd):
    q = _rand((2, s, h, hd))
    k = _rand((2, s, kh, hd))
    v = _rand((2, s, kh, hd))
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = _rand((1, 128, 4, 64), dtype)
    k = _rand((1, 128, 2, 64), dtype)
    v = _rand((1, 128, 2, 64), dtype)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_bidirectional_and_window():
    q = _rand((1, 256, 4, 32))
    k = _rand((1, 256, 2, 32))
    v = _rand((1, 256, 2, 32))
    for causal, window in [(False, None), (True, 64)]:
        got = ops.flash_attention(q, k, v, causal=causal, window=window,
                                  block_q=64, block_k=64, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("t,h,kh,hd,fill", [
    (256, 4, 4, 32, 256),   # full cache
    (512, 8, 2, 64, 300),   # partially filled
    (512, 4, 1, 128, 100),  # MQA
])
def test_decode_attention_vs_ref(t, h, kh, hd, fill):
    b = 2
    q = _rand((b, 1, h, hd))
    kc = _rand((b, t, kh, hd))
    vc = _rand((b, t, kh, hd))
    pos_c = np.full((b, t), -1, np.int32)
    pos_c[:, :fill] = np.arange(fill)
    pos_c = jnp.asarray(pos_c)
    pq = jnp.full((b,), fill - 1, jnp.int32)
    got = ops.decode_attention(q, kc, vc, pq, pos_c, block_t=128,
                               interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, pq, pos_c)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_ring_buffer_semantics():
    """Slot order must not matter — only absolute positions."""
    b, t, kh, hd = 1, 128, 2, 32
    q = _rand((b, 1, 4, hd))
    kc = _rand((b, t, kh, hd))
    vc = _rand((b, t, kh, hd))
    pos = jnp.asarray(np.arange(t, dtype=np.int32)[None])
    pq = jnp.full((b,), t - 1, jnp.int32)
    base = ops.decode_attention(q, kc, vc, pq, pos, block_t=64, interpret=True)
    roll = 37
    got = ops.decode_attention(q, jnp.roll(kc, roll, 1), jnp.roll(vc, roll, 1),
                               pq, jnp.roll(pos, roll, 1), block_t=64,
                               interpret=True)
    np.testing.assert_allclose(got, base, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("t", [80, 97, 640])
def test_decode_attention_ragged_cache_length(t):
    """t % block_t == 0 is no longer required: a ragged tail block is
    padded with pos=-1 slots (97 is prime) instead of asserting."""
    b, kh, hd = 2, 2, 32
    q = _rand((b, 1, 4, hd))
    kc = _rand((b, t, kh, hd))
    vc = _rand((b, t, kh, hd))
    pos = jnp.asarray(np.arange(t, dtype=np.int32)[None].repeat(b, 0))
    pq = jnp.full((b,), t - 1, jnp.int32)
    got = ops.decode_attention(q, kc, vc, pq, pos, block_t=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, pq, pos)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_kernels_interpret_defaults_resolve():
    """Direct kernel calls with interpret unset resolve via the backend
    (interpret on CPU) instead of the old hardcoded interpret=True."""
    from repro.kernels.decode_attention import decode_attention_pallas
    from repro.kernels.flash_attention import flash_attention_pallas

    b, t, kh, hd = 1, 64, 2, 128  # lane-aligned head dim, no ops.py padding
    q = _rand((b, 1, 4, hd))
    kc = _rand((b, t, kh, hd))
    vc = _rand((b, t, kh, hd))
    pos = jnp.asarray(np.arange(t, dtype=np.int32)[None])
    pq = jnp.full((b,), t - 1, jnp.int32)
    got = decode_attention_pallas(q, kc, vc, pq, pos, block_t=64)
    want = ref.decode_attention_ref(q, kc, vc, pq, pos)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    sq = _rand((b, 64, 4, hd))
    sk = _rand((b, 64, 2, hd))
    sv = _rand((b, 64, 2, hd))
    got = flash_attention_pallas(sq, sk, sv, causal=True, block_q=64,
                                 block_k=64)
    want = ref.flash_attention_ref(sq, sk, sv, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_window():
    b, t, hd = 1, 256, 64
    q = _rand((b, 1, 4, hd))
    kc = _rand((b, t, 2, hd))
    vc = _rand((b, t, 2, hd))
    pos = jnp.asarray(np.arange(t, dtype=np.int32)[None])
    pq = jnp.full((b,), t - 1, jnp.int32)
    got = ops.decode_attention(q, kc, vc, pq, pos, window=64, block_t=64,
                               interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, pq, pos, window=64)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
