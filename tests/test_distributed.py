"""Distribution-layer tests: sharding rule resolution, HLO analyzer, and a
multi-device (8 host CPU devices, subprocess) shard_map MoE equivalence +
mini dry-run."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.models.spec import ParamSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# sharding rules (no mesh needed beyond 1 device)
# ---------------------------------------------------------------------------


def test_spec_to_pspec_divisibility_fallback():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import spec_to_pspec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ps = spec_to_pspec(ParamSpec((64, 128), ("embed", "heads")), mesh)
    assert ps == P(None, "model") or ps == P(None, None)  # 1-dev: divisible

    # a dim that does NOT divide the model axis must fall back to replicated
    mesh_axes = jax.make_mesh((1, 1), ("data", "model"))
    ps2 = spec_to_pspec(ParamSpec((63, 7), ("vocab", "heads")), mesh_axes)
    assert isinstance(ps2, P)


def test_fsdp_shards_largest_free_dim():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import spec_to_pspec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ps = spec_to_pspec(ParamSpec((4, 128, 256), ("layers", "embed", "ffn")),
                       mesh, fsdp_axes=("data",))
    # 1-device mesh: everything divides; largest unsharded dim (256->ffn is
    # taken by model rule; embed 128 gets data)
    assert isinstance(ps, P)


def test_hlo_analyzer_counts_known_matmul():
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze_hlo

    f = jax.jit(lambda a, b: a @ b)
    hlo = f.lower(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                  jax.ShapeDtypeStruct((32, 16), jnp.float32)
                  ).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops"] == 2 * 64 * 32 * 16


def test_hlo_analyzer_scan_trip_multiplier():
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import analyze_hlo

    def scanned(ws, x):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    hlo = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((5, 16, 16), jnp.float32),
        jax.ShapeDtypeStruct((4, 16), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops"] == 5 * 2 * 4 * 16 * 16


# ---------------------------------------------------------------------------
# multi-device subprocess tests (8 forced host devices)
# ---------------------------------------------------------------------------

_SUBPROC_MOE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np, json
    from repro.configs import reduced_config
    from repro.models import moe as M
    from repro.models.spec import init_tree

    cfg = reduced_config("qwen3-moe-235b-a22b").replace(
        dtype="float32", capacity_factor=8.0, num_experts=8, top_k=2)
    params = init_tree(M.moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    y_ref, aux_ref = M.moe_dense_forward(params, x, cfg)
    with mesh:
        y, aux = M.moe_dropping_forward(params, x, cfg, mesh)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(json.dumps({"err": err, "aux_err": float(abs(aux - aux_ref))}))
""")

_SUBPROC_DRYRUN = textwrap.dedent("""
    import os
    os.environ["REPRO_DRYRUN_DEVICES"] = "8"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import repro.launch.mesh as lm
    # shrink the production mesh for the in-CI variant
    lm.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
        (2, 2, 2) if multi_pod else (2, 4),
        ("pod", "data", "model") if multi_pod else ("data", "model"))
    import repro.launch.dryrun as dr
    dr.make_production_mesh = lm.make_production_mesh
    recs = []
    for mp in (False, True):
        rec = dr.run_cell("qwen3-0.6b", "train_4k", mp, out_dir="")
        recs.append({"ok": rec["ok"], "coll": rec.get("collective_bytes_per_device", 0),
                     "flops": rec.get("per_device_flops", 0)})
    print(json.dumps(recs))
""")


def _run_sub(code: str, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # pin the subprocess to CPU: with libtpu installed, an unset
    # JAX_PLATFORMS makes jax probe the (absent) TPU and stall for
    # minutes on metadata retries; forced host devices work fine on cpu
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_moe_expert_parallel_matches_oracle_8dev():
    r = _run_sub(_SUBPROC_MOE)
    assert r["err"] < 5e-4, r
    # aux is pmean-of-shard-local estimates: small nonlinearity gap
    assert r["aux_err"] < 2e-3, r


@pytest.mark.slow
def test_mini_dryrun_single_and_multipod_8dev():
    recs = _run_sub(_SUBPROC_DRYRUN, timeout=560)
    assert all(r["ok"] for r in recs), recs
    assert all(r["flops"] > 0 for r in recs)
    assert recs[0]["coll"] > 0  # TP/DP must generate collectives
