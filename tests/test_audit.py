"""Byzantine wire defense: checksummed frames and slot payloads detect
every injected corruption, the sequenced delivery guard makes event
streams exactly-once under drop/dup/reorder chaos, and the runtime
invariant auditor certifies that a faulted run left no residue —
duplicate outcomes, stuck stations, leaked slots or unconserved pages.

The fuzz tests are seeded and exhaustive-by-trial (no dependency); a
hypothesis twin widens the search when the optional dev dependency is
installed."""
import numpy as np
import pytest

from repro.serving.engine import MigrationError, SeqState, SlotPayload
from repro.serving.faults import FaultEvent, FaultPlan, WireChaos
from repro.serving.transport import (DeliveryGuard, LocalTransport,
                                     TransportError, msg_from_bytes,
                                     msg_to_bytes)

# ---------------------------------------------------------------------------
# frame integrity: any flip in a checksummed region raises, never crashes
# ---------------------------------------------------------------------------


def _frame() -> bytes:
    return msg_to_bytes("events", (7, [("token", 3, 11, 0.25),
                                       ("admit", 4, 0.5)]))


def test_frame_roundtrip():
    kind, payload = msg_from_bytes(_frame())
    assert kind == "events"
    assert payload[0] == 7


def test_frame_flips_always_detected():
    """500 seeded random 1–4 byte flips anywhere in the frame: every one
    raises TransportError (100% detection), none crashes."""
    frame = _frame()
    rng = np.random.default_rng(0)
    for _ in range(500):
        corrupt = bytearray(frame)
        for _ in range(int(rng.integers(1, 5))):
            pos = int(rng.integers(len(corrupt)))
            corrupt[pos] ^= int(rng.integers(1, 256))
        with pytest.raises(TransportError):
            msg_from_bytes(bytes(corrupt))


def test_frame_truncations_always_detected():
    frame = _frame()
    for n in range(len(frame)):
        with pytest.raises(TransportError):
            msg_from_bytes(frame[:n])


def test_frame_size_cap_blocks_giant_allocation():
    frame = _frame()
    with pytest.raises(TransportError, match="oversized"):
        msg_from_bytes(frame, max_frame_bytes=len(frame) - 1)
    # at the cap it parses fine
    assert msg_from_bytes(frame, max_frame_bytes=len(frame))[0] == "events"


def test_frame_rejects_wrong_version_and_magic():
    frame = bytearray(_frame())
    with pytest.raises(TransportError, match="magic"):
        msg_from_bytes(b"XXXX" + bytes(frame[4:]))
    bad_ver = bytearray(frame)
    bad_ver[4] ^= 0xFF  # little-endian version low byte
    with pytest.raises(TransportError, match="version|checksum"):
        msg_from_bytes(bytes(bad_ver))


def test_frame_flips_hypothesis():
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    frame = _frame()

    @given(pos=st.integers(0, len(frame) - 1), mask=st.integers(1, 255))
    @settings(max_examples=200, deadline=None)
    def check(pos, mask):
        corrupt = bytearray(frame)
        corrupt[pos] ^= mask
        with pytest.raises(TransportError):
            msg_from_bytes(bytes(corrupt))

    check()


# ---------------------------------------------------------------------------
# slot payload integrity
# ---------------------------------------------------------------------------


def _payload() -> SlotPayload:
    rng = np.random.default_rng(1)
    return SlotPayload(
        version=2, model="toy", family="dense", max_seq=64,
        seq=SeqState(rid=5, prompt_len=8, generated=[9, 10], max_new=4,
                     done=False, t_submit=0.0, t_first_token=None,
                     t_done=None),
        position=10, key=np.asarray([3, 4], np.uint32),
        leaves={"kv/0": rng.standard_normal((2, 16, 4)).astype(np.float32),
                "kv/1": rng.standard_normal((2, 16, 4)).astype(np.float32)})


def test_slot_payload_roundtrip_with_checksums():
    p = _payload()
    q = SlotPayload.from_bytes(p.to_bytes())
    assert q.seq.rid == 5 and q.position == 10
    for name in p.leaves:
        np.testing.assert_array_equal(p.leaves[name], q.leaves[name])


def test_slot_payload_flips_always_detected():
    """Seeded random flips anywhere in the wire — header or any raw
    buffer — always raise MigrationError before any state is built."""
    wire = _payload().to_bytes()
    rng = np.random.default_rng(2)
    for _ in range(500):
        corrupt = bytearray(wire)
        for _ in range(int(rng.integers(1, 5))):
            pos = int(rng.integers(len(corrupt)))
            corrupt[pos] ^= int(rng.integers(1, 256))
        with pytest.raises(MigrationError):
            SlotPayload.from_bytes(bytes(corrupt))


def test_slot_payload_truncations_always_detected():
    wire = _payload().to_bytes()
    for n in range(0, len(wire), 7):
        with pytest.raises(MigrationError):
            SlotPayload.from_bytes(wire[:n])


def test_slot_payload_flips_hypothesis():
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    wire = _payload().to_bytes()

    @given(pos=st.integers(0, len(wire) - 1), mask=st.integers(1, 255))
    @settings(max_examples=200, deadline=None)
    def check(pos, mask):
        corrupt = bytearray(wire)
        corrupt[pos] ^= mask
        with pytest.raises(MigrationError):
            SlotPayload.from_bytes(bytes(corrupt))

    check()


# ---------------------------------------------------------------------------
# delivery guard: exactly-once over dup / drop / reorder
# ---------------------------------------------------------------------------


def test_guard_duplicates_suppressed():
    g = DeliveryGuard("events:t/0")
    g.receive(1, "ev", "a")
    g.receive(1, "ev", "a")
    g.receive(2, "ev", "b")
    g.receive(2, "ev", "b")
    assert g.drain() == [("ev", "a"), ("ev", "b")]
    assert g.stats.get("dups_suppressed") == 2
    assert g.audit("t/0") == []


def test_guard_reorder_restored():
    g = DeliveryGuard("events:t/0")
    g.receive(2, "ev", "b")
    g.receive(1, "ev", "a")
    g.receive(3, "ev", "c")
    assert g.drain() == [("ev", "a"), ("ev", "b"), ("ev", "c")]
    assert g.audit("t/0") == []


def test_guard_gap_resyncs_from_outbox():
    outbox = [(1, "ev", "a"), (2, "ev", "b"), (3, "ev", "c")]
    replayed = []

    def resync(last_seq):
        replayed.append(last_seq)
        for seq, kind, payload in outbox:
            if seq > last_seq:
                g.redeliver(seq, kind, payload)

    g = DeliveryGuard("events:t/0", resync=resync)
    g.receive(1, "ev", "a")
    g.receive(3, "ev", "c")  # 2 was dropped on the wire
    g.heal()
    assert replayed == [1]
    assert g.drain() == [("ev", "a"), ("ev", "b"), ("ev", "c")]
    assert g.stats.get("gaps_detected") == 1
    assert g.stats.get("resyncs") == 1
    assert g.audit("t/0") == []


def test_guard_dropped_tail_detected_via_high_water():
    """A dropped FINAL frame has no successor to reveal the gap; the
    sender's advertised high-water mark must still trigger the resync."""
    sent = []

    def resync(last_seq):
        for seq, kind, payload in sent:
            if seq > last_seq:
                g.redeliver(seq, kind, payload)

    g = DeliveryGuard("events:t/0", resync=resync)
    sent.append((1, "fin", "x"))
    g.expected = 1  # sender advertised seq 1; the frame itself vanished
    g.heal()
    assert g.drain() == [("fin", "x")]
    assert g.audit("t/0") == []


def test_guard_abandons_unhealable_gap_for_liveness():
    g = DeliveryGuard("events:t/0", resync=lambda last: None,
                      resync_patience=2)
    g.receive(1, "ev", "a")
    g.receive(4, "ev", "d")  # 2 and 3 are gone forever (sender died)
    for _ in range(5):
        g.heal()
    assert g.drain() == [("ev", "a"), ("ev", "d")]
    assert g.stats.get("gaps_abandoned") == 1
    assert g.audit("t/0") == []  # ledger closed: liveness preserved


def test_guard_chaos_drop_dup_reorder_end_clean():
    """A seeded byzantine schedule on the wire side of the guard: whatever
    mix of drops/dups/reorders fires, the drained stream is exactly the
    sent stream, in order, and the ledger closes clean."""
    plan = FaultPlan([FaultEvent("msg_drop", "*", magnitude=0.2),
                      FaultEvent("msg_dup", "*", magnitude=0.3),
                      FaultEvent("msg_reorder", "*", magnitude=0.2)],
                     wire_seed=5)
    chaos = WireChaos(plan)
    outbox = []

    def resync(last_seq):
        for seq, kind, payload in outbox:
            if seq > last_seq:
                g.redeliver(seq, kind, payload)

    g = DeliveryGuard("events:t/0", chaos=chaos, stats=chaos.stats,
                      resync=resync, resync_patience=0)
    n = 200
    for i in range(1, n + 1):
        outbox.append((i, "ev", i))
        g.expected = i
        g.receive(i, "ev", i)
        g.heal()
    g.heal()
    got = [payload for _, payload in g.drain()]
    assert got == list(range(1, n + 1))
    assert g.audit("t/0") == []
    # the schedule actually fired
    assert chaos.stats.get("msgs_dropped", 0) > 0
    assert chaos.stats.get("msgs_duped", 0) > 0
    assert chaos.stats.get("msgs_reordered", 0) > 0
    assert chaos.stats.get("dups_suppressed", 0) > 0


# ---------------------------------------------------------------------------
# per-family live engines behind a chaotic local transport
# ---------------------------------------------------------------------------

from test_migration import FAMILIES, make_engine  # noqa: E402


def _drive(transport, jobs, chaos_events):
    """Submit jobs and poll to completion; returns {rid: generated}."""
    events = []
    transport.wire_hooks(
        lambda rid, t: events.append(("admit", rid)),
        lambda rid, tok, t: events.append(("token", rid, tok)),
        lambda rid, k, c, s: None, lambda rid, sid: None)
    if chaos_events is not None:
        transport.arm_delivery(chaos_events, chaos_events.stats,
                               lambda: 0.0, "events:edge/0")
    for rid, toks, max_new in jobs:
        transport.submit(rid, toks, max_new, {}, None, None)
    done = {}
    for _ in range(10_000):
        fins, active, _ = transport.poll()
        for f in fins:
            done[f.rid] = list(f.generated)
        if not active and len(done) == len(jobs):
            break
    return done, events


@pytest.mark.slow
@pytest.mark.parametrize("family", FAMILIES)
def test_byzantine_event_stream_token_identical(family, family_model):
    """drop/dup/reorder chaos on a replica's event stream never changes
    the delivered tokens (temp=0) for ANY model family — the guard heals
    everything within the poll, and its ledger closes clean."""
    cfg, params = family_model(family)
    jobs = [(rid, (np.arange(6 + 3 * rid) % 300 + 4).astype(np.int32), 8)
            for rid in range(3)]
    honest, _ = _drive(LocalTransport(make_engine(cfg, params)), jobs, None)

    plan = FaultPlan([FaultEvent("msg_drop", "*", magnitude=0.25),
                      FaultEvent("msg_dup", "*", magnitude=0.25),
                      FaultEvent("msg_reorder", "*", magnitude=0.25)],
                     wire_seed=7)
    chaos = WireChaos(plan)
    tr = LocalTransport(make_engine(cfg, params))
    chaotic, events = _drive(tr, jobs, chaos)

    assert chaotic == honest  # token-identical despite the storm
    assert tr._guard.audit("edge/0") == []
    assert chaos.stats.get("dups_suppressed", 0) > 0
    # every delivered token arrived exactly once, in order
    per_rid = {}
    for ev in events:
        if ev[0] == "token":
            per_rid.setdefault(ev[1], []).append(ev[2])
    for rid, toks in honest.items():
        assert per_rid[rid] == toks


# ---------------------------------------------------------------------------
# the invariant auditor itself
# ---------------------------------------------------------------------------


class _Stub:
    def __init__(self, **kw):
        self.__dict__.update(kw)


def _stub_runtime(outcomes, records, links=None, wire=None, residue=()):
    return _Stub(outcomes=outcomes, records=records, links=links or {},
                 wire_stats=wire or {},
                 backend=_Stub(audit_residue=lambda: list(residue)))


def test_auditor_flags_duplicate_and_missing_outcomes():
    from repro.serving.audit import InvariantAuditor

    rec_done = _Stub(done=True)
    rec_open = _Stub(done=False)
    rt = _stub_runtime(
        outcomes=[_Stub(rid=1), _Stub(rid=1)],  # double-served
        records={1: rec_done, 2: rec_open})      # 2 never finished
    v = InvariantAuditor(rt).final_check()
    assert not v["clean"]
    text = " ".join(v["violations"])
    assert "2 terminal Outcomes" in text
    assert "no terminal Outcome" in text


def test_auditor_flags_stuck_station_and_undetected_corruption():
    from repro.serving.audit import InvariantAuditor

    rt = _stub_runtime(
        outcomes=[_Stub(rid=1)], records={1: _Stub(done=True)},
        links={"wan:edge": _Stub(busy=1, queue=[object()])},
        wire={"corrupt_undetected": 2},
        residue=["edge: slot 0 still busy (rid 9)"])
    v = InvariantAuditor(rt).final_check()
    assert not v["clean"]
    text = " ".join(v["violations"])
    assert "busy" in text and "undetected" in text and "slot 0" in text
    assert v["wire"]["corrupt_undetected"] == 2


def test_auditor_clean_on_consistent_state():
    from repro.serving.audit import InvariantAuditor

    rt = _stub_runtime(outcomes=[_Stub(rid=1)],
                       records={1: _Stub(done=True)},
                       links={"wan:edge": _Stub(busy=0, queue=[])})
    v = InvariantAuditor(rt).final_check()
    assert v["clean"] and v["violations"] == []
    assert v["requests"] == v["outcomes"] == 1


# ---------------------------------------------------------------------------
# end to end: byzantine storms through the full live control plane
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_live_cluster_byzantine_storm_audits_clean():
    """The full live control plane under event-stream chaos with the
    auditor on: identical served tokens to the honest run, a clean
    verdict, and the wire counters prove faults actually fired."""
    from conftest import make_twin_edge_server

    plan = FaultPlan([FaultEvent("msg_drop", "*", magnitude=0.2),
                      FaultEvent("msg_dup", "*", magnitude=0.3),
                      FaultEvent("msg_reorder", "*", magnitude=0.2)],
                     wire_seed=11)
    runs = {}
    for mode, fp in (("honest", None), ("byzantine", plan)):
        server = make_twin_edge_server(fault_plan=fp, audit=True)
        for i in range(3):
            server.submit(f"describe scene {i} please now. " * 2,
                          max_new=8, complexity={"text": 0.05})
        results = server.run(timeout_s=120.0)
        runs[mode] = sorted((r.rid, tuple(r.tokens)) for r in results)
        verdict = server.runtime.auditor.last
        assert verdict["clean"], verdict["violations"]
        if fp is not None:
            ws = server.runtime.wire_stats
            assert ws.get("dups_suppressed", 0) > 0
            assert ws.get("msgs_dropped", 0) > 0
            assert ws.get("corrupt_undetected", 0) == 0
    assert runs["byzantine"] == runs["honest"]


@pytest.mark.slow
def test_live_corrupt_migration_detected_and_recovered():
    """Every migration wire corrupted (p=1): the payload CRC rejects the
    inject, the clone re-prefills (recovered — the request completes with
    correct tokens), corrupt_detected counts it, nothing slips through,
    and the auditor signs off."""
    from conftest import make_twin_edge_server

    plan = FaultPlan([FaultEvent("corrupt", "*", magnitude=1.0)],
                     wire_seed=3)
    server = make_twin_edge_server(hedge_after_s=0.05, migrate=True,
                                   fault_plan=plan, audit=True)
    req = server.build_request("please describe this Scene in depth. " * 3,
                               max_new=100, complexity={"text": 0.05})
    server.submit_request(req)
    (res,) = server.run(timeout_s=120.0)
    ws = server.runtime.wire_stats
    assert ws.get("corrupt_injected", 0) >= 1
    assert ws.get("corrupt_detected", 0) >= 1
    assert ws.get("corrupt_undetected", 0) == 0
    assert not res.failed and not res.migrated  # recovered via re-prefill
    assert len(res.tokens) > 0
    verdict = server.runtime.auditor.last
    assert verdict["clean"], verdict["violations"]


def test_wire_chaos_determinism():
    """Two WireChaos instances over the same plan make identical decisions
    per link regardless of interleaving across links."""
    plan = FaultPlan.byzantine_storm(seed=9, corrupt=0.4, dup=0.3,
                                     drop=0.2, reorder=0.1)
    a, b = WireChaos(plan), WireChaos(plan)
    links = ["events:edge/0", "events:cloud/0", "migrate:edge1"]
    seq_a = [(k, ln, a.decide(k, ln, 0.0))
             for ln in links for k in ("corrupt", "msg_drop", "msg_dup")
             for _ in range(20)]
    # b interleaves the SAME per-link queries in a different global order
    seq_b = {}
    for k in ("corrupt", "msg_drop", "msg_dup"):
        for ln in links:
            seq_b[(k, ln)] = [b.decide(k, ln, 123.0) for _ in range(20)]
    per_link = {}
    for k, ln, v in seq_a:
        per_link.setdefault((k, ln), []).append(v)
    assert per_link == seq_b  # t differs, windows are infinite: same fate
    assert any(v for vs in seq_b.values() for v in vs)  # storm is real
