"""Fused image-complexity statistics Pallas TPU kernel (paper §3.1.1).

One pass over each image computes ALL the raw statistics the MoA-Off
modality-aware module needs: Sobel gradient-magnitude sum (edge density,
Eq. 2), Laplacian sum + sum-of-squares (sharpness variance, Eq. 4) and the
256-bin gray histogram (entropy texture, Eq. 3). The GPU version of this
would be OpenCV filters + shared-memory atomic histogram; TPUs have no
atomics, so the histogram is computed as a **bincount-as-GEMM**: per row-chunk
one-hot comparison matrix contracted against ones on the MXU. Stencils are
VPU-friendly shifted-slice arithmetic.

Tiling: grid over the batch; one image per grid step resides in VMEM
(assignment-normalized images are <= 1024x1024 f32 = 4 MiB; padded copy +
one-hot chunk keep the working set < 12 MiB, within a v5e's 16 MiB VMEM).
The histogram loop chunks rows so the one-hot tile stays (chunk*W, 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

HIST_BINS = 256


def _kernel(img_ref, stats_ref, hist_ref, *, hist_chunk: int):
    img = img_ref[0].astype(jnp.float32)  # (H, W)
    h, w = img.shape

    # --- stencils on an edge-padded copy (shifted slices, no gather) ---
    p = jnp.pad(img, 1, mode="edge")
    gx = (p[:-2, 2:] + 2.0 * p[1:-1, 2:] + p[2:, 2:]
          - p[:-2, :-2] - 2.0 * p[1:-1, :-2] - p[2:, :-2])
    gy = (p[2:, :-2] + 2.0 * p[2:, 1:-1] + p[2:, 2:]
          - p[:-2, :-2] - 2.0 * p[:-2, 1:-1] - p[:-2, 2:])
    mag = jnp.sqrt(gx * gx + gy * gy)
    lap = (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:] - 4.0 * img)

    stats_ref[0, 0] = jnp.sum(mag)
    stats_ref[0, 1] = jnp.sum(lap)
    stats_ref[0, 2] = jnp.sum(lap * lap)

    # --- histogram: chunked one-hot x ones GEMM (MXU bincount) ---
    bins = jnp.clip(jnp.floor(img), 0, 255)  # f32 values == bin ids
    n_chunks = h // hist_chunk
    ids = jnp.arange(HIST_BINS, dtype=jnp.float32)

    def body(i, acc):
        rows = jax.lax.dynamic_slice_in_dim(bins, i * hist_chunk, hist_chunk, 0)
        flat = rows.reshape(-1, 1)  # (chunk*W, 1)
        onehot = (flat == ids[None, :]).astype(jnp.float32)  # (chunk*W, 256)
        return acc + jnp.sum(onehot, axis=0)

    hist = jax.lax.fori_loop(0, n_chunks, body,
                             jnp.zeros((HIST_BINS,), jnp.float32))
    rem = h - n_chunks * hist_chunk
    if rem:  # static remainder
        rows = bins[n_chunks * hist_chunk:]
        hist = hist + jnp.sum(
            (rows.reshape(-1, 1) == ids[None, :]).astype(jnp.float32), axis=0)
    hist_ref[0] = hist


def image_stats_pallas(imgs: jax.Array, *, hist_chunk: int = 8,
                       interpret: bool = True):
    """imgs: (B, H, W) float32 in [0,255].

    Returns {"sobel_sum": (B,), "lap_sum": (B,), "lap_sq_sum": (B,),
    "hist": (B, 256)}.
    """
    b, h, w = imgs.shape
    kernel = functools.partial(_kernel, hist_chunk=min(hist_chunk, h))
    stats, hist = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, 4), lambda i: (i, 0)),
                   pl.BlockSpec((1, HIST_BINS), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, 4), jnp.float32),
                   jax.ShapeDtypeStruct((b, HIST_BINS), jnp.float32)],
        interpret=interpret,
    )(imgs)
    return {"sobel_sum": stats[:, 0], "lap_sum": stats[:, 1],
            "lap_sq_sum": stats[:, 2], "hist": hist}
