"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Grid (B, K, nT): KV-length blocks innermost; running (m, l, acc) for the G
query heads of one KV head live in VMEM scratch. Ring-buffer caches are
handled by masking on ABSOLUTE slot positions (pos_cache), exactly like the
XLA reference — empty slots carry position -1 and are masked out.

Latency note: decode attention is memory-bound (reads the whole KV cache,
does O(1) FLOPs per byte); the win of the kernel is fusing mask+softmax+
combine into the single streaming pass over HBM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import auto_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, posq_ref, posc_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, window: Optional[int],
            n_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)       # (G, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bt, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    pos_q = posq_ref[0, 0]                     # scalar int32
    pos_c = posc_ref[0, :]                     # (bt,) int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    d = pos_q - pos_c  # (bt,)
    ok = (pos_c >= 0) & (d >= 0)
    if window is not None:
        ok &= d < window
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)

    @pl.when(it == n_t - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def _paged_kernel(pages_ref, q_ref, k_ref, v_ref, posq_ref, posc_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float,
                  window: Optional[int], n_t: int):
    # the page table is consumed by the k/v index_maps (scalar prefetch);
    # the block body is the exact dense flash-decode update
    del pages_ref
    _kernel(q_ref, k_ref, v_ref, posq_ref, posc_ref, o_ref,
            acc_ref, m_ref, l_ref, scale=scale, window=window, n_t=n_t)


def decode_attention_pallas_paged(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array, pages: jax.Array,
                                  pos_q: jax.Array, pos_cache: jax.Array, *,
                                  window: Optional[int] = None,
                                  interpret: Optional[bool] = None
                                  ) -> jax.Array:
    """Paged flash-decode: the KV context is gathered page-by-page THROUGH
    the page table, straight out of the shared physical pool.

    q: (B,1,H,hd); k/v_pool: (P, page, K, hd) physical pages; pages: (B, NP)
    int32 page table rows (0 = the engine's null page); pos_q: (B,);
    pos_cache: (B, T<=NP*page) absolute positions per logical row.

    The page table rides in as a scalar-prefetch operand: the kv BlockSpec
    index_map reads ``pages[b, it]`` to pick the PHYSICAL page for grid step
    ``it``, so each page streams from HBM exactly once and no gathered copy
    of the context is ever materialized. Entries past ``pos_cache``'s width
    (the partial last page) and null-page garbage carry pos = -1 and mask to
    an exact zero, like the dense kernel's empty slots.
    """
    if interpret is None:
        interpret = auto_interpret()
    b, _, h, hd = q.shape
    kh = k_pool.shape[2]
    g = h // kh
    page = k_pool.shape[1]
    n_p = pages.shape[1]
    t = pos_cache.shape[1]
    if t < n_p * page:  # pad the ragged tail; pos -1 masks the pad entries
        pos_cache = jnp.pad(pos_cache, ((0, 0), (0, n_p * page - t)),
                            constant_values=-1)
    scale = hd ** -0.5

    qg = q[:, 0].reshape(b, kh, g, hd)
    posq2 = pos_q.reshape(b, 1).astype(jnp.int32)
    posc = pos_cache

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               n_t=n_p)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda pg, b_, kh_, it: (b_, kh_, 0, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda pg, b_, kh_, it: (pg[b_, it], 0, kh_, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda pg, b_, kh_, it: (pg[b_, it], 0, kh_, 0)),
            pl.BlockSpec((1, 1), lambda pg, b_, kh_, it: (b_, 0)),
            pl.BlockSpec((1, page), lambda pg, b_, kh_, it: (b_, it)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda pg, b_, kh_, it: (b_, kh_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        interpret=interpret,
    )(pages.astype(jnp.int32), qg, k_pool, v_pool, posq2, posc)
    return out.reshape(b, 1, h, hd)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, pos_q: jax.Array,
                            pos_cache: jax.Array, *,
                            window: Optional[int] = None,
                            block_t: int = 512,
                            interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,1,H,hd); k/v_cache: (B,T,K,hd); pos_q: (B,); pos_cache: (B,T).

    Returns (B,1,H,hd). Ragged cache lengths (t % block_t != 0) are
    handled copy-free when t has a decent power-of-two divisor (e.g. the
    serving engine's 1.5*2^n context buckets) by shrinking block_t to it;
    only pathological lengths fall back to padding a tail block whose
    slots carry pos=-1 (the kernel's empty-slot masking ignores them).
    ``interpret=None`` resolves per-backend (compiled on TPU, interpreted
    elsewhere).
    """
    if interpret is None:
        interpret = auto_interpret()
    b, _, h, hd = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    block_t = min(block_t, t)
    if t % block_t:
        # largest power-of-two divisor of t, capped by the requested block
        p2 = t & (-t)
        bt = min(p2, 1 << (block_t.bit_length() - 1))
        if bt >= 128:
            block_t = bt  # divides t exactly: no copy
        else:
            tail = (-t) % block_t
            zpad = [(0, 0)] * 4
            zpad[1] = (0, tail)
            k_cache = jnp.pad(k_cache, zpad)
            v_cache = jnp.pad(v_cache, zpad)
            pos_cache = jnp.pad(pos_cache, ((0, 0), (0, tail)),
                                constant_values=-1)
            t += tail
    n_t = t // block_t
    scale = hd ** -0.5

    qg = q[:, 0].reshape(b, kh, g, hd)  # (B,K,G,hd), head h = kh_idx*g + g_idx
    posq2 = pos_q.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=scale, window=window, n_t=n_t)
    out = pl.pallas_call(
        kernel,
        grid=(b, kh, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, kh_, it: (b_, kh_, 0, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b_, kh_, it: (b_, it, kh_, 0)),
            pl.BlockSpec((1, block_t, 1, hd), lambda b_, kh_, it: (b_, it, kh_, 0)),
            pl.BlockSpec((1, 1), lambda b_, kh_, it: (b_, 0)),
            pl.BlockSpec((1, block_t), lambda b_, kh_, it: (b_, it)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b_, kh_, it: (b_, kh_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, posq2, pos_cache)
    return out.reshape(b, 1, h, hd)
