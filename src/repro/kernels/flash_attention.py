"""Blocked online-softmax (flash) attention Pallas TPU kernel — GQA-aware.

Grid (B, H, nQ, nK) with the KV dimension innermost: the TPU grid executes
sequentially per core, so the running (m, l, acc) state lives in VMEM scratch
and persists across the nK steps of one (b, h, iq) row; the output block is
written once on the last KV step. GQA is expressed in the K/V index_maps
(query head h reads KV head h // group_size) so KV blocks are fetched once
per group, not per query head.

Block shapes default to (128, head_dim): 128 is MXU/VREG aligned, and
head_dim is padded to a lane multiple by the wrapper in ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import auto_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int], n_k: int,
            block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal block skip: block fully above the diagonal contributes nothing
    q_start = iq * block_q
    k_start = ik * block_k
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        ok = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            ok &= cols <= rows
        if window is not None:
            ok &= (rows - cols) < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,K,hd). Self-attention (pos == index).

    ``interpret=None`` resolves per-backend (compiled on TPU, interpreted
    elsewhere) so direct callers get the fast mode by default off-CPU.
    """
    if interpret is None:
        interpret = auto_interpret()
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q, n_k = s // block_q, s // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, n_k=n_k,
        block_q=block_q, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b_, h_, iq, ik: (b_, ik, h_ // g, 0)),
            pl.BlockSpec((1, block_k, 1, hd), lambda b_, h_, iq, ik: (b_, ik, h_ // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b_, h_, iq, ik: (b_, iq, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
