"""Backend-dependent kernel execution defaults.

Lives in its own module (instead of ``ops``) so the kernel files can resolve
``interpret`` without importing ``ops`` and creating a cycle: Pallas executes
kernel bodies in Python on CPU (this container) and compiles natively on TPU.
"""
from __future__ import annotations

import jax


def auto_interpret() -> bool:
    """True when Pallas must run in interpret mode (any non-TPU backend)."""
    return jax.default_backend() != "tpu"
