"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These define the semantics; the kernels must match them bit-for-tolerance.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# image complexity statistics (paper §3.1.1)
# ---------------------------------------------------------------------------


def image_stats_ref(img: jax.Array) -> dict:
    """Raw single-pass statistics for one image.

    img: (H, W) float32 in [0, 255].
    Returns {sobel_sum, lap_sum, lap_sq_sum, hist(256,)} — the complexity
    scores (Eq. 2-4) are scalar post-processing over these (see ops.py).
    """
    img = img.astype(jnp.float32)
    p = jnp.pad(img, 1, mode="edge")
    # Sobel gradients
    gx = (p[:-2, 2:] + 2.0 * p[1:-1, 2:] + p[2:, 2:]
          - p[:-2, :-2] - 2.0 * p[1:-1, :-2] - p[2:, :-2])
    gy = (p[2:, :-2] + 2.0 * p[2:, 1:-1] + p[2:, 2:]
          - p[:-2, :-2] - 2.0 * p[:-2, 1:-1] - p[:-2, 2:])
    mag = jnp.sqrt(gx * gx + gy * gy)
    # 4-neighbour Laplacian
    lap = (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
           - 4.0 * img)
    # gray-level histogram (bin = floor, clipped)
    bins = jnp.clip(jnp.floor(img), 0, 255).astype(jnp.int32)
    hist = jnp.zeros((256,), jnp.float32).at[bins.reshape(-1)].add(1.0)
    return {
        "sobel_sum": jnp.sum(mag),
        "lap_sum": jnp.sum(lap),
        "lap_sq_sum": jnp.sum(lap * lap),
        "hist": hist,
    }


def image_stats_batch_ref(imgs: jax.Array) -> dict:
    """imgs: (B, H, W) -> dict of stacked stats."""
    return jax.vmap(image_stats_ref)(imgs)


# ---------------------------------------------------------------------------
# flash attention (prefill) — GQA, causal, optional sliding window
# ---------------------------------------------------------------------------


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,K,hd) -> (B,S,H,hd). Self-attention layout
    (query position i == key position i)."""
    from repro.models.attention import dense_attention

    s = q.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    return dense_attention(q, k, v, pos, pos, causal=causal, window=window)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         pos_q: jax.Array, pos_cache: jax.Array, *,
                         window: Optional[int] = None) -> jax.Array:
    """q: (B,1,H,hd); caches (B,T,K,hd); pos_q (B,); pos_cache (B,T)."""
    from repro.models.attention import decode_attention_xla

    return decode_attention_xla(q, k_cache, v_cache, pos_q, pos_cache,
                                window=window)
