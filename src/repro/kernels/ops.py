"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to auto: Pallas executes the kernel body in Python on
CPU (this container) and compiles natively on TPU. Head dims are padded to a
lane multiple of 128 before entering the kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ComplexityConfig
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            decode_attention_pallas_paged)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.image_complexity import image_stats_pallas
from repro.kernels.runtime import auto_interpret as _auto_interpret


def _pad_head(x: jax.Array, mult: int = 128):
    hd = x.shape[-1]
    pad = (-hd) % mult
    if pad == 0:
        return x, hd
    cfgpad = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, cfgpad), hd


# ---------------------------------------------------------------------------
# image complexity
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret",))
def image_stats(imgs: jax.Array, interpret: Optional[bool] = None) -> dict:
    """Raw stats per image. imgs: (B, H, W) float32 in [0, 255]."""
    if interpret is None:
        interpret = _auto_interpret()
    return image_stats_pallas(imgs.astype(jnp.float32), interpret=interpret)


def image_complexity_from_stats(stats: dict, h: int, w: int,
                                cc: ComplexityConfig) -> dict:
    """Scalar post-processing of kernel stats -> Eq. 2/3/4 + c_img."""
    n = float(h * w)
    g_mean = stats["sobel_sum"] / n
    c_edge = jnp.clip((g_mean - cc.edge_p5) /
                      (cc.edge_p95 - cc.edge_p5 + cc.eps), 0.0, 1.0)
    lap_mean = stats["lap_sum"] / n
    lap_var = stats["lap_sq_sum"] / n - lap_mean ** 2
    c_lap = jnp.clip((lap_var - cc.lap_p5) /
                     (cc.lap_p95 - cc.lap_p5 + cc.eps), 0.0, 1.0)
    p = stats["hist"] / n
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0), axis=-1)
    c_ent = ent / jnp.log(256.0)
    c_res = jnp.minimum(1.0, n / float(cc.ref_h * cc.ref_w))
    c_res = jnp.broadcast_to(c_res, c_edge.shape)
    c_img = (cc.w_res * c_res + cc.w_edge * c_edge
             + cc.w_ent * c_ent + cc.w_lap * c_lap)
    return {"c_res": c_res, "c_edge": c_edge, "c_ent": c_ent, "c_lap": c_lap,
            "c_img": c_img}


def image_complexity(imgs: jax.Array, cc: ComplexityConfig = ComplexityConfig(),
                     interpret: Optional[bool] = None) -> dict:
    """End-to-end §3.1.1: (B,H,W) images -> complexity components + c_img."""
    stats = image_stats(imgs, interpret=interpret)
    return image_complexity_from_stats(stats, imgs.shape[1], imgs.shape[2], cc)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _auto_interpret()
    qp, hd = _pad_head(q)
    kp, _ = _pad_head(k)
    vp, _ = _pad_head(v)
    # scale must reflect the TRUE head dim, not the padded one
    qp = qp * (qp.shape[-1] ** 0.5) * (hd ** -0.5)
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out[..., :hd]


@functools.partial(jax.jit, static_argnames=("window", "block_t", "interpret"))
def decode_attention(q, k_cache, v_cache, pos_q, pos_cache, *,
                     window: Optional[int] = None, block_t: int = 512,
                     interpret: Optional[bool] = None):
    if interpret is None:
        interpret = _auto_interpret()
    qp, hd = _pad_head(q)
    kp, _ = _pad_head(k_cache)
    vp, _ = _pad_head(v_cache)
    qp = qp * (qp.shape[-1] ** 0.5) * (hd ** -0.5)
    out = decode_attention_pallas(qp, kp, vp, pos_q, pos_cache, window=window,
                                  block_t=block_t, interpret=interpret)
    return out[..., :hd]


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attention_paged(q, k_pool, v_pool, pages, pos_q, pos_cache, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None):
    """Paged flash-decode: k/v_pool (P, page, K, hd) physical pages, pages
    (B, NP) int32 page-table rows, pos_cache (B, T) absolute positions."""
    if interpret is None:
        interpret = _auto_interpret()
    qp, hd = _pad_head(q)
    kp, _ = _pad_head(k_pool)
    vp, _ = _pad_head(v_pool)
    qp = qp * (qp.shape[-1] ** 0.5) * (hd ** -0.5)
    out = decode_attention_pallas_paged(qp, kp, vp, pages, pos_q, pos_cache,
                                        window=window, interpret=interpret)
    return out[..., :hd]
