"""Sharded, async, atomic checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/<flattened.leaf.path>.npy  +  manifest.json
  · leaves are written to ``step_<N>.tmp-<pid>`` then the dir is atomically
    renamed — a crash mid-save can never corrupt the latest checkpoint;
  · bfloat16 leaves are stored as uint16 views (dtype recorded in the
    manifest) so files are loadable without ml_dtypes;
  · ``save(async_=True)`` snapshots to host memory synchronously (cheap) and
    writes in a daemon thread — training continues during the fsync;
  · restore() optionally re-shards onto a target sharding tree (elastic
    restarts onto a different mesh go through ``elastic_restore_tree``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SAFE.sub("_", ".".join(parts)) or "root"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def list_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------

    def save(self, step: int, tree: Any, async_: bool = True) -> None:
        self.wait()  # one in-flight save at a time
        # synchronous host snapshot (device -> host copy); cheap vs fsync
        flat = jax.tree_util.tree_flatten_with_path(tree)
        leaves = [(_path_str(p), np.asarray(x)) for p, x in flat[0]]
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, leaves), daemon=True)
            self._thread.start()
        else:
            self._write(step, leaves)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, leaves: List[Tuple[str, np.ndarray]]) -> None:
        try:
            final = self._step_dir(step)
            tmp = f"{final}.tmp-{os.getpid()}"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest: Dict[str, dict] = {}
            for name, arr in leaves:
                logical_dtype = str(arr.dtype)
                store = arr
                if logical_dtype == "bfloat16":
                    store = arr.view(np.uint16)
                np.save(os.path.join(tmp, name + ".npy"), store,
                        allow_pickle=False)
                manifest[name] = {"dtype": logical_dtype,
                                  "shape": list(arr.shape)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any]:
        """Restore into the structure of ``tree_like``.

        ``shardings``: optional tree of NamedSharding — leaves are device_put
        with them (elastic restore onto any mesh).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(flat))
        out = []
        for (path, like), shard in zip(flat, shard_flat):
            name = _path_str(path)
            info = manifest[name]
            arr = np.load(os.path.join(d, name + ".npy"))
            if info["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16.dtype)
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            else:
                out.append(jnp.asarray(arr))
        return step, jax.tree_util.tree_unflatten(treedef, out)
