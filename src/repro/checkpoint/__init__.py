from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
from repro.checkpoint.elastic import elastic_restore_tree  # noqa: F401
