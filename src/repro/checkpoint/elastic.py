"""Elastic restart: restore a checkpoint onto a DIFFERENT mesh shape.

Checkpoints store full (unsharded) arrays, so elasticity is a matter of
re-resolving the logical-axis rules against the new mesh and device_put-ing
with the new shardings — scale from 256 to 512 chips (or down to 1 for a
local debug session) without converting anything.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.sharding import DEFAULT_RULES, param_shardings


def elastic_restore_tree(ckpt: Checkpointer, tree_like: Any, specs: Any,
                         mesh, step: Optional[int] = None,
                         rules=DEFAULT_RULES, fsdp_axes=()) -> Tuple[int, Any]:
    """Restore ``tree_like`` re-sharded for ``mesh`` (any shape)."""
    shardings = None
    if mesh is not None and specs is not None:
        shardings = param_shardings(specs, mesh, rules, fsdp_axes)
    return ckpt.restore(tree_like, step=step, shardings=shardings)
