"""Phi-3-Vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct] — VLM.

phi3-mini backbone: 32L, d_model=3072, 32 heads (kv=32 -> MHA), d_ff=8192,
vocab 32064, SwiGLU. CLIP ViT-L/14-336 frontend is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings (576 patches, 1024-d)
which a linear projector maps into the backbone embedding space.

This is the *most paper-representative* arch: a true image+text MLLM whose
modalities are routed independently by the MoA-Off policy.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3_072,
    num_heads=32,
    num_kv_heads=32,  # MHA
    head_dim=96,
    d_ff=8_192,
    vocab_size=32_064,
    activation="swiglu",
    frontend="vision_stub",
    num_patches=576,  # ViT-L/14 @ 336px
    frontend_dim=1_024,  # CLIP ViT-L hidden
    rope_theta=10_000.0,
)
