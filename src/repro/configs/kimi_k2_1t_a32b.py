"""Kimi-K2 1T-A32B [arXiv:2501 Kimi K2] — trillion-parameter MoE.

61L, d_model=7168, 64 heads (GQA kv=8), per-expert d_ff=2048, 384 experts
top-8 + 1 shared expert, first layer dense (d_ff 18432), vocab 163840.
~1.04T total params, ~32B active per token.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7_168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18_432,  # dense layers (first_k_dense) width
    vocab_size=163_840,
    activation="swiglu",
    num_experts=384,
    top_k=8,
    moe_d_ff=2_048,
    num_shared_experts=1,
    first_k_dense=1,
    rope_theta=50_000.0,
)
