"""Qwen2.5-VL-7B [arXiv:2502.13923] — the paper's CLOUD model (§4.1).

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab 152064, ViT frontend
(stubbed patch embeddings per the assignment).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3_584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    activation="swiglu",
    frontend="vision_stub",
    num_patches=256,
    frontend_dim=1_280,
    rope_theta=1_000_000.0,
)
