"""DeepSeek-Coder-33B [arXiv:2401.14196] — llama-arch dense GQA.

62L, d_model=7168, 56 heads (GQA kv=8), d_ff=19200, vocab 32256, SwiGLU.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19_200,
    vocab_size=32_256,
    activation="swiglu",
    rope_theta=100_000.0,
)
