"""Architecture registry: the 10 assigned archs + the paper's own edge/cloud pair.

``get_config(name)`` returns the full literature config; ``reduced_config(name)``
returns a CPU-smoke-test-sized config of the same family (small layers/width,
few experts, tiny vocab) — the FULL configs are only exercised via the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.config import ModelConfig

# assigned archs (module name == arch id with '-' -> '_')
ASSIGNED_ARCHS: List[str] = [
    "nemotron-4-340b",
    "qwen3-0.6b",
    "deepseek-coder-33b",
    "yi-34b",
    "phi-3-vision-4.2b",
    "whisper-small",
    "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b",
    "mamba2-2.7b",
    "recurrentgemma-9b",
]

# paper's own testbed models (§4.1): edge = Qwen2-VL-2B, cloud = Qwen2.5-VL-7B
PAPER_ARCHS: List[str] = ["qwen2-vl-2b", "qwen2.5-vl-7b"]

ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS

_cache: Dict[str, ModelConfig] = {}


def _modname(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    if name in _cache:
        return _cache[name]
    if name == "tiny-dense":  # default smoke model for the trainer
        cfg = ModelConfig(
            name="tiny-dense", family="dense", num_layers=2, d_model=128,
            num_heads=4, num_kv_heads=2, d_ff=384, vocab_size=512,
        )
        _cache[name] = cfg
        return cfg
    if name not in ALL_ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_modname(name)}")
    cfg = mod.CONFIG
    assert cfg.name == name, (cfg.name, name)
    _cache[name] = cfg
    return cfg


def list_archs() -> List[str]:
    return list(ALL_ARCHS)


def reduced_config(name: str) -> ModelConfig:
    """Shrink a full config to CPU-smoke size, preserving the family shape."""
    cfg = get_config(name)
    kw = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=32,
        d_ff=0 if cfg.family == "ssm" else 256,
        vocab_size=512,
    )
    if cfg.num_experts:
        kw.update(num_experts=4, top_k=2, moe_d_ff=64,
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  first_k_dense=min(cfg.first_k_dense, 1))
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
    if cfg.block_pattern:
        kw.update(block_pattern=cfg.block_pattern, local_window=32, lru_width=0,
                  num_layers=3)  # one full pattern repeat
    if cfg.encoder_layers:
        kw.update(encoder_layers=2, encoder_seq=32)
    if cfg.frontend != "none":
        kw.update(num_patches=8, frontend_dim=48)
    # keep per-family kv ratios sane under the reduction
    if cfg.num_kv_heads == cfg.num_heads:  # MHA stays MHA
        kw["num_kv_heads"] = kw["num_heads"]
    return dataclasses.replace(cfg, **kw)


def iter_dryrun_cells():
    """Yield (arch, shape_name, skip_reason|None) for all 40 assigned cells."""
    from repro.config import applicable_shapes

    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape_name, reason in applicable_shapes(cfg).items():
            yield arch, shape_name, reason
