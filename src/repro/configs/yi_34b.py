"""Yi-34B [arXiv:2403.04652] — llama-arch dense GQA.

60L, d_model=7168, 56 heads (GQA kv=8), d_ff=20480, vocab 64000, SwiGLU.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7_168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20_480,
    vocab_size=64_000,
    activation="swiglu",
    rope_theta=5_000_000.0,
)
