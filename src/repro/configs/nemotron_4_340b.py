"""Nemotron-4-340B [arXiv:2402.16819] — dense GQA, squared-ReLU MLP.

96L, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab 256000.
Nemotron-4 uses squared-ReLU activation and no gated MLP.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18_432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,  # 18432 / 96
    d_ff=73_728,
    vocab_size=256_000,
    activation="squared_relu",
    rope_theta=10_000.0,
)
