"""Qwen2-VL-2B [arXiv:2409.12191] — the paper's EDGE model (§4.1).

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab 151936, ViT frontend
(stubbed patch embeddings per the assignment).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1_536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8_960,
    vocab_size=151_936,
    activation="swiglu",
    frontend="vision_stub",
    num_patches=256,
    frontend_dim=1_280,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
