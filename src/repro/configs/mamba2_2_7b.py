"""Mamba2-2.7B [arXiv:2405.21060] — pure SSM (state-space duality / SSD).

64L, d_model=2560, attention-free, d_ff=0 (no MLP; the Mamba block IS the
mixer), vocab 50280, ssm_state=128, expand=2 (d_inner=5120), head_dim=64
(80 SSD heads), conv4, chunk=256.

Sub-quadratic: ``long_500k`` decode runs (O(1) state per step).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2_560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    tie_embeddings=True,
)
