"""Whisper-small [arXiv:2212.04356] — encoder-decoder audio model.

12L encoder + 12L decoder, d_model=768, 12 heads (MHA, kv=12), d_ff=3072,
vocab 51865. The conv1d+mel frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (1500 frames x 80 mel features) projected by a
linear layer. Encoder context fixed at 1500 frames (30 s of audio).

Enc-dec: decode shapes lower the decoder step with self-attn KV cache at the
assigned seq_len plus cross-attn KV over the encoder output.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,  # decoder layers
    encoder_layers=12,
    encoder_seq=1_500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3_072,
    vocab_size=51_865,
    activation="gelu",
    frontend="audio_stub",
    frontend_dim=80,  # mel bins
    rope_theta=10_000.0,  # decoder uses learned pos in the original; RoPE here
)
