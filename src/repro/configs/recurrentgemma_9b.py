"""RecurrentGemma-9B [arXiv:2402.19427 Griffin] — RG-LRU + local attention 2:1.

38L, d_model=4096, 16 heads MQA (kv=1), d_ff=12288 (GeGLU), vocab 256000,
block pattern (rglru, rglru, local_attn) repeating, window 2048,
lru_width=4096.

Sub-quadratic (bounded-window attention + recurrent state): ``long_500k`` runs.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,  # rounded to full pattern repeats at build time (36 + 2)
    d_model=4_096,
    num_heads=16,
    num_kv_heads=1,  # MQA
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    activation="geglu",
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2_048,
    lru_width=4_096,
    rope_theta=10_000.0,
)
