"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B family] — dense GQA with qk-norm.

28L, d_model=1024, 16 heads (GQA kv=8), head_dim=128 (decoupled from d_model),
d_ff=3072, vocab 151936, SwiGLU, RMSNorm on Q/K per head (qk_norm).
This is the natural *edge-tier* model in the MoA-Off pairing.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1_024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,  # qwen3 decouples head_dim from d_model/num_heads
    d_ff=3_072,
    vocab_size=151_936,
    activation="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
