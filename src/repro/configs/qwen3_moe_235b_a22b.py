"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3-235B-A22B] — 128-expert top-8 MoE.

94L, d_model=4096, 64 heads (GQA kv=4), per-expert d_ff=1536, 128 experts
top-8, vocab 151936, qk_norm, no shared experts.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4_096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=12_288,  # unused (no dense layers); kept for the cost model's MLP bound
    vocab_size=151_936,
    activation="swiglu",
    qk_norm=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=1_536,
    num_shared_experts=0,
    first_k_dense=0,
    rope_theta=1_000_000.0,
)
