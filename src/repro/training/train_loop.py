"""Training loop: jit'd train_step factory (grad-accum, remat, donation),
fault-tolerant Trainer (auto-resume, preemption save, data-skip on resume).
"""
from __future__ import annotations

import os
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.config import TrainConfig
from repro.configs import get_config
from repro.data.synthetic import make_token_batch
from repro.models import build_model
from repro.training.optimizer import OptState, adamw_update, init_opt_state


def make_train_step(model, opt_cfg, accum: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics), jit-ready.

    ``accum`` > 1 splits the batch into microbatches inside a lax.scan —
    activation memory scales with the microbatch, grads accumulate in f32.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {}
        new_params, new_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg)
        out = {"loss": loss, **om}
        out.update({k: v for k, v in metrics.items()})
        return new_params, new_state, out

    return step


class Trainer:
    """Fault-tolerant single-controller trainer.

    · auto-resumes from the latest checkpoint in ``cfg.checkpoint_dir``;
    · async-checkpoints every ``checkpoint_every`` steps;
    · on SIGTERM/SIGINT (preemption) writes a final checkpoint and stops;
    · the data stream is seeded by (seed, step) so resume skips consumed
      batches deterministically.
    """

    def __init__(self, cfg: TrainConfig, model=None, mesh=None):
        self.cfg = cfg
        mcfg = get_config(cfg.model)
        self.model = model or build_model(mcfg, mesh=mesh,
                                          sharding=cfg.sharding,
                                          param_dtype="float32")
        self.mesh = mesh
        self.ckpt = Checkpointer(cfg.checkpoint_dir, keep=cfg.keep_checkpoints)
        self._step_fn = jax.jit(
            make_train_step(self.model, cfg.optimizer,
                            cfg.sharding.gradient_accum),
            donate_argnums=(0, 1))
        self.params = None
        self.opt_state: Optional[OptState] = None
        self.step = 0
        self.history: list = []
        self._preempted = False

    # ------------------------------------------------------------------

    def _batch(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.cfg.seed << 20) + step)
        b = make_token_batch(rng, self.cfg.batch_size, self.cfg.seq_len,
                             self.model.cfg.vocab_size)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def initialize(self, resume: bool = True) -> None:
        rng = jax.random.PRNGKey(self.cfg.seed)
        self.params = self.model.init(rng)
        self.opt_state = init_opt_state(self.params, self.cfg.optimizer)
        if resume and self.ckpt.latest_step() is not None:
            tree = {"params": self.params, "opt": self.opt_state}
            step, restored = self.ckpt.restore(tree)
            self.params = restored["params"]
            self.opt_state = OptState(restored["opt"].step,
                                      restored["opt"].m, restored["opt"].v)
            self.step = step
            print(f"[trainer] resumed from step {step}")

    def _install_preempt_handler(self) -> None:
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def save(self, async_: bool = True) -> None:
        self.ckpt.save(self.step, {"params": self.params,
                                   "opt": self.opt_state}, async_=async_)

    def train(self, steps: Optional[int] = None) -> list:
        if self.params is None:
            self.initialize()
        self._install_preempt_handler()
        target = self.step + (steps if steps is not None else self.cfg.steps)
        t0 = time.perf_counter()
        while self.step < target and not self._preempted:
            batch = self._batch(self.step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % self.cfg.log_every == 0:
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                self.history.append((self.step, loss))
                print(f"[trainer] step={self.step} loss={loss:.4f} "
                      f"({dt / self.cfg.log_every:.3f}s/step)")
                t0 = time.perf_counter()
            if self.step % self.cfg.checkpoint_every == 0:
                self.save(async_=True)
        self.save(async_=False)  # final/preemption checkpoint is blocking
        self.ckpt.wait()
        return self.history
