from repro.training.optimizer import (OptState, adamw_update,  # noqa
                                      init_opt_state, lr_schedule)
from repro.training.train_loop import Trainer, make_train_step  # noqa
