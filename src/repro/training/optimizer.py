"""AdamW with warmup-cosine schedule, global-norm clipping and ZeRO sharding.

No optax dependency — states are plain pytrees so the checkpointer and the
ZeRO sharding rules treat them exactly like params. ZeRO stage 1/3 placement
is decided in ``repro.distributed.sharding`` (opt states follow the params'
PartitionSpec, plus FSDP over the data axis when requested).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array
    m: Any  # first moment (pytree like params)
    v: Any  # second moment


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params: Any, cfg: OptimizerConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params: Any, grads: Any, state: OptState,
                 cfg: OptimizerConfig) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return (new_p, OptState(step, new_m, new_v),
            {"grad_norm": gnorm, "lr": lr})
