"""Replicated tier pools: ``TierSpec.servers`` engine replicas behind one
tier-level façade.

An :class:`EnginePool` owns N replica transports (local in-process
engines and/or spawn-process workers — see
:mod:`repro.serving.transport`) for one topology tier and gives the
``LiveBackend`` a single surface for:

* **tier-local load balancing** — new submissions go to the least-loaded
  replica by (occupancy, KV headroom) with a deterministic index
  tie-break, so replicated runs are reproducible;
* **replica-aware affinity** — a turn of a parked session is submitted to
  the replica holding its parked KV, and a prompt extending a replica's
  cached prefix prefers that replica (longest stored prefix wins);
* **replica-granular faults** — snapshots/restores target one replica,
  and a crashed replica's restored slots re-home onto sibling replicas
  *inside* the tier (LAN-free wire round trip through the versioned
  ``SlotPayload`` format) before any cross-tier rescue triggers;
* **aggregated observation** — tier load / queue depth / KV headroom /
  counters summed or maxed across replicas for the scheduler, plus the
  raw per-replica vectors (``replica_loads``) the state estimator now
  carries for imbalance visibility.

A pool of ONE local replica is a transparent pass-through: same calls,
same order, bit-identical to the pre-pool single-engine path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.engine import MigrationError
from repro.serving.transport import (FinishedSeq, LocalTransport,
                                     ProcessTransport, ReplicaSpec,
                                     TransportError)

AGG_COUNTERS = ("decode_tokens", "prefill_tokens", "encode_tokens",
                "prefix_hits", "prefix_hit_tokens", "resumed_sessions",
                "resumed_tokens", "parks", "drafted_tokens",
                "accepted_tokens", "spec_rounds")


class EnginePool:
    """N replica transports serving ONE topology tier."""

    def __init__(self, name: str, transports: List):
        if not transports:
            raise ValueError(f"pool {name!r} needs at least one replica")
        self.name = name
        self.transports = list(transports)
        self._owner: Dict[int, int] = {}  # rid -> replica index

    # -- shape --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.transports)

    @property
    def n_alive(self) -> int:
        return sum(tr.alive for tr in self.transports)

    @property
    def cfg(self):
        return self.transports[0].cfg

    @property
    def serving(self):
        return self.transports[0].serving

    @property
    def primary_engine(self):
        """First local replica's engine (None for all-process pools) —
        kept for single-replica back-compat (``server.engines``)."""
        for tr in self.transports:
            if isinstance(tr, LocalTransport):
                return tr.engine
        return None

    @property
    def supports_restore(self) -> bool:
        return all(tr.supports_restore for tr in self.transports)

    def wire_hooks(self, on_admit, on_token, on_warm, on_park) -> None:
        for tr in self.transports:
            tr.wire_hooks(on_admit, on_token, on_warm, on_park)

    def arm_wire_chaos(self, chaos, stats, now_rel) -> None:
        """Arm byzantine message chaos on every replica's event/finish
        stream (link ``events:<tier>/<i>``). Local transports gain the
        sequenced delivery guard; process transports attach chaos to the
        guard they always run."""
        for i, tr in enumerate(self.transports):
            tr.arm_delivery(chaos, stats, now_rel,
                            f"events:{self.name}/{i}")

    def delivery_audit(self) -> List[str]:
        """Invariant check: no replica guard holding frames or gaps."""
        out = []
        for i, tr in enumerate(self.transports):
            guard = getattr(tr, "_guard", None)
            if guard is not None:
                out.extend(guard.audit(f"{self.name}/{i}"))
        return out

    # -- observation --------------------------------------------------------

    def load(self) -> float:
        """Occupied-slot fraction across the pool (1.0 when fully busy)."""
        total = sum(tr.total_slots() for tr in self.transports)
        free = sum(tr.free_slots() for tr in self.transports if tr.alive)
        return 1.0 - free / max(total, 1)

    def replica_loads(self) -> List[float]:
        """Instantaneous per-replica occupied-slot fractions (dead = 1.0)."""
        out = []
        for tr in self.transports:
            total = max(tr.total_slots(), 1)
            free = tr.free_slots() if tr.alive else 0
            out.append(1.0 - free / total)
        return out

    def queue_depth(self) -> int:
        return sum(tr.queue_len() for tr in self.transports)

    def kv_headroom(self) -> float:
        """Best replica's free KV fraction: admission feasibility (a new
        request lands on ONE replica, the least-loaded one)."""
        return max((tr.kv_headroom() for tr in self.transports if tr.alive),
                   default=0.0)

    def occupancy(self) -> int:
        return sum(tr.occupancy() for tr in self.transports)

    def has_free_slot(self) -> bool:
        return any(tr.alive and tr.free_slots() > 0
                   for tr in self.transports)

    def counters(self) -> Dict[str, int]:
        agg = {k: 0 for k in AGG_COUNTERS}
        for tr in self.transports:
            for k, v in tr.counters().items():
                agg[k] = agg.get(k, 0) + int(v)
        return agg

    def __getattr__(self, name: str):
        # aggregated counter properties (pool.decode_tokens etc.) so the
        # launcher/benchmarks read pools like they read engines
        if name in AGG_COUNTERS:
            return self.counters()[name]
        raise AttributeError(name)

    def replica_stats(self) -> List[Dict]:
        """Per-replica utilization row (the launcher's stats line)."""
        out = []
        for i, tr in enumerate(self.transports):
            out.append({
                "replica": i, "kind": tr.kind, "alive": tr.alive,
                "active": tr.total_slots() - tr.free_slots()
                if tr.alive else 0,
                "slots": tr.total_slots(), "queue": tr.queue_len(),
                "kv_headroom": tr.kv_headroom(),
                "decode_tokens": tr.counters().get("decode_tokens", 0)})
        return out

    # -- replica selection --------------------------------------------------

    def _load_key(self, i: int) -> Tuple:
        tr = self.transports[i]
        # least-loaded: fewest queued+active first, most KV headroom
        # second, replica index as the deterministic tie-break
        return (tr.occupancy(), -tr.kv_headroom(), i)

    def _alive(self) -> List[int]:
        return [i for i, tr in enumerate(self.transports) if tr.alive]

    def least_loaded(self, skip: Optional[int] = None,
                     need_slot: bool = False) -> Optional[int]:
        cands = [i for i in self._alive() if i != skip
                 and (not need_slot or self.transports[i].free_slots() > 0)]
        return min(cands, key=self._load_key) if cands else None

    def session_replica(self, sid: str) -> Optional[int]:
        for i in self._alive():
            if self.transports[i].has_session(sid):
                return i
        return None

    def choose(self, tokens: Optional[np.ndarray], extras_fp: bytes,
               session: Optional[str] = None) -> int:
        """Replica for one new submission: parked-session home first, then
        longest cached prefix, then least-loaded (deterministic ties)."""
        if len(self.transports) == 1:
            return 0
        if session is not None:
            home = self.session_replica(session)
            if home is not None:
                return home
        if tokens is not None:
            best_hit, best_i = 0, None
            for i in self._alive():
                hit = self.transports[i].prefix_hit_len(tokens, extras_fp)
                if hit > best_hit or (hit == best_hit and hit > 0
                                      and best_i is not None
                                      and self._load_key(i)
                                      < self._load_key(best_i)):
                    best_hit, best_i = hit, i
            if best_i is not None:
                return best_i
        r = self.least_loaded()
        if r is None:
            raise TransportError(f"pool {self.name!r} has no live replica")
        return r

    # -- request plane ------------------------------------------------------

    def replica_of(self, rid: int) -> Optional[int]:
        return self._owner.get(rid)

    def submit_to(self, r: int, rid: int, tokens, max_new: int, extras,
                  deadline, session) -> None:
        self._owner[rid] = r
        self.transports[r].submit(rid, tokens, max_new, extras,
                                  deadline, session)

    def cancel(self, rid: int) -> None:
        r = self._owner.pop(rid, None)
        if r is not None:
            self.transports[r].cancel(rid)
        else:  # unknown home (e.g. replayed duplicate): sweep the pool
            for tr in self.transports:
                if tr.alive:
                    tr.cancel(rid)

    def poll(self) -> Tuple[List[FinishedSeq], bool, List[int]]:
        """Drive/drain every replica once; merged finished sequences,
        any-activity flag, and rids lost to dead process replicas."""
        fins: List[FinishedSeq] = []
        lost: List[int] = []
        active = False
        for tr in self.transports:
            # dead process replicas still drain their buffered finished
            # sequences and report their in-flight rids as lost
            f, a, l = tr.poll()
            fins.extend(f)
            lost.extend(l)
            active |= a
        for seq in fins:
            self._owner.pop(seq.rid, None)
        for rid in lost:
            self._owner.pop(rid, None)
        return fins, active, lost

    def set_throttle(self, mult: float) -> None:
        for tr in self.transports:
            if tr.alive:
                tr.set_throttle(mult)

    def heartbeat_ok(self) -> bool:
        """Tier heartbeat: ANY live replica responding keeps the tier
        routable (replica-granular loss is handled inside the pool)."""
        return any(tr.alive and tr.heartbeat_ok() for tr in self.transports)

    @property
    def healthy(self) -> bool:
        return any(tr.alive and tr.healthy for tr in self.transports)

    def close(self) -> None:
        for tr in self.transports:
            tr.close()

    # -- partial offload ----------------------------------------------------

    def encode_image(self, image, num_patches: int = 0,
                     frontend_dim: int = 0):
        r = self.least_loaded()
        if r is None:
            raise TransportError(f"pool {self.name!r} has no live replica")
        return self.transports[r].encode_image(image, num_patches,
                                               frontend_dim)

    # -- slot wire (cross-tier migration + intra-tier re-homing) ------------

    def extract_wire(self, rid: int, *, remove: bool = False
                     ) -> Optional[bytes]:
        r = self._owner.get(rid)
        if r is None or not self.transports[r].alive \
                or not self.transports[r].healthy:
            return None
        try:
            wire = self.transports[r].extract_wire(rid, remove=remove)
        except (MigrationError, TransportError):
            return None
        if remove:
            self._owner.pop(rid, None)
        return wire

    def inject_wire(self, wire: bytes, rid: int) -> int:
        """Inject a shipped slot into the least-loaded replica with a free
        slot; raises MigrationError when nothing can take it (the caller
        falls back to a fresh prefill, exactly like the single-engine
        path)."""
        r = self.least_loaded(need_slot=True)
        if r is None:
            raise MigrationError(f"pool {self.name!r}: no replica with a "
                                 f"free slot")
        try:
            self.transports[r].inject_wire(wire)
        except TransportError as e:
            raise MigrationError(str(e)) from e
        self._owner[rid] = r
        return r

    def move_slot(self, rid: int, src: int) -> Optional[int]:
        """Intra-tier re-home: ship ``rid``'s slot off replica ``src`` to a
        sibling through the standard wire. Returns the destination replica
        index, None when nothing moved (no capacity / extract failed — the
        slot is still on ``src``), or -1 when the slot was extracted but
        every inject failed (lost: the caller must resubmit it cold)."""
        dsts = sorted((i for i in self._alive()
                       if i != src and self.transports[i].free_slots() > 0),
                      key=self._load_key)
        if not dsts:
            return None
        try:
            wire = self.transports[src].extract_wire(rid, remove=True)
        except (MigrationError, TransportError):
            return None
        self._owner.pop(rid, None)
        for dst in dsts + [src]:  # last resort: back onto the source
            try:
                self.transports[dst].inject_wire(wire)
            except (MigrationError, TransportError):
                continue
            self._owner[rid] = dst
            return dst if dst != src else None
        return -1

    # -- fault discipline (replica-granular) --------------------------------

    def snapshot_replica(self, r: int) -> dict:
        return self.transports[r].snapshot()

    def restore_replica(self, r: int, snap: dict) -> None:
        tr = self.transports[r]
        tr.restore(snap)
        # ownership of the restored rids returns to r (slots moved away
        # since the snapshot keep their new home — their record.migrated
        # flag keeps them off the replay path)
        for rid in tr.rids():
            self._owner.setdefault(rid, r)

    def rids_on(self, r: int) -> List[int]:
        return self.transports[r].rids()

    def slot_rids_on(self, r: int) -> List[int]:
        return self.transports[r].slot_rids()

    # -- sessions ------------------------------------------------------------

    def has_session(self, sid: str) -> bool:
        return self.session_replica(sid) is not None

    def session_count(self) -> int:
        return sum(tr.session_count() for tr in self.transports if tr.alive)

    def session_ids(self) -> List[str]:
        out: List[str] = []
        for i in self._alive():
            out.extend(self.transports[i].session_ids())
        return out

    def resume_session_wire(self, sid: str) -> Optional[bytes]:
        r = self.session_replica(sid)
        if r is None:
            return None
        return self.transports[r].resume_session_wire(sid)

    def adopt_session_wire(self, sid: str, wire: bytes) -> bool:
        r = self.least_loaded()
        if r is None:
            return False
        return self.transports[r].adopt_session_wire(sid, wire)

    def drop_session(self, sid: str) -> None:
        r = self.session_replica(sid)
        if r is not None:
            self.transports[r].drop_session(sid)

    # -- preemption ----------------------------------------------------------

    def decode_slots(self) -> List[Tuple[int, int]]:
        """(rid, remaining) across local replicas, replica-major order (a
        single local replica reproduces the engine's slot order exactly)."""
        out: List[Tuple[int, int]] = []
        for tr in self.transports:
            if tr.alive:
                out.extend(tr.decode_slots())
        return out


def build_engine_pools(topology, serving, dtype: str = "float32",
                       replicas: Optional[Dict[str, int]] = None,
                       transport: str = "local",
                       serving_overrides: Optional[Dict[str, object]] = None,
                       ) -> Dict[str, EnginePool]:
    """One :class:`EnginePool` per topology tier.

    Replica counts default to each tier's ``TierSpec.servers`` (the
    topology's declared server count, finally instantiated); ``replicas``
    overrides per tier name. Local replicas of a tier share ONE model +
    params build (same ``PRNGKey(tier_index)`` seed as
    ``build_cluster_engines``), so replicated decoding is token-identical
    to the single-engine path at temp=0; process replicas rebuild the
    same params from the same seed in their worker.

    ``serving_overrides`` swaps the shared :class:`ServingConfig` per tier
    name — heterogeneous tiers rarely share slot budgets (an edge device
    admits fewer concurrent decodes than a cloud pod).
    """
    if transport not in ("local", "process"):
        raise ValueError(f"unknown transport {transport!r} "
                         f"(expected 'local' or 'process')")
    pools: Dict[str, EnginePool] = {}
    for i, tier in enumerate(topology.tiers):
        n = max(1, int((replicas or {}).get(tier.name, tier.servers)))
        sv = (serving_overrides or {}).get(tier.name, serving)
        if transport == "process":
            trs: List = [ProcessTransport(ReplicaSpec(
                model=tier.model, serving=sv, dtype=dtype,
                param_seed=i, name=f"{tier.name}/{r}"))
                for r in range(n)]
        else:
            from repro.configs import reduced_config  # local: no cycle
            from repro.models import build_model
            from repro.serving.engine import TierEngine
            import jax

            cfg = reduced_config(tier.model).replace(dtype=dtype)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(i))
            trs = [LocalTransport(TierEngine(model, params, sv))
                   for _ in range(n)]
        pools[tier.name] = EnginePool(tier.name, trs)
    return pools
