"""Declarative, deterministic fault plans for chaos-testing the runtime.

A :class:`FaultPlan` is a schedule of :class:`FaultEvent` windows on the
**epoch-relative** clock (seconds since the runtime's first event), so one
plan drives the analytic backend's virtual clock and the live backend's
monotonic clock identically — the acceptance bar for sim-vs-live chaos
parity. Four event kinds:

* ``crash`` — the tier's node is down for the window: every service attempt
  started inside it faults (heartbeat-detected, then retried/failed through
  the runtime's shared retry budget).
* ``slow`` — service on the tier takes ``magnitude``× as long while the
  window is open (a thermally-throttled / contended node).
* ``degrade`` — the tier's WAN link runs at ``magnitude``× bandwidth;
  ``magnitude == 0`` is a full partition (transfers black-hole and only a
  configured transfer timeout releases them).
* ``flap`` — sugar for periodic crashing: expands into crash windows of
  ``magnitude`` duty cycle (down fraction) every ``period`` seconds.

The scalar ``fail_rate`` the runtime always supported is kept as a shim:
``FaultPlan.from_fail_rate(p)`` compiles it into a plan whose Bernoulli
draws flow through the exact same rng stream as before, so golden metrics
stay bit-identical. Plans are plain data: JSON round-trip via
``to_json``/``from_json`` (the ``--fault-plan`` launcher flag), and
``FaultPlan.storm(seed=...)`` builds a seeded pseudo-random storm for
benchmarks — deterministic given the seed, never drawing at query time.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["FaultEvent", "FaultPlan"]

KINDS = ("crash", "slow", "degrade", "flap")
INF = float("inf")


@dataclass(frozen=True)
class FaultEvent:
    """One fault window. ``t`` is epoch-relative (seconds since the
    runtime's first event); ``duration`` may be infinite (never recovers).
    ``magnitude``: slow -> service-time multiplier (>1), degrade ->
    bandwidth multiplier in [0, 1] (0 = partition), flap -> down duty
    cycle in (0, 1]. ``period`` is the flap cycle length."""

    kind: str
    tier: str
    t: float = 0.0
    duration: float = INF
    magnitude: float = 1.0
    period: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.t < 0 or self.duration < 0:
            raise ValueError("fault windows cannot start/extend before 0")
        if self.kind == "flap" and (self.period <= 0
                                    or not 0 < self.magnitude <= 1):
            raise ValueError("flap needs period > 0 and duty in (0, 1]")
        if self.kind == "degrade" and not 0 <= self.magnitude:
            raise ValueError("degrade magnitude is a bandwidth multiplier")


class FaultPlan:
    """Immutable compiled schedule answering point-in-time queries."""

    def __init__(self, events: Sequence[FaultEvent] = (),
                 fail_rate: float = 0.0):
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self.fail_rate = float(fail_rate)
        # compile: flap -> crash windows; bucket windows per tier
        self._crash: Dict[str, List[Tuple[float, float]]] = {}
        self._slow: Dict[str, List[Tuple[float, float, float]]] = {}
        self._link: Dict[str, List[Tuple[float, float, float]]] = {}
        for e in self.events:
            if e.kind == "crash":
                self._crash.setdefault(e.tier, []).append(
                    (e.t, e.t + e.duration))
            elif e.kind == "flap":
                if math.isinf(e.duration):
                    raise ValueError("flap needs a finite duration")
                cycles = max(1, int(math.ceil(e.duration / e.period)))
                for k in range(cycles):
                    t0 = e.t + k * e.period
                    t1 = min(t0 + e.magnitude * e.period, e.t + e.duration)
                    if t1 > t0:
                        self._crash.setdefault(e.tier, []).append((t0, t1))
            elif e.kind == "slow":
                self._slow.setdefault(e.tier, []).append(
                    (e.t, e.t + e.duration, e.magnitude))
            elif e.kind == "degrade":
                self._link.setdefault(e.tier, []).append(
                    (e.t, e.t + e.duration, e.magnitude))
        for wins in self._crash.values():
            wins.sort()

    # -- queries (t is epoch-relative) --------------------------------------

    @property
    def has_crashes(self) -> bool:
        return bool(self._crash)

    def crashed(self, tier: str, t: float) -> bool:
        return any(t0 <= t < t1 for t0, t1 in self._crash.get(tier, ()))

    def slow_multiplier(self, tier: str, t: float) -> float:
        mult = 1.0
        for t0, t1, m in self._slow.get(tier, ()):
            if t0 <= t < t1:
                mult *= m
        return mult

    def link_multiplier(self, tier: str, t: float) -> float:
        mult = 1.0
        for t0, t1, m in self._link.get(tier, ()):
            if t0 <= t < t1:
                mult *= m
        return mult

    # -- construction --------------------------------------------------------

    @classmethod
    def from_fail_rate(cls, fail_rate: float) -> "FaultPlan":
        """Shim for the legacy scalar knob: the plan carries the Bernoulli
        rate and no windows, and the backends draw it through the SAME rng
        stream the bare ``fail_rate`` used (golden metrics bit-identical)."""
        return cls((), fail_rate=fail_rate)

    @classmethod
    def storm(cls, seed: int, tiers: Sequence[str], duration: float,
              crash_frac: float = 0.5, slow_mult: float = 4.0,
              degrade_mult: float = 0.25) -> "FaultPlan":
        """Seeded pseudo-random fault storm over ``tiers``: one crash
        window, one slow window and one link-degrade window land on rng-
        chosen tiers at rng-chosen offsets inside ``duration``. All draws
        happen HERE — the compiled plan is deterministic data."""
        rng = np.random.default_rng(seed)
        tiers = list(tiers)
        ev = []
        crash_tier = tiers[int(rng.integers(len(tiers)))]
        t0 = float(rng.uniform(0.05, 0.3) * duration)
        ev.append(FaultEvent("crash", crash_tier, t=t0,
                             duration=crash_frac * duration))
        slow_tier = tiers[int(rng.integers(len(tiers)))]
        ev.append(FaultEvent("slow", slow_tier,
                             t=float(rng.uniform(0.0, 0.4) * duration),
                             duration=0.5 * duration, magnitude=slow_mult))
        link_tier = tiers[int(rng.integers(len(tiers)))]
        ev.append(FaultEvent("degrade", link_tier,
                             t=float(rng.uniform(0.1, 0.5) * duration),
                             duration=0.4 * duration,
                             magnitude=degrade_mult))
        return cls(ev)

    # -- JSON round-trip ------------------------------------------------------

    def to_json(self) -> str:
        events = []
        for e in self.events:
            d = asdict(e)
            if math.isinf(d["duration"]):
                d["duration"] = "inf"
            events.append(d)
        return json.dumps({"fail_rate": self.fail_rate, "events": events},
                          sort_keys=True)

    @classmethod
    def from_json(cls, raw: str) -> "FaultPlan":
        obj = json.loads(raw)
        events = []
        for d in obj.get("events", ()):
            d = dict(d)
            if d.get("duration") == "inf":
                d["duration"] = INF
            events.append(FaultEvent(**d))
        return cls(events, fail_rate=float(obj.get("fail_rate", 0.0)))

    def __repr__(self) -> str:
        return (f"FaultPlan({len(self.events)} events, "
                f"fail_rate={self.fail_rate})")
