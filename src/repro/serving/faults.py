"""Declarative, deterministic fault plans for chaos-testing the runtime.

A :class:`FaultPlan` is a schedule of :class:`FaultEvent` windows on the
**epoch-relative** clock (seconds since the runtime's first event), so one
plan drives the analytic backend's virtual clock and the live backend's
monotonic clock identically — the acceptance bar for sim-vs-live chaos
parity. Four event kinds:

* ``crash`` — the tier's node is down for the window: every service attempt
  started inside it faults (heartbeat-detected, then retried/failed through
  the runtime's shared retry budget).
* ``slow`` — service on the tier takes ``magnitude``× as long while the
  window is open (a thermally-throttled / contended node).
* ``degrade`` — the tier's WAN link runs at ``magnitude``× bandwidth;
  ``magnitude == 0`` is a full partition (transfers black-hole and only a
  configured transfer timeout releases them).
* ``flap`` — sugar for periodic crashing: expands into crash windows of
  ``magnitude`` duty cycle (down fraction) every ``period`` seconds.

Byzantine *message-level* fault kinds ride the same windows, keyed per
**wire link** instead of per tier. ``magnitude`` is the per-message
probability in (0, 1]; the event's ``tier`` field selects the link(s):

* ``corrupt`` — flip byte(s) of a frame / slot payload on the wire (the
  receiving side's CRC32 must detect it and raise
  ``TransportError``/``MigrationError``);
* ``msg_drop`` — the message silently vanishes (the sequence layer
  detects the gap and resyncs from the sender's outbox);
* ``msg_dup`` — the message is delivered twice (the per-replica delivery
  ledger suppresses the duplicate);
* ``msg_reorder`` — the message is held and delivered after its
  successor (the sequence layer restores order).

Links are named ``proto:tier`` or ``proto:tier/replica`` — e.g.
``events:edge/0`` (replica 0's sequenced event stream),
``frame:cloud/1`` (a process replica's raw pipe frames),
``migrate:cloud`` / ``session:edge`` (slot-payload transfers landing on
a tier) and ``draft:edge`` (speculative draft blocks). An event's
``tier`` selector matches a link when it equals the full link name, is a
``proto:tier`` prefix of it, names the link's tier, or is ``"*"``.

All draws are made by :class:`WireChaos` from per-(kind, link) counters
hashed with the plan's ``wire_seed`` — no shared rng stream, so the
analytic and live backends make IDENTICAL per-link decisions whenever
they issue the same sequence of queries per link (the byzantine
sim-vs-live parity bar; windows spanning the whole run make window
membership clock-independent too).

The scalar ``fail_rate`` the runtime always supported is kept as a shim:
``FaultPlan.from_fail_rate(p)`` compiles it into a plan whose Bernoulli
draws flow through the exact same rng stream as before, so golden metrics
stay bit-identical. Plans are plain data: JSON round-trip via
``to_json``/``from_json`` (the ``--fault-plan`` launcher flag), and
``FaultPlan.storm(seed=...)`` builds a seeded pseudo-random storm for
benchmarks — deterministic given the seed, never drawing at query time.
"""
from __future__ import annotations

import json
import math
import zlib
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["FaultEvent", "FaultPlan", "WireChaos"]

MSG_KINDS = ("corrupt", "msg_drop", "msg_dup", "msg_reorder")
KINDS = ("crash", "slow", "degrade", "flap") + MSG_KINDS
INF = float("inf")


@dataclass(frozen=True)
class FaultEvent:
    """One fault window. ``t`` is epoch-relative (seconds since the
    runtime's first event); ``duration`` may be infinite (never recovers).
    ``magnitude``: slow -> service-time multiplier (>1), degrade ->
    bandwidth multiplier in [0, 1] (0 = partition), flap -> down duty
    cycle in (0, 1]. ``period`` is the flap cycle length."""

    kind: str
    tier: str
    t: float = 0.0
    duration: float = INF
    magnitude: float = 1.0
    period: float = 1.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (known: {KINDS}); "
                "a stale plan file must fail loudly, not inject silently")
        if self.t < 0 or self.duration < 0:
            raise ValueError("fault windows cannot start/extend before 0")
        if self.kind == "flap" and (self.period <= 0
                                    or not 0 < self.magnitude <= 1):
            raise ValueError("flap needs period > 0 and duty in (0, 1]")
        if self.kind == "degrade" and not 0 <= self.magnitude:
            raise ValueError("degrade magnitude is a bandwidth multiplier")
        if self.kind in MSG_KINDS and not 0 < self.magnitude <= 1:
            raise ValueError(
                f"{self.kind} magnitude is a per-message probability in "
                f"(0, 1], got {self.magnitude}")


class FaultPlan:
    """Immutable compiled schedule answering point-in-time queries."""

    def __init__(self, events: Sequence[FaultEvent] = (),
                 fail_rate: float = 0.0, wire_seed: int = 0):
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self.fail_rate = float(fail_rate)
        self.wire_seed = int(wire_seed)
        # compile: flap -> crash windows; bucket windows per tier
        self._crash: Dict[str, List[Tuple[float, float]]] = {}
        self._slow: Dict[str, List[Tuple[float, float, float]]] = {}
        self._link: Dict[str, List[Tuple[float, float, float]]] = {}
        # message faults bucket per kind: (selector, t0, t1, probability)
        self._msg: Dict[str, List[Tuple[str, float, float, float]]] = {}
        for e in self.events:
            if e.kind in MSG_KINDS:
                self._msg.setdefault(e.kind, []).append(
                    (e.tier, e.t, e.t + e.duration, e.magnitude))
            elif e.kind == "crash":
                self._crash.setdefault(e.tier, []).append(
                    (e.t, e.t + e.duration))
            elif e.kind == "flap":
                if math.isinf(e.duration):
                    raise ValueError("flap needs a finite duration")
                cycles = max(1, int(math.ceil(e.duration / e.period)))
                for k in range(cycles):
                    t0 = e.t + k * e.period
                    t1 = min(t0 + e.magnitude * e.period, e.t + e.duration)
                    if t1 > t0:
                        self._crash.setdefault(e.tier, []).append((t0, t1))
            elif e.kind == "slow":
                self._slow.setdefault(e.tier, []).append(
                    (e.t, e.t + e.duration, e.magnitude))
            elif e.kind == "degrade":
                self._link.setdefault(e.tier, []).append(
                    (e.t, e.t + e.duration, e.magnitude))
        for wins in self._crash.values():
            wins.sort()

    # -- queries (t is epoch-relative) --------------------------------------

    @property
    def has_crashes(self) -> bool:
        return bool(self._crash)

    @property
    def has_msg_faults(self) -> bool:
        return bool(self._msg)

    @staticmethod
    def _sel_match(sel: str, link: str) -> bool:
        """Does selector ``sel`` cover wire link ``link``? Matches the full
        link name, a ``proto:tier`` prefix, the bare tier name, or ``*``."""
        if sel == "*" or sel == link:
            return True
        if link.startswith(sel + "/"):
            return True
        tier = link.split(":", 1)[-1].split("/", 1)[0]
        return sel == tier

    def msg_prob(self, kind: str, link: str, t: float) -> float:
        """Per-message probability of ``kind`` on ``link`` at rel-time ``t``
        (max over matching open windows)."""
        p = 0.0
        for sel, t0, t1, prob in self._msg.get(kind, ()):
            if t0 <= t < t1 and self._sel_match(sel, link):
                p = max(p, prob)
        return p

    def crashed(self, tier: str, t: float) -> bool:
        return any(t0 <= t < t1 for t0, t1 in self._crash.get(tier, ()))

    def slow_multiplier(self, tier: str, t: float) -> float:
        mult = 1.0
        for t0, t1, m in self._slow.get(tier, ()):
            if t0 <= t < t1:
                mult *= m
        return mult

    def link_multiplier(self, tier: str, t: float) -> float:
        mult = 1.0
        for t0, t1, m in self._link.get(tier, ()):
            if t0 <= t < t1:
                mult *= m
        return mult

    # -- construction --------------------------------------------------------

    @classmethod
    def from_fail_rate(cls, fail_rate: float) -> "FaultPlan":
        """Shim for the legacy scalar knob: the plan carries the Bernoulli
        rate and no windows, and the backends draw it through the SAME rng
        stream the bare ``fail_rate`` used (golden metrics bit-identical)."""
        return cls((), fail_rate=fail_rate)

    @classmethod
    def storm(cls, seed: int, tiers: Sequence[str], duration: float,
              crash_frac: float = 0.5, slow_mult: float = 4.0,
              degrade_mult: float = 0.25) -> "FaultPlan":
        """Seeded pseudo-random fault storm over ``tiers``: one crash
        window, one slow window and one link-degrade window land on rng-
        chosen tiers at rng-chosen offsets inside ``duration``. All draws
        happen HERE — the compiled plan is deterministic data."""
        rng = np.random.default_rng(seed)
        tiers = list(tiers)
        ev = []
        crash_tier = tiers[int(rng.integers(len(tiers)))]
        t0 = float(rng.uniform(0.05, 0.3) * duration)
        ev.append(FaultEvent("crash", crash_tier, t=t0,
                             duration=crash_frac * duration))
        slow_tier = tiers[int(rng.integers(len(tiers)))]
        ev.append(FaultEvent("slow", slow_tier,
                             t=float(rng.uniform(0.0, 0.4) * duration),
                             duration=0.5 * duration, magnitude=slow_mult))
        link_tier = tiers[int(rng.integers(len(tiers)))]
        ev.append(FaultEvent("degrade", link_tier,
                             t=float(rng.uniform(0.1, 0.5) * duration),
                             duration=0.4 * duration,
                             magnitude=degrade_mult))
        return cls(ev)

    @classmethod
    def byzantine_storm(cls, seed: int, corrupt: float = 0.5,
                        dup: float = 0.2, drop: float = 0.1,
                        reorder: float = 0.1,
                        links: str = "*") -> "FaultPlan":
        """Whole-run byzantine wire storm: corruption on every link plus
        dup/drop/reorder on the sequenced streams. Infinite windows keep
        the decisions clock-independent (identical across backends)."""
        ev = []
        if corrupt > 0:
            ev.append(FaultEvent("corrupt", links, magnitude=corrupt))
        if dup > 0:
            ev.append(FaultEvent("msg_dup", links, magnitude=dup))
        if drop > 0:
            ev.append(FaultEvent("msg_drop", links, magnitude=drop))
        if reorder > 0:
            ev.append(FaultEvent("msg_reorder", links, magnitude=reorder))
        return cls(ev, wire_seed=seed)

    # -- JSON round-trip ------------------------------------------------------

    def to_json(self) -> str:
        events = []
        for e in self.events:
            d = asdict(e)
            if math.isinf(d["duration"]):
                d["duration"] = "inf"
            events.append(d)
        return json.dumps({"fail_rate": self.fail_rate,
                           "wire_seed": self.wire_seed, "events": events},
                          sort_keys=True)

    @classmethod
    def from_json(cls, raw: str) -> "FaultPlan":
        obj = json.loads(raw)
        events = []
        for d in obj.get("events", ()):
            d = dict(d)
            if d.get("duration") == "inf":
                d["duration"] = INF
            events.append(FaultEvent(**d))
        return cls(events, fail_rate=float(obj.get("fail_rate", 0.0)),
                   wire_seed=int(obj.get("wire_seed", 0)))

    def __repr__(self) -> str:
        return (f"FaultPlan({len(self.events)} events, "
                f"fail_rate={self.fail_rate})")


class WireChaos:
    """Deterministic message-level fault injector for one runtime.

    Every decision hashes ``(wire_seed, kind, link, n)`` with a per-
    (kind, link) counter ``n`` — no rng object, no shared stream, and no
    dependence on PYTHONHASHSEED — so two backends (or a re-run) that
    issue the same sequence of queries per link make identical choices.
    ``stats`` is a shared mutable counter dict (usually the runtime's
    ``wire_stats``) that injection sites and delivery guards bump."""

    def __init__(self, plan: FaultPlan, stats: Optional[Dict[str, int]] = None):
        self.plan = plan
        self.seed = plan.wire_seed
        self.stats: Dict[str, int] = stats if stats is not None else {}
        self._n: Dict[Tuple[str, str], int] = {}

    def bump(self, key: str, n: int = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + n

    def _unit(self, kind: str, link: str) -> float:
        key = (kind, link)
        n = self._n.get(key, 0)
        self._n[key] = n + 1
        h = zlib.crc32(f"{self.seed}|{kind}|{link}|{n}".encode())
        return (h % 999983) / 999983.0

    def decide(self, kind: str, link: str, t: float) -> bool:
        """Draw the fate of one message of ``kind`` on ``link`` at
        rel-time ``t``. Counters only advance inside an open window, so
        whole-run windows preserve cross-backend determinism."""
        p = self.plan.msg_prob(kind, link, t)
        if p <= 0.0:
            return False
        return self._unit(kind, link) < p

    def tamper(self, data: bytes, link: str) -> bytes:
        """Deterministically flip one byte of ``data`` (guaranteed to
        differ: the xor mask is never zero)."""
        if not data:
            return data
        key = ("tamper", link)
        n = self._n.get(key, 0)
        self._n[key] = n + 1
        h = zlib.crc32(f"{self.seed}|tamper|{link}|{n}".encode())
        pos = h % len(data)
        mask = 1 + ((h >> 8) % 255)
        out = bytearray(data)
        out[pos] ^= mask
        return bytes(out)
