"""Tier-local prefix & session KV stores: the hit/miss logic shared by BOTH
execution backends.

``PrefixStore`` holds cache rows keyed by the exact bytes of a token prefix
(plus an extras fingerprint covering any modality payload that occupies
cache positions, e.g. a VLM's image patches). Entries are inserted at
bucket-aligned prefix lengths — the same power-of-two ladder the engine's
bucketed prefill uses — so a lookup only probes the handful of lengths the
store actually holds, longest first. The store is bounded in bytes and
LRU-evicted.

``SessionStore`` parks ONE payload per session id between turns. A resume is
a hit when the new prompt token-for-token extends the parked conversation
(prompt + generated tokens of the previous turn) with at least one new
token.

The ``data`` slot of an entry is opaque to the store: the live
``TierEngine`` keeps real per-slot cache rows (numpy leaves), the
``AnalyticBackend`` keeps only the virtual sizes — both run the SAME
insert/lookup code, so their hit/miss decision traces are identical by
construction (byte budgets aside: the analytic store prices entries with the
analytic ``slot_payload_bytes``, so under a budget tight enough to evict,
eviction order may differ from the live store's exact accounting).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PrefixEntry", "PrefixStore", "SessionStore", "ParkedSession",
           "extension_suffix", "prefix_buckets", "extras_fingerprint"]


def extras_fingerprint(extras: Optional[Dict[str, Any]]) -> bytes:
    """Stable content hash of prefill extras (e.g. vision patches). Two
    prompts share cache positions only when their extras are identical —
    the image occupies the leading positions of a VLM prompt."""
    if not extras:
        return b""
    h = hashlib.sha1()
    for name in sorted(extras):
        arr = np.asarray(extras[name])
        h.update(name.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.digest()


def prefix_buckets(n: int, lo: int = 16) -> List[int]:
    """Bucket-aligned prefix lengths to index a prompt of ``n`` tokens at:
    the power-of-two ladder up to n, plus n itself. Matches the prefill
    bucket ladder so stored prefixes line up with how prompts batch."""
    out = []
    b = lo
    while b < n:
        out.append(b)
        b *= 2
    if n >= lo:
        out.append(n)
    return out


def extension_suffix(cached: np.ndarray, tokens: np.ndarray
                     ) -> Optional[np.ndarray]:
    """The new tokens past ``cached`` when ``tokens`` strictly extends it
    (>= 1 new token), else None."""
    n = len(cached)
    if len(tokens) <= n:
        return None
    if not np.array_equal(np.asarray(tokens[:n]), np.asarray(cached)):
        return None
    return np.asarray(tokens[n:])


@dataclass
class PrefixEntry:
    tokens: np.ndarray  # the exact prefix tokens this entry covers
    extras_fp: bytes
    nbytes: float  # budget charge (live: real row bytes; analytic: priced)
    data: Any = None  # opaque to the store (cache rows / nothing)
    sliceable: bool = True  # rows positionally addressable (dense KV)


class _LRUBytes:
    """OrderedDict-backed LRU with a byte budget (0 disables the store)."""

    def __init__(self, budget_bytes: float):
        self.budget = float(budget_bytes)
        self._d: OrderedDict = OrderedDict()
        self.bytes = 0.0
        self.evictions = 0
        # fired with the entry on EVERY removal (evict / pop / overwrite):
        # the paged engine decrefs an entry's pool pages here, so dropping
        # a store reference and freeing physical pages can never diverge
        self.on_evict = None

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        e = self._d.get(key)
        if e is not None:
            self._d.move_to_end(key)
        return e

    def put(self, key, entry, nbytes: float) -> bool:
        if self.budget <= 0 or nbytes > self.budget:
            return False
        old = self._d.pop(key, None)
        if old is not None:
            self.bytes -= old.nbytes
            if self.on_evict is not None:
                self.on_evict(old)
        self._d[key] = entry
        self.bytes += nbytes
        while self.bytes > self.budget and len(self._d) > 1:
            _, ev = self._d.popitem(last=False)
            self.bytes -= ev.nbytes
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(ev)
        if self.bytes > self.budget:  # the sole entry is itself too big
            _, ev = self._d.popitem(last=False)
            self.bytes = 0.0
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(ev)
            return False
        return True

    def pop(self, key):
        e = self._d.pop(key, None)
        if e is not None:
            self.bytes -= e.nbytes
            if self.on_evict is not None:
                self.on_evict(e)
        return e

    def contains(self, key) -> bool:
        """Membership probe WITHOUT touching recency."""
        return key in self._d

    def keys(self):
        return self._d.keys()


class PrefixStore:
    """Bounded, LRU-evicted store of token-prefix cache rows."""

    def __init__(self, budget_bytes: float, min_prefix: int = 16):
        self.lru = _LRUBytes(budget_bytes)
        self.min_prefix = int(min_prefix)
        self._lengths: Dict[int, int] = {}  # prefix length -> live entries

    @property
    def enabled(self) -> bool:
        return self.lru.budget > 0

    @property
    def evictions(self) -> int:
        return self.lru.evictions

    @staticmethod
    def _key(extras_fp: bytes, tokens: np.ndarray) -> Tuple[bytes, int, bytes]:
        t = np.ascontiguousarray(np.asarray(tokens, np.int64))
        return (extras_fp, len(t), hashlib.sha1(t.tobytes()).digest())

    def insert(self, tokens: np.ndarray, extras_fp: bytes, nbytes: float,
               data: Any = None, sliceable: bool = True) -> bool:
        """Store rows covering exactly ``tokens``. Returns False when the
        store is disabled, the entry exceeds the whole budget, or an entry
        for this prefix already exists (first writer wins: identical
        prefixes produce identical rows, so re-extraction is pure waste)."""
        if not self.enabled or len(tokens) < self.min_prefix:
            return False
        key = self._key(extras_fp, tokens)
        if self.lru.get(key) is not None:
            return False
        entry = PrefixEntry(tokens=np.asarray(tokens).copy(),
                            extras_fp=extras_fp, nbytes=float(nbytes),
                            data=data, sliceable=sliceable)
        before = set(self.lru.keys())
        if not self.lru.put(key, entry, float(nbytes)):
            return False
        self._recount(before)
        return True

    def _recount(self, before) -> None:
        after = set(self.lru.keys())
        for k in before - after:
            n = k[1]
            self._lengths[n] -= 1
            if not self._lengths[n]:
                del self._lengths[n]
        for k in after - before:
            self._lengths[k[1]] = self._lengths.get(k[1], 0) + 1

    def evict_oldest(self) -> Optional[PrefixEntry]:
        """Force out the least-recently-used entry (fires ``on_evict``).
        The paged engine calls this under page pressure: store-held pages
        are spare capacity, reclaimed before a request is ever blocked."""
        if not len(self.lru):
            return None
        before = set(self.lru.keys())
        key = next(iter(self.lru.keys()))
        e = self.lru.pop(key)
        self.lru.evictions += 1
        self._recount(before)
        return e

    def contains(self, tokens: np.ndarray, extras_fp: bytes) -> bool:
        """Exact-prefix membership probe (no recency touch)."""
        return self.lru.contains(self._key(extras_fp, tokens))

    def lookup(self, tokens: np.ndarray, extras_fp: bytes
               ) -> Optional[PrefixEntry]:
        """Longest stored prefix that ``tokens`` strictly extends (the hit
        must leave >= 1 suffix token to produce the next-token logits)."""
        tokens = np.asarray(tokens)
        for n in sorted(self._lengths, reverse=True):
            if n >= len(tokens) or n < self.min_prefix:
                continue
            key = self._key(extras_fp, tokens[:n])
            e = self.lru.get(key)
            if e is not None and np.array_equal(e.tokens, tokens[:n]):
                return e
        return None


@dataclass
class ParkedSession:
    """One parked turn: the tokens the cache rows cover (prompt + generated
    minus the final sampled-but-not-fed token) and an opaque payload."""

    tokens: np.ndarray
    extras_fp: bytes
    nbytes: float
    data: Any = None
    turns: int = 1
    meta: Dict[str, Any] = field(default_factory=dict)


class SessionStore:
    """LRU-bounded sid -> ParkedSession map (one payload per session)."""

    def __init__(self, budget_bytes: float):
        self.lru = _LRUBytes(budget_bytes)

    @property
    def enabled(self) -> bool:
        return self.lru.budget > 0

    @property
    def evictions(self) -> int:
        return self.lru.evictions

    def __len__(self) -> int:
        return len(self.lru)

    def __contains__(self, sid: str) -> bool:
        return self.lru.contains(sid)  # probe only: no recency touch

    def park(self, sid: str, parked: ParkedSession) -> bool:
        parked.nbytes = float(parked.nbytes)
        return self.lru.put(sid, parked, parked.nbytes)

    def ids(self) -> List[str]:
        """Parked session ids, LRU order (oldest first)."""
        return list(self.lru.keys())

    def peek(self, sid: str) -> Optional[ParkedSession]:
        return self.lru.get(sid)

    def resume(self, sid: str) -> Optional[ParkedSession]:
        """Pop the parked payload (its rows are consumed by the resume)."""
        return self.lru.pop(sid)
