"""Refcounted physical-page allocator for the paged KV pool.

The device side of paging is a physical-page leading axis on every big KV
leaf plus one int32 page table per slot (see ``TierEngine``); this module is
the HOST side: which physical pages are free, who holds references to each,
and the gauges the scheduler observes (``pages_total`` / ``pages_free`` /
``pages_shared`` / high-water mark).

Page 0 is the **null page**: every unmapped page-table entry points at it,
so device gathers and scatters never need bounds checks — null-page content
is garbage by construction and every read of it is masked out via the
per-slot absolute-position ``pos`` leaf (pos = -1 entries score ``-1e30``
and underflow to an exact 0 after the softmax exp).

Sharing is plain refcounting: a prefix-store entry or a second slot mapping
the same physical page increfs it; the page returns to the free list when
the LAST reference drops. Copy-on-write discipline is enforced by the
engine: a shared page is only ever mapped strictly BEHIND a slot's write
frontier (the partial boundary page is copied into a fresh private page at
warm admission), so no jitted step ever needs to fault a write.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

__all__ = ["PagePool", "pages_needed"]


def pages_needed(total_rows: int, page_size: int, max_seq: int) -> int:
    """Pages covering ``total_rows`` KV rows, capped at a full sequence."""
    rows = min(max(int(total_rows), 0), int(max_seq))
    return -(-rows // int(page_size))  # ceil


class PagePool:
    """Free-list + refcount bookkeeping over ``num_pages`` physical pages.

    Page ids run 0..num_pages; id 0 is the pinned null page and is never
    handed out. ``page_bytes`` is the per-page device footprint summed over
    every pooled cache leaf (used only for gauge reporting).
    """

    def __init__(self, num_pages: int, page_size: int,
                 page_bytes: float = 0.0):
        if num_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {num_pages}")
        self.num_pages = int(num_pages)  # excludes the null page
        self.page_size = int(page_size)
        self.page_bytes = float(page_bytes)
        # refcnt[0] is the null page, pinned forever
        self.refcnt = np.zeros((self.num_pages + 1,), np.int32)
        self.refcnt[0] = 1
        # LIFO free list: recently freed pages are re-used first (their
        # device lines are most likely still resident)
        self.free_list: List[int] = list(range(self.num_pages, 0, -1))
        self.high_water = 0

    # -- allocation --------------------------------------------------------

    @property
    def pages_free(self) -> int:
        return len(self.free_list)

    @property
    def pages_used(self) -> int:
        return self.num_pages - len(self.free_list)

    @property
    def pages_shared(self) -> int:
        """Physical pages mapped by more than one reader (CoW dedup wins)."""
        return int((self.refcnt[1:] > 1).sum())

    def can_alloc(self, n: int) -> bool:
        return n <= len(self.free_list)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` fresh pages (refcount 1 each); None if short."""
        if n > len(self.free_list):
            return None
        pages = [self.free_list.pop() for _ in range(n)]
        for p in pages:
            assert self.refcnt[p] == 0, (p, int(self.refcnt[p]))
            self.refcnt[p] = 1
        self.high_water = max(self.high_water, self.pages_used)
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        for p in pages:
            p = int(p)
            if p == 0:
                continue
            assert self.refcnt[p] > 0, f"incref of free page {p}"
            self.refcnt[p] += 1

    def decref(self, pages: Sequence[int]) -> int:
        """Drop one reference per page; freed pages rejoin the free list.
        Returns how many pages were physically freed."""
        freed = 0
        for p in pages:
            p = int(p)
            if p == 0:
                continue
            assert self.refcnt[p] > 0, f"decref of free page {p}"
            self.refcnt[p] -= 1
            if self.refcnt[p] == 0:
                self.free_list.append(p)
                freed += 1
        return freed

    def reown(self, owners: Sequence[int]) -> None:
        """Rebuild allocator state from a flat list of page references (one
        entry PER REFERENCE — a page shared by two owners appears twice).
        Restore path: the snapshot records who owns what; refcounts and the
        free list are derived rather than trusted."""
        self.refcnt[:] = 0
        self.refcnt[0] = 1
        for p in owners:
            p = int(p)
            if p:
                self.refcnt[p] += 1
        self.free_list = [p for p in range(self.num_pages, 0, -1)
                          if self.refcnt[p] == 0]
        self.high_water = max(self.high_water, self.pages_used)

    # -- gauges / snapshot -------------------------------------------------

    def gauges(self) -> dict:
        return {
            "pages_total": self.num_pages,
            "pages_free": self.pages_free,
            "pages_shared": self.pages_shared,
            "pages_high_water": self.high_water,
            "page_bytes": self.page_bytes,
        }

    def snapshot(self) -> dict:
        return {"refcnt": self.refcnt.copy(),
                "free_list": list(self.free_list),
                "high_water": self.high_water}

    def restore(self, snap: dict) -> None:
        self.refcnt = snap["refcnt"].copy()
        self.free_list = list(snap["free_list"])
        self.high_water = snap["high_water"]

    def check(self) -> None:
        """Invariant check (tests): every page is free xor referenced."""
        free = set(self.free_list)
        assert len(free) == len(self.free_list), "duplicate free pages"
        for p in range(1, self.num_pages + 1):
            rc = int(self.refcnt[p])
            assert rc >= 0, (p, rc)
            assert (rc == 0) == (p in free), (p, rc, p in free)
        assert int(self.refcnt[0]) >= 1
