"""Per-tier health state machine: the runtime's circuit breaker.

Each tier walks ``healthy -> suspect -> quarantined -> probing -> healthy``
driven by the failure edges the runtime already produces (heartbeat-detected
service faults, transfer timeouts) plus a failure-rate EWMA:

* ``healthy``  — routable; ``suspect_after`` consecutive failures -> suspect.
* ``suspect``  — still routable (a degraded signal the scheduler can weigh),
  one success heals it; ``quarantine_after`` consecutive failures ->
  quarantined.
* ``quarantined`` — the circuit is OPEN: the policy and the runtime route
  around the tier. After ``probe_after_s`` of cool-down the next admission
  request is let through as a *probe*.
* ``probing`` — exactly one in-flight probe; success closes the circuit
  (healthy), failure re-opens it (quarantined, cool-down restarts).

The monitor publishes ``snapshot()`` — tier name -> state string — which the
runtime feeds into ``SystemState.health`` for the scheduler, and answers
``available``/``admit`` for the runtime's own degraded-routing decisions.
All transitions are pure functions of the (time, event) sequence, so the
analytic and live backends drive identical state trajectories from
identical fault plans.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable

from repro.config import ResilienceConfig

__all__ = ["HEALTHY", "SUSPECT", "QUARANTINED", "PROBING",
           "TierHealth", "HealthMonitor", "retry_backoff_s"]

HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"
PROBING = "probing"


def retry_backoff_s(cfg: ResilienceConfig, rid: int, attempt: int) -> float:
    """Capped exponential backoff with deterministic jitter.

    ``attempt`` is 1-based (the first retry waits ~base). The jitter is a
    hash of (rid, attempt) — no rng stream is consumed, so backoff can
    never perturb the golden fault/accuracy draws, and both backends
    compute the identical delay."""
    base = cfg.backoff_base_s * (2.0 ** (max(attempt, 1) - 1))
    jitter = ((rid * 1_000_003 + attempt * 7_919) % 997) / 997.0
    return min(base, cfg.backoff_cap_s) * (1.0 + cfg.backoff_jitter * jitter)


@dataclass
class TierHealth:
    """One tier's breaker state + failure statistics."""

    state: str = HEALTHY
    consecutive_failures: int = 0
    failure_ewma: float = 0.0  # EWMA of the per-attempt failure indicator
    quarantined_at: float = 0.0  # epoch-relative time the circuit opened
    failures: int = 0
    successes: int = 0
    heartbeat_ok: bool = True


class HealthMonitor:
    """Failure-driven circuit breaker over the topology's tiers."""

    def __init__(self, tiers: Iterable[str], cfg: ResilienceConfig):
        self.cfg = cfg
        self.tiers: Dict[str, TierHealth] = {t: TierHealth() for t in tiers}
        self.quarantine_count = 0  # circuit-open transitions (monotonic)
        self.probe_count = 0

    # -- event feeds ---------------------------------------------------------

    def record_failure(self, tier: str, t: float) -> bool:
        """One failed attempt on ``tier`` at epoch-relative ``t``. Returns
        True when this failure OPENED the circuit (healthy/suspect ->
        quarantined, or a failed probe re-opening it) — the runtime's cue
        to rescue parked sessions."""
        h = self.tiers.get(tier)
        if h is None:
            return False
        a = self.cfg.failure_ewma_alpha
        h.failure_ewma = (1 - a) * h.failure_ewma + a
        h.failures += 1
        h.consecutive_failures += 1
        if h.state == PROBING:
            # the probe died: re-open, restart the cool-down
            h.state = QUARANTINED
            h.quarantined_at = t
            self.quarantine_count += 1
            return True
        if h.state == QUARANTINED:
            return False
        if h.consecutive_failures >= self.cfg.quarantine_after:
            h.state = QUARANTINED
            h.quarantined_at = t
            self.quarantine_count += 1
            return True
        if h.consecutive_failures >= self.cfg.suspect_after:
            h.state = SUSPECT
        return False

    def record_success(self, tier: str) -> None:
        """One completed attempt on ``tier``: heals suspect tiers and
        closes the circuit when it was the in-flight probe."""
        h = self.tiers.get(tier)
        if h is None:
            return
        a = self.cfg.failure_ewma_alpha
        h.failure_ewma = (1 - a) * h.failure_ewma
        h.successes += 1
        h.consecutive_failures = 0
        if h.state in (SUSPECT, PROBING):
            h.state = HEALTHY

    def heartbeat(self, tier: str, ok: bool) -> None:
        """Liveness signal (live backend): a stale heartbeat marks a
        healthy tier suspect; it never opens the circuit by itself (only
        real failures do), so backends can't diverge on routing."""
        h = self.tiers.get(tier)
        if h is None:
            return
        h.heartbeat_ok = ok
        if not ok and h.state == HEALTHY:
            h.state = SUSPECT

    # -- queries -------------------------------------------------------------

    def state(self, tier: str) -> str:
        h = self.tiers.get(tier)
        return h.state if h is not None else HEALTHY

    def available(self, tier: str, t: float) -> bool:
        """Pure check: may traffic be placed on ``tier`` now? (Does NOT
        consume the probe slot — use ``admit`` on the placement path.)"""
        h = self.tiers.get(tier)
        if h is None:
            return True
        if h.state == QUARANTINED:
            return t - h.quarantined_at >= self.cfg.probe_after_s
        return h.state != PROBING

    def admit(self, tier: str, t: float) -> bool:
        """Placement check. A quarantined tier past its cool-down admits
        exactly ONE request — the probe — and transitions to probing;
        further requests are refused until the probe resolves."""
        h = self.tiers.get(tier)
        if h is None:
            return True
        if h.state == QUARANTINED:
            if t - h.quarantined_at >= self.cfg.probe_after_s:
                h.state = PROBING
                self.probe_count += 1
                return True
            return False
        if h.state == PROBING:
            return False  # one probe at a time
        return True

    def snapshot(self) -> Dict[str, str]:
        return {t: h.state for t, h in self.tiers.items()}

    def __repr__(self) -> str:
        states = ", ".join(f"{t}={h.state}" for t, h in self.tiers.items())
        return f"HealthMonitor({states})"
