"""Analytic per-request cost model: FLOPs, HBM bytes, transfer bytes, latency.

Drives the discrete-event simulator AND the roofline sanity checks. All
formulas derive from the real ModelConfig (param counts come from the same
spec trees that build the models — no hand-entered sizes).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.config import ModelConfig, TierConfig
from repro.core.request import ModalityInput, Request


@functools.lru_cache(maxsize=64)
def _active_params(cfg: ModelConfig) -> int:
    return cfg.active_param_count()


@functools.lru_cache(maxsize=64)
def _kv_bytes_per_token(cfg: ModelConfig) -> int:
    """KV-cache bytes appended per generated/prefilled token (bf16)."""
    if cfg.family == "ssm":
        return 0  # O(1) state instead
    hd, k = cfg.resolved_head_dim, cfg.num_kv_heads
    n_attn = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn = sum(1 for b in cfg.block_pattern if b == "local_attn") * (
            cfg.num_layers // len(cfg.block_pattern))
    return 2 * n_attn * k * hd * 2  # k+v, bf16


def prefill_flops(cfg: ModelConfig, prompt_tokens: int,
                  image_tokens: int = 0) -> float:
    """2·N_active per token matmul FLOPs + quadratic attention term."""
    s = prompt_tokens + image_tokens
    linear = 2.0 * _active_params(cfg) * s
    if cfg.family == "ssm":
        attn = 2.0 * cfg.num_layers * s * cfg.ssm_chunk * cfg.d_inner
    else:
        hd, h = cfg.resolved_head_dim, cfg.num_heads
        eff_ctx = s
        if cfg.family == "hybrid":
            eff_ctx = min(s, cfg.local_window)
        attn = 4.0 * cfg.num_layers * s * eff_ctx * h * hd / 2.0  # causal half
    return linear + attn


def decode_flops(cfg: ModelConfig, context_len: int) -> float:
    """FLOPs for ONE generated token at the given context length."""
    linear = 2.0 * _active_params(cfg)
    if cfg.family == "ssm":
        attn = 2.0 * cfg.num_layers * cfg.d_inner * cfg.ssm_state
    else:
        hd, h = cfg.resolved_head_dim, cfg.num_heads
        ctx = context_len
        if cfg.family == "hybrid":
            ctx = min(ctx, cfg.local_window)
        attn = 4.0 * cfg.num_layers * ctx * h * hd
    return linear + attn


def decode_hbm_bytes(cfg: ModelConfig, context_len: int) -> float:
    """HBM traffic for one decode step: weights + KV read (the decode bound)."""
    weight_bytes = 2.0 * _active_params(cfg)  # bf16 resident weights
    kv = _kv_bytes_per_token(cfg) * min(
        context_len,
        cfg.local_window if cfg.family == "hybrid" else context_len)
    if cfg.family == "ssm":
        kv = (cfg.num_layers * cfg.ssm_heads * cfg.ssm_head_dim
              * cfg.ssm_state * 4.0)
    return weight_bytes + kv


def weights_bytes(cfg: ModelConfig) -> float:
    return 2.0 * cfg.param_count()


@dataclass
class PhaseCost:
    flops: float
    hbm_bytes: float
    seconds: float


def phase_latency(flops: float, hbm_bytes: float, tier: TierConfig,
                  batch: int = 1) -> float:
    """Roofline latency on a tier: max(compute, memory) + dispatch."""
    t_c = flops / (tier.num_chips * tier.flops_per_s * tier.mfu)
    t_m = hbm_bytes / (tier.num_chips * tier.hbm_bw)
    return max(t_c, t_m) + tier.startup_s / max(batch, 1)


def request_phase_costs(cfg: ModelConfig, prompt_tokens: int,
                        image_tokens: int, decode_tokens: int,
                        tier: TierConfig,
                        cached_tokens: int = 0) -> Dict[str, PhaseCost]:
    """Phase costs of one request. ``cached_tokens`` > 0 is a prefix-cache /
    resumed-session hit: the leading tokens' KV rows are reused, so the
    prefill phase pays only the suffix — the quadratic attention discount
    falls out of the prefix-sum difference (suffix queries still attend the
    full context). HBM keeps the full-context KV traffic (cached rows are
    read back; suffix rows are written)."""
    pf = prefill_flops(cfg, prompt_tokens, image_tokens)
    if cached_tokens > 0:
        cached = min(int(cached_tokens), max(prompt_tokens + image_tokens - 1,
                                             0))
        pf = max(0.0, pf - prefill_flops(cfg, cached))
    pb = 2.0 * _active_params(cfg) + _kv_bytes_per_token(cfg) * (
        prompt_tokens + image_tokens)
    prefill = PhaseCost(pf, pb, phase_latency(pf, pb, tier))
    ctx = prompt_tokens + image_tokens
    df = db = 0.0
    dsec = 0.0
    for i in range(decode_tokens):
        f = decode_flops(cfg, ctx + i)
        b = decode_hbm_bytes(cfg, ctx + i)
        df += f
        db += b
    dsec = phase_latency(df, db, tier)  # amortized (continuous batching)
    return {"prefill": prefill, "decode": PhaseCost(df, db, dsec)}


def transfer_seconds(num_bytes: float, bandwidth_bps: float,
                     rtt_s: float) -> float:
    return rtt_s + 8.0 * num_bytes / max(bandwidth_bps, 1.0)


# -- return path (downlink) ---------------------------------------------------

#: bytes per generated token riding the downlink back to the user (token ids
#: / short detokenized text chunks)
RESPONSE_BYTES_PER_TOKEN = 4.0


def downlink_seconds(num_tokens: float, spec) -> float:
    """Seconds for ``num_tokens`` of response to ride a remote tier's
    downlink back to the user (0 for local tiers). ``TierSpec.downlink_bps``
    sizes the return path; 0 falls back to the (usually symmetric) uplink."""
    if not getattr(spec, "is_remote", False):
        return 0.0
    bps = getattr(spec, "downlink_bps", 0.0) or spec.uplink_bps
    return transfer_seconds(num_tokens * RESPONSE_BYTES_PER_TOKEN, bps,
                            spec.rtt_s)


def embedding_bytes(cfg: ModelConfig) -> float:
    """Bytes of compact patch embeddings shipped for ONE off-fusion image in
    the fusion model's geometry (fp32, matching the live backend's
    ``TierEngine.encode_image`` payload)."""
    return float((cfg.num_patches or 256)
                 * (cfg.frontend_dim or cfg.d_model) * 4.0)


# -- cross-tier speculative decoding ------------------------------------------


def speculation_uplink_bytes(decode_tokens: int, k: int,
                             accept_rate: float) -> float:
    """Expected draft-block bytes riding the target tier's uplink for one
    speculated request: every verify round ships ``k`` proposed token ids
    (priced like the embed_bytes fusion uplink — one lump charged at
    arrival by both execution backends)."""
    a = min(max(float(accept_rate), 0.0), 1.0)
    per_round = a * k + 1.0  # expected commits per round (accepts + bonus)
    rounds = float(np.ceil(max(int(decode_tokens), 0) / per_round))
    return rounds * k * RESPONSE_BYTES_PER_TOKEN


def speculation_costs(target_cfg: ModelConfig, draft_cfg: ModelConfig,
                      target_tier: TierConfig, draft_tier: TierConfig,
                      decode_tokens: int, context_len: int, k: int,
                      accept_rate: float,
                      rtt_s: float = 0.0) -> Dict[str, float]:
    """Analytic schedule of draft-and-verify decode for one request.

    Per round: ``k`` sequential decode steps on the DRAFT tier, one
    round-trip shipping the draft block, and ONE chunked verify on the
    TARGET — a single weights pass covering ``k+1`` positions plus their
    KV reads, which is the whole speedup: the target pays its memory-bound
    weight read once per ``accept_rate*k + 1`` committed tokens instead of
    once per token. Expected commits per round follow the acceptance EWMA;
    total commits always equal ``decode_tokens`` (output is exactly the
    target-only stream).
    """
    a = min(max(float(accept_rate), 0.0), 1.0)
    per_round = a * k + 1.0
    d = max(int(decode_tokens), 0)
    rounds = int(np.ceil(d / per_round)) if d else 0
    drafted = rounds * k
    accepted = max(d - rounds, 0)  # each round commits accepts + 1 bonus
    # draft side: k sequential single-token steps per round
    df = db = 0.0
    for i in range(k):
        df += decode_flops(draft_cfg, context_len + i)
        db += decode_hbm_bytes(draft_cfg, context_len + i)
    draft_round_s = phase_latency(df, db, draft_tier)
    # target side: one chunk = one weights read + (k+1) tokens' compute/KV
    vf = decode_flops(target_cfg, context_len) * (k + 1)
    vb = (decode_hbm_bytes(target_cfg, context_len)
          + (decode_hbm_bytes(target_cfg, context_len)
             - 2.0 * _active_params(target_cfg)) * k)
    verify_round_s = phase_latency(vf, vb, target_tier)
    link_round_s = float(rtt_s)  # block bytes ride the arrival uplink lump
    seconds = rounds * (draft_round_s + link_round_s + verify_round_s)
    return {"rounds": rounds, "drafted": drafted, "accepted": accepted,
            "draft_s": rounds * draft_round_s,
            "verify_s": rounds * verify_round_s,
            "link_s": rounds * link_round_s, "seconds": seconds,
            "draft_flops": rounds * df, "draft_hbm_bytes": rounds * db,
            "verify_flops": rounds * vf, "verify_hbm_bytes": rounds * vb}


# -- cross-tier KV migration -------------------------------------------------

#: tier-to-tier fabric when neither side sits behind a WAN uplink (two edge
#: boxes on the same LAN segment)
LAN_BPS = 10e9
LAN_RTT_S = 0.001


def slot_payload_bytes(cfg: ModelConfig, context_tokens: int) -> float:
    """Analytic size of one migrated slot (``TierEngine.extract_slot``):
    per-token KV rows for the attended context plus any O(1) recurrent state,
    plus a small header/SeqState overhead. Mirrors the live wire format's
    accounting without materializing it."""
    tokens = context_tokens
    state = 0.0
    if cfg.family == "ssm":
        state = (cfg.num_layers * cfg.ssm_heads * cfg.ssm_head_dim
                 * cfg.ssm_state * 4.0)
    elif cfg.family == "hybrid":
        tokens = min(tokens, cfg.local_window)  # ring window rows only
        state = cfg.num_layers * (cfg.lru_width or cfg.d_model) * 4.0
    return _kv_bytes_per_token(cfg) * tokens + state + 2048.0


def migration_seconds(payload_bytes: float, src, dst) -> float:
    """Seconds to ship a slot payload from tier ``src`` to tier ``dst``
    (TierSpec-likes). The payload rides the remote party's WAN uplink —
    preferring the destination's, matching how the runtime routes migration
    transfers through its per-remote-tier link stations — or a LAN hop when
    both tiers are local."""
    if getattr(dst, "is_remote", False):
        return transfer_seconds(payload_bytes, dst.uplink_bps, dst.rtt_s)
    if getattr(src, "is_remote", False):
        return transfer_seconds(payload_bytes, src.uplink_bps, src.rtt_s)
    return transfer_seconds(payload_bytes, LAN_BPS, LAN_RTT_S)


def modality_tokens(cfg: ModelConfig, mod: ModalityInput) -> int:
    """How many backbone tokens a modality contributes."""
    if mod.kind == "image":
        return cfg.num_patches or 256
    if mod.kind == "text":
        return int(mod.meta.get("tokens", 64))
    if mod.kind == "audio":
        return int(mod.meta.get("frames", cfg.encoder_seq or 1500))
    return 0
