from repro.serving.accuracy_model import AccuracyModel, MMBENCH, VQAV2  # noqa
from repro.serving.engine import SeqState, TierEngine  # noqa
from repro.serving.runtime import (AnalyticBackend, ClusterRuntime,  # noqa
                                   LiveBackend)
from repro.serving.simulator import (ClusterSimulator,  # noqa
                                     EdgeCloudSimulator)
from repro.serving.tiers import (ClusterServer, EdgeCloudServer,  # noqa
                                 ServedResult)
