"""One event-driven cluster runtime behind the simulator AND the live server.

``ClusterRuntime`` owns the request lifecycle the paper's control plane
schedules (§3.2): arrival → score/route → per-remote-tier WAN transfer →
remote modality encode → fusion enqueue → service/streaming decode →
complete, plus the hedge / retry / failure edges. It is parameterized by an
:class:`ExecutionBackend`, which decides what "executing" a stage means:

* :class:`AnalyticBackend` — a virtual clock and the analytic cost model:
  service times come from ``serving.cost_model`` over the real ModelConfigs,
  stations are FIFO multi-server queues, failures are sampled.
  ``ClusterSimulator`` is a thin shell over this backend and reproduces the
  pre-refactor metric keys and values exactly.
* :class:`LiveBackend` — the monotonic clock and one real ``TierEngine``
  per tier: partial offload is *executed* (an image routed off-fusion is
  encoded by the routed tier's engine and its compact embeddings ship to
  the fusion tier's prefill extras), decode streams tokens with per-request
  TTFT/SLO tracking and EDF-ordered admission, and hedging / fault recovery
  (engine ``snapshot()``/``restore()``) run against live engines.
  ``ClusterServer`` is a thin shell over this backend.

Both backends share the WAN link model (per-remote-tier uplink stations,
parallel transfers joined before service) and emit the same canonical
lifecycle trace per request (``RequestRecord.events``), which the
sim-vs-live parity test compares timing-aside.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.config import (ClusterTopology, ModelConfig, ResilienceConfig,
                          ServingConfig, SpecConfig, TierSpec)
from repro.core.request import Job, Outcome, Request, RequestRecord
from repro.core.scheduler import MoAOffScheduler
from repro.serving import cost_model as cm
from repro.serving.engine import MigrationError, SlotPayload
from repro.serving.faults import FaultPlan, WireChaos
from repro.serving.health import HealthMonitor, retry_backoff_s
from repro.serving.prefix import (ParkedSession, PrefixStore, SessionStore,
                                  extras_fingerprint, prefix_buckets)


@dataclass(order=True)
class Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


class Station:
    """FIFO multi-server station with failure injection + utilization stats."""

    def __init__(self, name: str, servers: int, fail_rate: float = 0.0):
        self.name = name
        self.servers = servers
        self.busy = 0
        self.queue: List = []
        self.fail_rate = fail_rate
        self.busy_time = 0.0
        self._last_t = 0.0
        self.flops = 0.0
        self.mem_byte_s = 0.0

    def utilization_update(self, t: float):
        self.busy_time += self.busy / max(self.servers, 1) * (t - self._last_t)
        self._last_t = t

    # a station "at capacity" = all servers busy + ~3 queued per server;
    # ℓ = 0.8 (the Eq.5 gate) then corresponds to a ~2-deep queue
    QUEUE_TOLERANCE = 4

    @property
    def load(self) -> float:
        denom = max(self.servers, 1) * self.QUEUE_TOLERANCE
        return min(1.0, (self.busy + len(self.queue)) / denom)


class ExecutionBackend(Protocol):
    """What 'executing' a lifecycle stage means (virtual vs. real)."""

    #: True -> the runtime's clock jumps to each event's timestamp
    #: (discrete-event simulation); False -> events fire when the monotonic
    #: clock reaches them and ``advance`` drives real work in between.
    virtual_clock: bool
    #: scalar b fallback when the topology has no remote tier
    fallback_bandwidth_bps: float

    def bind(self, runtime: "ClusterRuntime") -> None: ...
    def handlers(self) -> Dict[str, Callable[[Event], None]]: ...
    def tier_loads(self) -> Dict[str, float]: ...
    def queue_depths(self) -> Dict[str, int]: ...
    def score_cost_s(self, policy_name: str) -> float: ...
    def embed_bytes(self, tier: str) -> float: ...
    def encode(self, t: float, job: Job) -> None: ...
    def enqueue(self, t: float, job: Job) -> None: ...
    def advance(self) -> bool: ...
    # -- cross-tier KV migration (the runtime decides WHEN, backends HOW) --
    def occupancy(self) -> Dict[str, int]: ...
    def can_migrate(self, src: str, dst: str) -> bool: ...
    def preempt_candidate(self, tier: str, t: float) -> Optional[Job]: ...
    def migrate_extract(self, t: float, donor: Job, carrier: Job, dst: str,
                        *, remove: bool = False) -> Optional[float]: ...
    def migrate_inject(self, t: float, carrier: Job) -> None: ...
    # -- sessions (sticky routing + parked-state moves) --
    def session_tier(self, sid: str) -> Optional[str]: ...
    def session_extract(self, t: float, job: Job, src: str
                        ) -> Optional[float]: ...
    def session_install(self, t: float, job: Job) -> None: ...
    def parked_sessions(self) -> Dict[str, int]: ...


class ClusterRuntime:
    """Backend-agnostic request lifecycle over a :class:`ClusterTopology`."""

    def __init__(self, topology: ClusterTopology, scheduler: MoAOffScheduler,
                 policy_name: str, backend, hedge_after_s: float = 0.0,
                 observed_bandwidth_bps: Optional[float] = None,
                 migrate: bool = False, migrate_threshold: int = 0,
                 hedge_in_service: bool = False, sessions: bool = False,
                 session_move_threshold: int = 0,
                 resilience: Optional[ResilienceConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 spec: Optional[SpecConfig] = None,
                 audit: bool = False):
        self.topology = topology
        self.scheduler = scheduler
        # cross-tier speculative decoding (draft-and-verify): validate the
        # pairing against the topology and share the config with the
        # scheduler (which stamps decisions) unless it brought its own
        if spec is not None:
            topology.tier(spec.draft_tier)
            topology.tier(spec.target_tier)
            if getattr(scheduler, "spec", None) is None:
                scheduler.spec = spec
        self.spec = spec
        self.policy_name = policy_name
        self.backend = backend
        self.hedge_after_s = hedge_after_s
        self.observed_bandwidth_bps = observed_bandwidth_bps
        # cross-tier KV migration: ``migrate`` turns the migrate edge on for
        # hedged clones and fault re-homing; ``migrate_threshold`` > 0
        # additionally preempt-migrates when a tier's occupancy crosses it
        # (and implies ``migrate``). Disabled, every code path is identical
        # to the pre-migration runtime (golden-locked).
        self.migrate_threshold = int(migrate_threshold)
        self.migrate = bool(migrate) or self.migrate_threshold > 0
        # hedge mid-decode stragglers too (speculative backup-task style).
        # Without migration the clone re-prefills from token 0 and races the
        # donor; with migration it receives the donor's cache rows instead —
        # the benchmark's "hedge path with vs without migration" comparison.
        self.hedge_in_service = bool(hedge_in_service) or self.migrate
        self.migrations = 0  # successful cross-tier slot migrations
        # multi-turn sessions: turns route sticky-by-default to the tier
        # holding the session's parked KV; ``session_move_threshold`` > 0
        # instead SHIPS the parked payload to the scheduler's preferred
        # compatible tier when the parked tier is that much busier
        # (occupancy difference), falling back to sticky/cold otherwise.
        self.sessions = bool(sessions)
        self.session_move_threshold = int(session_move_threshold)
        self.session_moves = 0
        # resilience layer: per-tier circuit breaker, retry backoff,
        # deadline shedding, transfer timeouts. The default (all off) makes
        # every path below byte-identical to the pre-resilience runtime.
        self.resilience = resilience or ResilienceConfig()
        self.plan = fault_plan
        # byzantine wire layer: built ONLY when the plan carries message
        # faults — otherwise every wire path below is untouched (legacy
        # byte-identical). ``wire_stats`` is the shared counter dict that
        # injection sites, delivery guards and backends all bump.
        self.wire_stats: Dict[str, int] = {}
        self.wire_chaos: Optional[WireChaos] = (
            WireChaos(fault_plan, stats=self.wire_stats)
            if fault_plan is not None and fault_plan.has_msg_faults
            else None)
        if audit:
            from repro.serving.audit import InvariantAuditor
            self.auditor: Optional[InvariantAuditor] = InvariantAuditor(self)
        else:
            self.auditor = None
        self.health: Optional[HealthMonitor] = (
            HealthMonitor([t.name for t in topology.tiers], self.resilience)
            if self.resilience.health else None)
        # epoch anchor: fault plans are authored in seconds-since-first-
        # event so one plan drives the virtual AND the monotonic clock
        self.t0: Optional[float] = None
        self.run_deadline: Optional[float] = None  # current run()'s budget
        self.shed_count = 0  # deadline-shed requests (terminal)
        self.failed_count = 0  # retry-budget-exhausted requests (terminal)
        self.degraded_routes = 0  # requests re-routed off an open circuit
        self.rescued_sessions = 0  # parked sessions evacuated at quarantine
        self.specs: Dict[str, TierSpec] = {t.name: t for t in topology.tiers}
        self.links: Dict[str, Station] = {
            t.name: Station(f"link:{t.name}", 1)
            for t in topology.tiers if t.is_remote}
        self.events: List[Event] = []
        self._seq = itertools.count()
        self.records: Dict[int, RequestRecord] = {}
        self.outcomes: List[Outcome] = []
        self.t = 0.0
        self.handlers: Dict[str, Callable[[Event], None]] = {
            "arrival": self._on_arrival,
            "transfer_done": self._on_transfer_done,
            "hedge_check": self._on_hedge_check,
            "migrate_done": self._on_migrate_done,
            "session_done": self._on_session_done,
            "retry_enqueue": self._on_retry_enqueue,
            "transfer_timeout": self._on_transfer_timeout,
            "session_rescue_done": self._on_session_rescue_done,
        }
        backend.bind(self)
        self.handlers.update(backend.handlers())

    # ------------------------------------------------------------------

    def _push(self, t: float, kind: str, **payload):
        heapq.heappush(self.events, Event(t, next(self._seq), kind, payload))

    def rel(self, t: float) -> float:
        """Epoch-relative time (seconds since the first processed event):
        the clock :class:`FaultPlan` windows and quarantine cool-downs are
        authored on, bridging virtual and monotonic backend clocks."""
        return t - self.t0 if self.t0 is not None else 0.0

    def submit(self, req: Request) -> None:
        """Schedule a request's arrival (``req.arrival_s`` is on the
        backend's clock: virtual seconds analytic, monotonic live)."""
        self._push(req.arrival_s, "arrival", request=req)

    # -- scheduler feedback ------------------------------------------------

    def _observe(self):
        remote = self.topology.remote_tiers
        # the scalar b of Eq. 5 is the edge<->cloud WAN: the anchor remote
        # tier's uplink unless the server pinned an observed value
        wan = self.observed_bandwidth_bps
        if wan is None:
            wan = (self.topology.default_remote.uplink_bps if remote
                   else self.backend.fallback_bandwidth_bps)
        kv_fn = getattr(self.backend, "kv_headroom", None)
        rep_fn = getattr(self.backend, "replica_loads", None)
        # in-flight + queued WAN transfers count toward the destination
        # tier's queue depth: they are committed near-future load, and
        # without them a bandwidth-saturated remote tier reads as idle —
        # the adaptive-tau controller would keep shedding into the full
        # link instead of pulling work back
        depths = dict(self.backend.queue_depths())
        for tname, link in self.links.items():
            backlog = link.busy + len(link.queue)
            if backlog:
                depths[tname] = depths.get(tname, 0) + backlog
        self.scheduler.observe(
            loads=self.backend.tier_loads(),
            bandwidth_bps=wan,
            bandwidths={t.name: t.uplink_bps for t in remote},
            queue_depths=depths,
            parked=(self.backend.parked_sessions()
                    if self.sessions else None),
            kv=kv_fn() if kv_fn is not None else None,
            replicas=rep_fn() if rep_fn is not None else None,
            health=(self.health.snapshot() if self.health is not None
                    else None))

    # -- lifecycle: arrival ------------------------------------------------

    def _on_arrival(self, ev: Event):
        req: Request = ev.payload["request"]
        rec = self.records.get(req.rid)
        if rec is None:
            rec = RequestRecord(rid=req.rid)
            self.records[req.rid] = rec
        rec.mark("arrival")
        self._observe()
        decision = self.scheduler.route(req)
        # score cost: the modality-aware module runs on the edge CPU/NPU —
        # orders of magnitude below model inference (§4.2.3). The analytic
        # backend charges it as a fixed sub-millisecond virtual cost; live,
        # the real scoring time just elapsed on the monotonic clock.
        score_cost = self.backend.score_cost_s(self.policy_name)
        fusion = self.topology.fusion_tier(decision.routes)
        # session affinity: a turn of a parked session serves WHERE the
        # parked KV lives (sticky-by-default) — unless the parked tier is
        # ``session_move_threshold`` occupancy deeper than the scheduler's
        # pick AND the payload can move (same model), in which case the
        # parked state ships to the preferred tier instead of the request
        # chasing it. Identical rule through both backends.
        sticky = move_src = None
        if self.sessions and req.session:
            parked_tier = self.backend.session_tier(req.session)
            if parked_tier is not None and parked_tier in self.specs:
                if (parked_tier != fusion and self.session_move_threshold > 0
                        and self.backend.can_migrate(parked_tier, fusion)):
                    occ = self.backend.occupancy()
                    if (occ.get(parked_tier, 0) - occ.get(fusion, 0)
                            >= self.session_move_threshold):
                        move_src = parked_tier
                        # the moved rows cover the whole history too: the
                        # turn serves entirely at the destination (same
                        # route override as the sticky branch below)
                        decision = dataclasses.replace(
                            decision,
                            routes={m: fusion for m in decision.routes},
                            reason=decision.reason + "+session-move")
                if move_src is None:
                    fusion = parked_tier
                    sticky = parked_tier
                    # the parked rows cover the WHOLE history (vision
                    # prefix included): the turn serves entirely on the
                    # parked tier, so the scheduler's per-modality picks
                    # are overridden too — otherwise phantom off-fusion
                    # encodes/WAN hops get charged for work that never
                    # happens, and the fusion-tier partial-offload
                    # discount would zero out prefill that IS paid here
                    decision = dataclasses.replace(
                        decision,
                        routes={m: parked_tier for m in decision.routes},
                        reason=decision.reason + "+sticky")
        # graceful degradation: when the serving tier's circuit is open the
        # whole request re-homes to the best available tier (the probe goes
        # through when the cool-down elapsed). Only the FUSION tier gates —
        # quarantined encode-side tiers are already steered around by the
        # health-aware policy, and gating here would leak probe slots.
        if self.health is not None and not self.health.admit(fusion,
                                                             self.rel(ev.t)):
            fb = self._fallback_tier(ev.t, exclude=fusion)
            if fb != fusion:
                fusion = fb
                sticky = move_src = None
                decision = dataclasses.replace(
                    decision, routes={m: fb for m in decision.routes},
                    reason=decision.reason + "+degraded")
                rec.degraded = True
                self.degraded_routes += 1
        rec.mark("routed", fusion)
        if rec.degraded:
            rec.mark("degraded", fusion)
        if sticky is not None:
            rec.mark("sticky", sticky)
        job = Job(request=req, decision=decision, fusion=fusion, tier=fusion,
                  t_start=ev.t, record=rec)
        # cross-tier speculative decoding: honor the scheduler's stamp only
        # when the fused generation still lands on the target tier (the
        # sticky/move/degraded overrides above may have re-homed it) and
        # the draft tier exists here
        sp = decision.speculate
        if (self.spec is not None and sp is not None and fusion == sp[1]
                and sp[0] in self.specs and sticky is None
                and move_src is None and not rec.degraded):
            job.payload["speculate"] = {"draft": sp[0], "target": sp[1],
                                        "k": int(sp[2]),
                                        "alpha": float(sp[3])}
        if move_src is not None:
            self._session_move(ev.t + score_cost, job, move_src)
        # partial offload (§3.2): modalities routed off the fusion tier are
        # encoded where they were routed — the runtime marks the stage, the
        # backend executes it (analytic: charge encode FLOPs to the routed
        # station; live: run the routed engine's frontend and stash the
        # embeddings for the fusion prefill)
        for name in sorted(req.modalities):
            routed = decision.routes.get(name, fusion)
            if routed != fusion:
                rec.mark(f"encode:{name}", routed)
        self.backend.encode(ev.t, job)
        # bytes that must cross a WAN: payloads of remote-routed modalities,
        # tallied per remote tier (their links transfer in parallel)
        remote_bytes: Dict[str, float] = {}
        for name, m in req.modalities.items():
            routed = decision.routes.get(name, fusion)
            if self.specs[routed].is_remote:
                remote_bytes[routed] = (remote_bytes.get(routed, 0.0)
                                        + m.size_bytes)
        if self.specs[fusion].is_remote:
            # the fusion tier's own link carries at minimum the text/prompt
            remote_bytes[fusion] = remote_bytes.get(fusion, 0.0) or 2048.0
            # embeddings of images encoded AWAY from a remote fusion tier
            # ride the fusion uplink too (they used to travel free): the
            # compact patch embeddings must reach the fusion prefill
            emb = sum(self.backend.embed_bytes(fusion)
                      for name, m in req.modalities.items()
                      if m.kind == "image"
                      and decision.routes.get(name, fusion) != fusion)
            remote_bytes[fusion] += emb
            spx = job.payload.get("speculate")
            if spx is not None:
                # draft token blocks ride the target's uplink — priced as
                # one arrival-time lump like the embed_bytes above (the
                # live backend ships the real bytes round by round over
                # the same link)
                remote_bytes[fusion] += cm.speculation_uplink_bytes(
                    req.decode_tokens, spx["k"], spx["alpha"])
        job.transfer_bytes = sum(remote_bytes.values())
        if remote_bytes:
            # each remote tier's payload crosses its OWN uplink; the links
            # run in parallel and service starts when the last one lands
            # (sorted for deterministic event order)
            for tname, nbytes in sorted(remote_bytes.items()):
                self._enqueue_link(ev.t + score_cost, tname, job, nbytes)
        if job.pending_transfers == 0:  # no links, no session move in flight
            self._enqueue_service(ev.t + score_cost, job)
        if self.hedge_after_s > 0:
            self._push(ev.t + self.hedge_after_s, "hedge_check", job=job)
        self._maybe_preempt(ev.t)

    # -- lifecycle: WAN links ----------------------------------------------

    def _link_seconds(self, tier: str, num_bytes: float,
                      t: Optional[float] = None) -> float:
        spec = self.specs[tier]
        sec = cm.transfer_seconds(num_bytes, spec.uplink_bps, spec.rtt_s)
        if self.plan is not None and t is not None:
            mult = self.plan.link_multiplier(tier, self.rel(t))
            if mult <= 0.0:
                return float("inf")  # partitioned: the transfer black-holes
            sec /= mult
        return sec

    def _link_dispatch(self, t: float, xfer: dict) -> None:
        """Start one transfer on its (already reserved) link server. A
        partitioned link (multiplier 0) never lands the transfer — only a
        configured transfer timeout releases the server then."""
        sec = self._link_seconds(xfer["tier"], xfer["bytes"], t)
        if sec != float("inf"):
            self._push(t + sec, "transfer_done", xfer=xfer)
        if self.resilience.transfer_timeout_s > 0:
            self._push(t + self.resilience.transfer_timeout_s,
                       "transfer_timeout", xfer=xfer)

    def _link_release(self, t: float, tier: str) -> None:
        """Free one link server and dispatch the next queued transfer."""
        link = self.links[tier]
        link.utilization_update(t)
        link.busy -= 1
        if link.queue:
            nxt = link.queue.pop(0)
            link.busy += 1
            self._link_dispatch(t, nxt)

    def _enqueue_link(self, t: float, tier: str, job: Job, num_bytes: float,
                      kind: str = "data"):
        """Queue one transfer (a job may hold several, one per remote tier
        its modalities route to); the job proceeds to service only once
        every pending transfer has landed. ``kind`` — "data" (modality
        payloads), "migrate" (slot payloads, resolving into an injection)
        or "session" (parked-session payloads, installed before service) —
        all ride the SAME link stations, queueing behind each other."""
        if kind == "data":
            job.record.mark("transfer", tier)
        xfer = {"job": job, "tier": tier, "bytes": num_bytes, "kind": kind}
        job.pending_transfers += 1
        link = self.links[tier]
        link.utilization_update(t)
        if link.busy < link.servers:
            link.busy += 1
            self._link_dispatch(t, xfer)
        else:
            link.queue.append(xfer)

    def _on_transfer_done(self, ev: Event):
        xfer = ev.payload["xfer"]
        if xfer.get("timed_out"):
            return  # the timeout already released the server and the job
        xfer["landed"] = True
        self._link_release(ev.t, xfer["tier"])
        job: Job = xfer["job"]
        job.pending_transfers -= 1
        if job.pending_transfers == 0:
            if job.payload.pop("xfer_dead", None):
                return  # a sibling timed out: the retry path owns the job
            if xfer["kind"] == "migrate":
                self._migrate_inject(ev.t, job)
            else:
                self._join_transfers(ev.t, job)

    def _on_transfer_timeout(self, ev: Event):
        """A WAN transfer exceeded the configured timeout (a slow or
        partitioned link): release the link server, count one failure
        against the tier's breaker, and recover per transfer kind — data
        payloads spend a retry, lost migration payloads fall back to a
        fresh prefill, lost session payloads cold-prefill."""
        xfer = ev.payload["xfer"]
        if xfer.get("landed") or xfer.get("timed_out"):
            return
        xfer["timed_out"] = True
        tier, job = xfer["tier"], xfer["job"]
        self._link_release(ev.t, tier)
        job.pending_transfers -= 1
        job.record.mark("timeout", tier)
        self._note_failure(ev.t, job, tier)
        kind = xfer["kind"]
        if kind == "migrate":
            job.payload.pop("migration_wire", None)
            job.payload.pop("migration_nbytes", None)
            job.payload.pop("cost_tier", None)  # reprice at the new tier
            donor = job.payload.pop("migration_donor", None)
            if job.record.done:
                return
            if donor is not None and not donor.record.done:
                return  # the donor still decodes: it wins the dead race
            if job.pending_transfers == 0:
                self._enqueue_service(ev.t, job)
            return
        if kind == "session":
            job.payload.pop("session_wire", None)
            job.payload.pop("session_parked", None)
            job.payload.pop("session_pending", None)
            if job.pending_transfers == 0 and not job.record.done:
                self._enqueue_service(ev.t, job)
            return
        # data: the modality payload never reached the remote tier — one
        # failed attempt; the shared failure path retries/re-routes/sheds
        if job.pending_transfers > 0:
            job.payload["xfer_dead"] = True
        self.handle_service_failure(ev.t, job, tier)

    def _join_transfers(self, t: float, job: Job) -> None:
        """All of a job's arrival-side transfers have landed: install any
        moved session payload so admission finds it, then enqueue."""
        if job.payload.pop("session_pending", None):
            self._wire_transfer_fault(t, f"session:{job.tier}", job,
                                      "session_wire")
            self.backend.session_install(t, job)
        self._enqueue_service(t, job)

    # -- byzantine wire faults on slot-payload transfers --------------------

    def _wire_transfer_fault(self, t: float, link: str, job: Job,
                             key: str) -> None:
        """Decide the fate of one landed slot-payload transfer on ``link``.

        Both draws are made unconditionally so the per-link counters (and
        hence every later decision) advance identically in the analytic
        and live backends. Live wires get their actual bytes flipped (the
        receiving CRC raises and the backend counts the detection); the
        analytic backend carries the same verdict as flags its mirror
        consumes. ``wire_tampered`` is popped by whoever detects it — if
        an injection ever succeeds with the flag still set, the backend
        records undetected corruption and the auditor flags the run."""
        wc = self.wire_chaos
        if wc is None:
            return
        rel = self.rel(t)
        corrupt = wc.decide("corrupt", link, rel)
        drop = wc.decide("msg_drop", link, rel)
        if corrupt:
            wire = job.payload.get(key)
            if isinstance(wire, (bytes, bytearray)):
                job.payload[key] = wc.tamper(bytes(wire), link)
            job.payload["wire_tampered"] = True
            wc.bump("corrupt_injected")
        if drop:
            job.payload.pop(key, None)
            job.payload["wire_dropped"] = True
            wc.bump("msgs_dropped")

    def _migrate_inject(self, t: float, carrier: Job) -> None:
        """Single choke point for landing a migration payload (link and
        local paths): byzantine wire faults apply here, then the backend
        injects (falling back to a fresh prefill on a detected fault)."""
        self._wire_transfer_fault(t, f"migrate:{carrier.tier}", carrier,
                                  "migration_wire")
        self.backend.migrate_inject(t, carrier)

    # -- lifecycle: service ------------------------------------------------

    def _enqueue_service(self, t: float, job: Job):
        # deadline-aware load shedding: refuse the FIRST enqueue of a
        # request already past its SLO (hedge clones and retries carry
        # ``t_enqueue`` and are decided on the retry path instead)
        if (self.resilience.shed and not job.record.done
                and "t_enqueue" not in job.payload
                and t >= job.request.arrival_s + job.request.slo_s):
            self.fail_request(t, job, job.tier, "shed")
            return
        job.record.mark("enqueue", job.tier)
        if "t_enqueue" not in job.payload:
            job.payload["t_enqueue"] = t
            job.record.wan_s = t - job.t_start
        self.backend.enqueue(t, job)

    # -- lifecycle: hedging ------------------------------------------------

    def _on_hedge_check(self, ev: Event):
        job: Job = ev.payload["job"]
        if job.record.done or job.hedged:
            return
        # by default only genuinely queued jobs are hedged — a job already
        # being served would pay a full second prefill elsewhere. With
        # ``hedge_in_service`` a mid-decode straggler is hedged too: its
        # clone re-prefills and races (speculative backup task), or — with
        # migration — receives the donor's prefilled slot instead.
        if job.in_service and not self.hedge_in_service:
            return
        others = [n for n in self.specs if n != job.tier]
        if not others:
            return
        loads = self.backend.tier_loads()
        if job.in_service:
            if job.record.migrated:
                return  # already moved once (e.g. preempted): no ping-pong
            cands = [n for n in others
                     if self.backend.can_migrate(job.tier, n)] \
                if self.migrate else []
            if cands:
                alt = min(cands, key=lambda n: (loads.get(n, 0.0), n))
                clone = job.clone(tier=alt)
                clone.hedged = True
                job.hedged = True
                job.record.mark("hedged", alt)
                if not self._try_migrate(ev.t, job, clone, alt):
                    # donor died between the decision and the extract: the
                    # clone falls back to a fresh prefill on that tier
                    self._enqueue_service(ev.t, clone)
                return
            if self.migrate:
                return  # no compatible tier to ship the slot to
        # duplicate to the least-loaded other tier; first copy wins
        alt = min(others, key=lambda n: (loads.get(n, 0.0), n))
        clone = job.clone(tier=alt)
        clone.hedged = True
        job.hedged = True
        job.record.mark("hedged", alt)
        self._enqueue_service(ev.t, clone)

    # -- lifecycle: cross-tier KV migration --------------------------------

    def _try_migrate(self, t: float, donor: Job, carrier: Job, dst: str, *,
                     remove: bool = False, pre: str = "") -> bool:
        """Extract ``donor``'s slot state and ship it to ``dst``, where
        ``carrier`` (the hedge clone, or ``donor`` itself when moving)
        resumes without a second prefill. The payload crosses the remote
        party's WAN link station (queueing like any transfer) or a LAN hop
        when both tiers are local. Returns False — with no state mutated —
        when the backend cannot extract (dead donor, incompatible tiers).

        Unlike a re-prefill hedge clone, an injected copy resumes from the
        donor's EXACT position, so the donor is redundant the moment the
        injection lands: the backend retires it then (the donor still wins
        if it finishes during the transport window; an injection that fails
        falls back to a re-prefill clone and the race survives)."""
        src = donor.tier
        nbytes = self.backend.migrate_extract(t, donor, carrier, dst,
                                              remove=remove)
        if nbytes is None:
            return False
        if carrier is not donor:
            carrier.payload["migration_donor"] = donor
        # migrated/migration_bytes/migrations are committed only when the
        # injection lands (commit_migration) — a transport that ends in the
        # re-prefill fallback must not report a migration
        carrier.payload["migration_nbytes"] = nbytes
        rec = carrier.record
        if pre:
            rec.mark(pre, src)
        carrier.tier = dst
        rec.mark("migrate", dst)
        spec_s, spec_d = self.specs[src], self.specs[dst]
        if spec_d.is_remote:
            self._enqueue_link(t, dst, carrier, nbytes, kind="migrate")
        elif spec_s.is_remote:
            self._enqueue_link(t, src, carrier, nbytes, kind="migrate")
        else:
            self._push(t + cm.migration_seconds(nbytes, spec_s, spec_d),
                       "migrate_done", job=carrier)
        return True

    def _on_migrate_done(self, ev: Event):
        self._migrate_inject(ev.t, ev.payload["job"])

    # -- lifecycle: session moves ------------------------------------------

    def _session_move(self, t: float, job: Job, src: str) -> None:
        """Ship a parked session payload from ``src`` to the job's serving
        tier ahead of the turn (the scheduler preferred a less-loaded
        compatible tier over sticking). Rides the same transport as KV
        migration; an extract that fails (payload evicted meanwhile) leaves
        the turn to a cold prefill."""
        nbytes = self.backend.session_extract(t, job, src)
        if nbytes is None:
            return
        dst = job.tier
        job.record.mark("session_move", dst)
        self.session_moves += 1
        job.payload["session_pending"] = True
        spec_s, spec_d = self.specs[src], self.specs[dst]
        if spec_d.is_remote:
            self._enqueue_link(t, dst, job, nbytes, kind="session")
        elif spec_s.is_remote:
            self._enqueue_link(t, src, job, nbytes, kind="session")
        else:
            job.pending_transfers += 1
            self._push(t + cm.migration_seconds(nbytes, spec_s, spec_d),
                       "session_done", job=job)

    def _on_session_done(self, ev: Event):
        job: Job = ev.payload["job"]
        job.pending_transfers -= 1
        if job.pending_transfers == 0:
            self._join_transfers(ev.t, job)

    def commit_migration(self, carrier: Job) -> None:
        """Called by the backend when an injection actually lands."""
        nbytes = carrier.payload.pop("migration_nbytes", 0.0)
        carrier.record.migrated = True
        carrier.record.migration_bytes += nbytes
        self.migrations += 1

    def _maybe_preempt(self, t: float):
        """Load-triggered preemption: when a tier's occupancy (in-service +
        queued requests) reaches ``migrate_threshold``, move the in-service
        request with the most remaining decode work to the least-occupied
        compatible tier — shipping its cache rows, not re-running its
        prefill. Checked at every arrival (when fresh load lands)."""
        if self.migrate_threshold <= 0:
            return
        occ = self.backend.occupancy()
        if not occ:
            return
        src = max(occ, key=lambda n: (occ[n], n))
        if occ[src] < self.migrate_threshold:
            return
        cands = [n for n in self.specs
                 if n != src and occ.get(n, 0) < occ[src]
                 and self.backend.can_migrate(src, n)]
        if not cands:
            return
        dst = min(cands, key=lambda n: (occ.get(n, 0), n))
        victim = self.backend.preempt_candidate(src, t)
        if victim is None:
            return
        self._try_migrate(t, victim, victim, dst, remove=True, pre="preempt")

    # -- lifecycle: completion ---------------------------------------------

    def finish(self, job: Job, tier: str, latency_s: float, *,
               correct: bool = True,
               tier_flops: Optional[Dict[str, float]] = None,
               tier_mem_bytes: Optional[Dict[str, float]] = None) -> Outcome:
        """Retire a request: exactly one Outcome per record (the caller must
        have won the ``record.done`` race before calling)."""
        req = job.request
        rec = job.record
        rec.mark("complete", tier)
        self.scheduler.observe(latency_s=latency_s)
        if rec.drafted_tokens > 0:
            # acceptance-rate feedback: the EWMA gates future speculation
            self.scheduler.observe(
                acceptance=rec.accepted_tokens / rec.drafted_tokens)
        if self.health is not None:
            self.health.record_success(tier)
        out = Outcome(
            rid=req.rid, latency_s=latency_s, routes=job.decision.routes,
            correct=correct, tier_flops=tier_flops or {},
            tier_mem_bytes=tier_mem_bytes or {},
            transfer_bytes=job.transfer_bytes, hedged=job.hedged,
            retries=job.retries, served_tier=tier, ttft_s=rec.ttft_s,
            on_time=latency_s <= req.slo_s, truncated=rec.truncated,
            migrated=rec.migrated, migration_bytes=rec.migration_bytes,
            warm=rec.warm, warm_tokens=rec.warm_tokens,
            degraded=rec.degraded, drafted_tokens=rec.drafted_tokens,
            accepted_tokens=rec.accepted_tokens)
        rec.outcome = out
        self.outcomes.append(out)
        return out

    # -- lifecycle: failure, degradation & shedding ------------------------

    def _fallback_tier(self, t: float, exclude: str = "") -> str:
        """Best tier to re-home degraded traffic onto: highest capability
        among tiers whose circuit admits traffic (local preferred at equal
        capability — degraded edge-only routing keeps serving, at the
        accuracy the weaker tier can deliver). Falls back to the full tier
        set when everything is quarantined, so routing never deadlocks."""
        pool = [s for n, s in self.specs.items() if n != exclude
                and (self.health is None
                     or self.health.available(n, self.rel(t)))]
        if not pool:
            pool = list(self.specs.values())
        return max(pool,
                   key=lambda s: (s.capability, not s.is_remote, s.name)).name

    def _note_failure(self, t: float, job: Job, tier: str) -> None:
        """Feed one failed attempt into the breaker; on the open transition
        mark the trace and evacuate the tier's parked sessions."""
        if self.health is None:
            return
        if self.health.record_failure(tier, self.rel(t)):
            if not job.record.done:
                job.record.mark("quarantine", tier)
            if self.resilience.rescue_sessions:
                self._rescue_sessions(t, tier)

    def handle_service_failure(self, t: float, job: Job, tier: str) -> None:
        """Shared post-fault path for BOTH backends: feed the breaker,
        spend one retry or fail terminally, re-route a retry whose tier's
        circuit is open, apply capped-exponential backoff, and shed retries
        that provably cannot meet the deadline."""
        job.in_service = False
        self._note_failure(t, job, tier)
        if job.record.done:
            return
        if job.retries >= self.backend.retry_limit(tier):
            self.fail_request(t, job, tier, "retries")
            return
        job.retries += 1
        job.record.mark("retry", tier)
        res = self.resilience
        if self.health is not None and \
                not self.health.admit(job.tier, self.rel(t)):
            # job.fusion stays put: the fallback tier has no embeddings
            # shipped for it, so the full prefill is priced/executed there
            fb = self._fallback_tier(t, exclude=job.tier)
            if fb != job.tier:
                job.tier = fb
                job.record.degraded = True
                self.degraded_routes += 1
                job.record.mark("degraded", fb)
        delay = 0.0
        if res.retry_backoff:
            delay = retry_backoff_s(res, job.request.rid, job.retries)
        if res.shed and t + delay >= (job.request.arrival_s
                                      + job.request.slo_s):
            self.fail_request(t, job, tier, "shed")
            return
        if delay > 0:
            self._push(t + delay, "retry_enqueue", job=job)
        else:
            self._enqueue_service(t, job)  # retry (possibly behind queue)

    def _on_retry_enqueue(self, ev: Event):
        job: Job = ev.payload["job"]
        if job.record.done:
            return  # a hedged twin finished during the backoff window
        self._enqueue_service(ev.t, job)

    def fail_request(self, t: float, job: Job, tier: str,
                     reason: str) -> None:
        """Terminal failure: exactly one failed Outcome per record (shed or
        retry-budget exhaustion), so callers always get an answer for every
        submitted request instead of a silent hang."""
        rec = job.record
        if rec.done:
            return
        rec.done = True
        rec.mark("shed" if reason == "shed" else "failed", tier)
        if reason == "shed":
            self.shed_count += 1
        else:
            self.failed_count += 1
        abandon = getattr(self.backend, "abandon", None)
        if abandon is not None:
            abandon(job)
        req = job.request
        out = Outcome(
            rid=req.rid, latency_s=t - req.arrival_s,
            routes=job.decision.routes, correct=False,
            transfer_bytes=job.transfer_bytes, hedged=job.hedged,
            retries=job.retries, served_tier=tier, ttft_s=rec.ttft_s,
            on_time=False, truncated=rec.truncated, migrated=rec.migrated,
            migration_bytes=rec.migration_bytes, warm=rec.warm,
            warm_tokens=rec.warm_tokens, failed=True, fail_reason=reason,
            degraded=rec.degraded)
        rec.outcome = out
        self.outcomes.append(out)

    def _rescue_sessions(self, t: float, src: str) -> None:
        """Quarantine transition on ``src``: ship its parked sessions to
        the least-occupied compatible available tier (same slot-payload
        transport as migration, one hop — not through the wedged link
        station) so later turns resume warm somewhere healthy."""
        ids_fn = getattr(self.backend, "parked_session_ids", None)
        if ids_fn is None:
            return
        sids = list(ids_fn(src))
        if not sids:
            return
        cands = [n for n in self.specs
                 if n != src and self.backend.can_migrate(src, n)
                 and (self.health is None
                      or self.health.available(n, self.rel(t)))]
        if not cands:
            return
        occ = self.backend.occupancy()
        dst = min(cands, key=lambda n: (occ.get(n, 0), n))
        spec_s, spec_d = self.specs[src], self.specs[dst]
        for sid in sids:
            out = self.backend.session_rescue_extract(t, sid, src)
            if out is None:
                continue
            nbytes, payload = out
            self._push(t + cm.migration_seconds(nbytes, spec_s, spec_d),
                       "session_rescue_done", sid=sid, dst=dst,
                       payload=payload)
            self.rescued_sessions += 1

    def _on_session_rescue_done(self, ev: Event):
        self.backend.session_rescue_install(
            ev.t, ev.payload["sid"], ev.payload["dst"],
            ev.payload["payload"])

    # -- event loop --------------------------------------------------------

    def _next_due(self) -> Optional[Event]:
        if not self.events:
            return None
        if not self.backend.virtual_clock and \
                self.events[0].t > time.monotonic():
            return None
        return heapq.heappop(self.events)

    def run(self, max_wall_s: Optional[float] = None) -> List[Outcome]:
        deadline = (time.monotonic() + max_wall_s
                    if max_wall_s is not None else None)
        # the live backend's idle wait clamps its sleep to this, so a
        # long event-driven doze can never overshoot the caller's budget
        self.run_deadline = deadline
        while True:
            if deadline is not None and time.monotonic() > deadline:
                break
            ev = self._next_due()
            if ev is not None:
                if self.t0 is None:
                    self.t0 = ev.t  # epoch anchor for plan/health clocks
                self.t = ev.t
                self.handlers[ev.kind](ev)
                continue
            if not self.backend.advance():
                break
        if self.auditor is not None:
            self.auditor.final_check()
        return self.outcomes


# ---------------------------------------------------------------------------
# Analytic backend (virtual clock + cost model)
# ---------------------------------------------------------------------------


class AnalyticBackend:
    """Discrete-event execution: service times from the analytic cost model
    over the REAL model configs, FIFO multi-server stations per tier, fault
    injection via heartbeat-detected retries, accuracy sampled from the
    calibrated accuracy model."""

    virtual_clock = True

    def __init__(self, topology: ClusterTopology, acc_model, seed: int = 0,
                 fail_rate: float = 0.0,
                 fallback_bandwidth_bps: float = 300e6,
                 prefix_cache_mb: float = 0.0,
                 session_cache_mb: float = 64.0,
                 prefix_min_tokens: int = 16,
                 max_context_tokens: Optional[int] = None,
                 serving: Optional[ServingConfig] = None):
        from repro.configs import get_config  # local import, no cycle

        # retry budget + heartbeat timeout, shared semantics with the live
        # engines (default ServingConfig keeps the historical detect=2.0)
        self.serving = serving or ServingConfig()
        self.acc = acc_model
        self.rng = np.random.default_rng(seed)
        self.fallback_bandwidth_bps = fallback_bandwidth_bps
        self.specs: Dict[str, TierSpec] = {t.name: t for t in topology.tiers}
        self.models: Dict[str, ModelConfig] = {
            t.name: get_config(t.model) for t in topology.tiers}
        self.stations: Dict[str, Station] = {
            t.name: Station(t.name, t.servers, fail_rate)
            for t in topology.tiers}
        self.encode_flops: Dict[str, float] = {}  # partial-offload side work
        self.active: Dict[str, List[Job]] = {t.name: [] for t in topology.tiers}
        self.fault_draws = 0  # fault-rng draws (one per service start)
        # prefix & session KV reuse: the SAME stores (and therefore the
        # same hit/miss decisions) the live engines run, holding virtual
        # sizes instead of cache rows. The prefix mirror engages only for
        # requests carrying real token ids (content decides a hit).
        self.prefix: Dict[str, PrefixStore] = {
            t.name: PrefixStore(prefix_cache_mb * 1e6,
                                min_prefix=prefix_min_tokens)
            for t in topology.tiers}
        self.parked: Dict[str, SessionStore] = {
            t.name: SessionStore(session_cache_mb * 1e6)
            for t in topology.tiers}
        self.prefix_hits = 0
        self.resumed_sessions = 0
        self.parks = 0
        # mirror of the live engines' cache capacity: a turn whose total
        # context would not fit a ``max_seq``-sized engine cold-prefills
        # there, so the analytic mirror must refuse the hit too. None (the
        # default) skips the check — set it to the engines' max_seq when
        # comparing decision traces against a live cluster.
        self.max_context_tokens = max_context_tokens
        self.rt: Optional[ClusterRuntime] = None

    def bind(self, runtime: ClusterRuntime) -> None:
        self.rt = runtime

    def handlers(self):
        return {"service_done": self._on_service_done,
                "service_failed": self._on_service_failed}

    # -- state the scheduler observes --------------------------------------

    def tier_loads(self) -> Dict[str, float]:
        return {name: st.load for name, st in self.stations.items()}

    def queue_depths(self) -> Dict[str, int]:
        return {name: st.busy + len(st.queue)
                for name, st in self.stations.items()}

    def occupancy(self) -> Dict[str, int]:
        # in-service + queued, the preemption trigger (same composition the
        # live backend reports: occupied slots + waiting)
        return self.queue_depths()

    def score_cost_s(self, policy_name: str) -> float:
        return 5e-4 if policy_name.startswith("moa-off") else 0.0

    def embed_bytes(self, tier: str) -> float:
        return cm.embedding_bytes(self.models[tier])

    # -- prefix & session KV reuse ------------------------------------------

    @staticmethod
    def _req_ids(req: Request) -> Optional[np.ndarray]:
        """Real prompt token ids when the workload carries them (the live
        parity workloads do); None keeps the prefix mirror out of play."""
        text = req.modalities.get("text")
        if text is None or text.data is None:
            return None
        return np.asarray(text.data)

    @staticmethod
    def _req_fp(req: Request) -> bytes:
        """Extras fingerprint over the raw image payloads — a different
        value than the engine's patch-embedding hash, but the same
        equivalence (same image <=> same fingerprint), which is all the
        hit/miss decision needs."""
        data = {n: m.data for n, m in req.modalities.items()
                if m.kind == "image" and m.data is not None}
        return extras_fingerprint(data)

    def _context_tokens(self, req: Request, tier: str) -> Tuple[int, int]:
        """(text, image) backbone tokens of a request on a tier's model."""
        mcfg = self.models[tier]
        text = image = 0
        for m in req.modalities.values():
            n = cm.modality_tokens(mcfg, m)
            if m.kind == "image":
                image += n
            else:
                text += n
        return text, image

    def session_tier(self, sid: str) -> Optional[str]:
        for tier, store in self.parked.items():
            if sid in store:
                return tier
        return None

    def session_extract(self, t: float, job: Job, src: str
                        ) -> Optional[float]:
        rec = self.parked[src].resume(job.request.session)
        if rec is None:
            return None
        job.payload["session_parked"] = rec
        return float(rec.nbytes)

    def session_install(self, t: float, job: Job) -> None:
        tampered = job.payload.pop("wire_tampered", False)
        dropped = job.payload.pop("wire_dropped", False)
        rec = job.payload.pop("session_parked", None)
        if rec is None or dropped:
            return  # lost on the wire: the turn cold-prefills
        if tampered:
            # the live twin's CRC rejects the payload at adopt: mirror the
            # detection and the cold-prefill recovery
            self.rt.wire_chaos.bump("corrupt_detected")
            return
        self.parked[job.tier].park(job.request.session, rec)

    def parked_sessions(self) -> Dict[str, int]:
        return {tier: len(store) for tier, store in self.parked.items()}

    def _warm_state(self, job: Job) -> Optional[Tuple[str, int]]:
        """(kind, cached_tokens) when this admission lands on reused rows —
        the mirror of ``TierEngine._warm_plan``: a parked session this turn
        extends wins over a stored prefix; either discounts the prefill to
        the suffix. The analytic session rule cannot compare generated
        token content (it never materializes tokens), so a turn counts as
        extending when its context strictly grew — live and analytic
        decisions agree for well-formed multi-turn histories."""
        req = job.request
        tier = job.tier
        text, image = self._context_tokens(req, tier)
        sid = req.session
        store = self.parked.get(tier)
        if (self.rt.sessions and sid and store is not None
                and store.enabled):
            rec = store.peek(sid)
            if rec is not None and rec.extras_fp == self._req_fp(req):
                cached = int(rec.meta.get("context", 0))
                fits = (self.max_context_tokens is None
                        or text + image + 1 < self.max_context_tokens)
                if text + image > cached and fits:
                    store.resume(sid)  # rows consumed by this turn
                    return ("resume", cached)
        pstore = self.prefix.get(tier)
        if pstore is not None and pstore.enabled:
            ids = self._req_ids(req)
            if ids is not None:
                e = pstore.lookup(ids, self._req_fp(req))
                if e is not None:
                    return ("prefix", len(e.tokens) + image)
        return None

    def _store_prefixes(self, job: Job) -> None:
        """Mirror of ``TierEngine._store_prefixes``: deposit this prompt's
        prefixes (virtual sizes) at bucket-aligned lengths — exact length
        only for the point-in-time state families."""
        tier = job.tier
        pstore = self.prefix.get(tier)
        if pstore is None or not pstore.enabled:
            return
        ids = self._req_ids(job.request)
        if ids is None:
            return
        fp = self._req_fp(job.request)
        mcfg = self.models[tier]
        _, image = self._context_tokens(job.request, tier)
        sliceable = mcfg.family in ("dense", "vlm", "moe")
        lengths = (prefix_buckets(len(ids), pstore.min_prefix)
                   if sliceable else [len(ids)])
        for n in lengths:
            if n < pstore.min_prefix or pstore.contains(ids[:n], fp):
                continue
            pstore.insert(ids[:n], fp,
                          cm.slot_payload_bytes(mcfg, n + image),
                          sliceable=sliceable)

    def _maybe_park(self, job: Job) -> None:
        """Park a completing session turn: remember how much context its
        virtual cache rows cover and what it would cost to ship them."""
        req = job.request
        store = self.parked.get(job.tier)
        if not (self.rt.sessions and req.session and store is not None
                and store.enabled):
            return
        text, image = self._context_tokens(req, job.tier)
        # the rows cover prompt + generated minus the final sampled token
        context = text + image + max(req.decode_tokens - 1, 0)
        nbytes = cm.slot_payload_bytes(self.models[job.tier], context)
        ids = self._req_ids(req)
        ok = store.park(req.session, ParkedSession(
            tokens=(np.zeros(0, np.int32) if ids is None else ids),
            extras_fp=self._req_fp(req), nbytes=float(nbytes),
            meta={"context": context}))
        if ok:
            self.parks += 1
            job.record.mark("park", job.tier)

    # -- cross-tier KV migration --------------------------------------------

    def can_migrate(self, src: str, dst: str) -> bool:
        """KV rows only make sense between tiers serving the SAME model."""
        return (src != dst and src in self.models and dst in self.models
                and self.models[src].name == self.models[dst].name)

    def preempt_candidate(self, tier: str, t: float) -> Optional[Job]:
        """In-service job with the most remaining service time (never one
        already hedged or previously migrated)."""
        best, best_key = None, None
        for job in self.active.get(tier, ()):
            if job.record.done or job.record.migrated or job.hedged:
                continue
            rem = job.payload["t_serve"] + job.payload["service_s"] - t
            if rem <= 0:
                continue
            key = (rem, -job.request.rid)
            if best is None or key > best_key:
                best, best_key = job, key
        return best

    def migrate_extract(self, t: float, donor: Job, carrier: Job, dst: str,
                        *, remove: bool = False) -> Optional[float]:
        """Virtual extract: size the payload from the donor's attended
        context and reprice the carrier as decode-remainder-only on ``dst``
        (the shipped rows replace the prefill AND the already-generated
        fraction of the decode)."""
        if not self.can_migrate(donor.tier, dst):
            return None
        p = donor.payload
        if "t_serve" not in p or p.get("cost_tier") != donor.tier:
            return None  # not in service here: nothing prefilled to ship
        total, pre = p["service_s"], p["service_prefill_s"]
        elapsed = max(0.0, t - p["t_serve"])
        if elapsed < pre:
            # still mid-prefill: there are no cache rows to ship yet (the
            # live engine can only extract an admitted, post-prefill slot)
            return None
        frac = min(1.0, max(0.0, (elapsed - pre) / max(total - pre, 1e-9)))
        req = donor.request
        ctx = int(p.get("service_context", 0.0)
                  + frac * req.decode_tokens)
        nbytes = cm.slot_payload_bytes(self.models[donor.tier], ctx)
        if remove:
            # preemption: release the donor's server NOW and drop its stale
            # completion event when it fires
            self._release_in_service(t, donor)
        # price the carrier's service on dst: decode remainder only
        tier0 = carrier.tier
        carrier.tier = dst
        c = self._service_request(carrier)
        carrier.tier = tier0
        scale = 1.0 - frac
        sec = c["decode_s"] * scale
        carrier.payload.update(
            service_s=sec, service_flops=c["decode_flops"] * scale,
            service_mem=c["mem_byte_s"] * sec / max(c["seconds"], 1e-9),
            service_prefill_s=0.0,
            service_decode_flops=c["decode_flops"] * scale,
            service_context=ctx, cost_tier=dst)
        carrier.in_service = False
        return float(nbytes)

    def _release_in_service(self, t: float, job: Job) -> None:
        """Free the server a genuinely-in-service job occupies, charge its
        tier for the work expended so far, and drop its stale completion
        event. A no-op when the job is no longer in service there (e.g. a
        fault retried it mid-transport), so the station's ``busy`` count
        can never be corrupted by a stale retirement."""
        if job not in self.active.get(job.tier, ()):
            return
        p = job.payload
        st = self.stations[job.tier]
        total, pre = p["service_s"], p["service_prefill_s"]
        elapsed = max(0.0, t - p["t_serve"])
        frac = min(1.0, max(0.0, (elapsed - pre) / max(total - pre, 1e-9)))
        # work done before moving: the WHOLE prefill (migration only happens
        # post-prefill) plus the decoded fraction
        dec_f = p.get("service_decode_flops", 0.0)
        st.flops += (p["service_flops"] - dec_f) + dec_f * frac
        st.mem_byte_s += p["service_mem"] * (
            (pre + frac * (total - pre)) / max(total, 1e-9))
        p.setdefault("preempted", []).append(job.tier)
        self._active_remove(job.tier, job)
        self._next_from_queue(t, st)

    def migrate_inject(self, t: float, carrier: Job) -> None:
        tampered = carrier.payload.pop("wire_tampered", False)
        dropped = carrier.payload.pop("wire_dropped", False)
        donor = carrier.payload.pop("migration_donor", None)
        if carrier.record.done:
            carrier.payload.pop("migration_nbytes", None)
            return  # the donor finished during the transport window
        if tampered or dropped:
            # mirror of the live CRC rejection / vanished payload: no
            # commit, the donor (if any) keeps racing, and the carrier
            # falls back to a fresh prefill priced at the new tier
            if tampered:
                self.rt.wire_chaos.bump("corrupt_detected")
            carrier.payload.pop("migration_nbytes", None)
            carrier.payload.pop("cost_tier", None)  # reprice: full prefill
            self.rt._enqueue_service(t, carrier)
            return
        if donor is not None and not donor.record.done:
            # the injected copy resumes at the donor's exact position on a
            # fresher tier: retire the donor now (release its server, drop
            # its stale completion) instead of decoding the tail twice
            self._release_in_service(t, donor)
        self.rt.commit_migration(carrier)
        self.rt._enqueue_service(t, carrier)

    # -- cost model ---------------------------------------------------------

    def _service_request(self, job: Job,
                         cached_tokens: int = 0) -> Dict[str, float]:
        """Phase-split cost of one fused inference on ``job.tier``.

        Pure function of (request, routes, serving tier) — all accounting
        side effects live with the callers, so it can be re-evaluated (e.g.
        for a hedged clone on another tier) without double charging. The
        prefill/decode split lets the migration path price a clone that
        receives the donor's cache rows (decode remainder only).
        ``cached_tokens`` > 0 is a warm admission: prefill pays the suffix
        only (see ``cost_model.request_phase_costs``).
        """
        req = job.request
        tier = job.tier
        mcfg = self.models[tier]
        tcfg = self.specs[tier]
        text_tokens = 0
        image_tokens = 0
        for m in req.modalities.values():
            n = cm.modality_tokens(mcfg, m)
            if m.kind == "image":
                image_tokens += n
            else:
                text_tokens += n
        # the paper's "severe latency tail typical of edge-only models
        # struggling with difficult samples": a weak model rambles /
        # re-derives on inputs beyond its capability knee -> decode length
        # grows with difficulty, scaled by how far the tier sits from
        # cloud-class capability (easy inputs run at full speed)
        decode_tokens = req.decode_tokens
        weakness = 1.0 - tcfg.capability
        if weakness > 0:
            decode_tokens = int(decode_tokens * (
                1.0 + 14.0 * weakness * max(0.0, req.difficulty - 0.45)))
        # PARTIAL offloading (§3.2): modalities routed to another tier of a
        # fused request are ENCODED there — only their compact embeddings
        # ride along, so the serving tier never spends prefill FLOPs on
        # them (images included; their encode is charged to the routed
        # tier's station by ``encode``). The discount belongs to the PLANNED
        # fusion tier only: a hedged clone running elsewhere has no
        # embeddings waiting for it and must prefill everything.
        if tier == job.fusion:
            routes = job.decision.routes
            off_text = off_img = 0
            for nm, m in req.modalities.items():
                if routes.get(nm, tier) == tier:
                    continue
                if m.kind == "image":
                    off_img += cm.modality_tokens(mcfg, m)
                else:
                    off_text += cm.modality_tokens(mcfg, m)
            text_tokens = max(0, text_tokens - off_text)
            image_tokens = max(0, image_tokens - off_img)
        costs = cm.request_phase_costs(mcfg, text_tokens, image_tokens,
                                       decode_tokens, tcfg,
                                       cached_tokens=cached_tokens)
        decode_s = costs["decode"].seconds
        decode_flops = costs["decode"].flops
        spec_stats: Dict[str, float] = {}
        spx = job.payload.get("speculate")
        if (spx is not None and tier == spx["target"]
                and spx["draft"] in self.models):
            # draft-and-verify decode: the draft tier proposes k-token
            # blocks, the target verifies each block in ONE chunked pass —
            # its memory-bound weight read amortizes over the accepted
            # prefix. Decode seconds come from the speculative schedule;
            # the target's flops become the (k+1)-wide verify chunks, and
            # the draft tier's work is stashed for _on_service_done to
            # charge to the DRAFT station (like off-fusion ``encode``).
            sc = cm.speculation_costs(
                mcfg, self.models[spx["draft"]], tcfg,
                self.specs[spx["draft"]], decode_tokens,
                text_tokens + image_tokens, spx["k"], spx["alpha"],
                rtt_s=self.specs[spx["draft"]].rtt_s or tcfg.rtt_s)
            decode_s = sc["seconds"]
            decode_flops = sc["verify_flops"]
            spec_stats = {"spec_rounds": sc["rounds"],
                          "spec_drafted": sc["drafted"],
                          "spec_accepted": sc["accepted"],
                          "spec_draft_flops": sc["draft_flops"],
                          "spec_draft_hbm": sc["draft_hbm_bytes"],
                          "spec_draft_s": sc["draft_s"]}
        sec = costs["prefill"].seconds + decode_s
        flops = costs["prefill"].flops + decode_flops
        kv = cm._kv_bytes_per_token(mcfg) * (text_tokens + image_tokens
                                             + req.decode_tokens)
        mem_byte_s = (cm.weights_bytes(mcfg) / max(tcfg.servers, 1)
                      + kv) * sec
        out = {"seconds": sec, "flops": flops, "mem_byte_s": mem_byte_s,
               "prefill_s": costs["prefill"].seconds,
               "decode_s": decode_s,
               "decode_flops": decode_flops,
               "context_tokens": float(text_tokens + image_tokens)}
        out.update(spec_stats)
        return out

    def encode(self, t: float, job: Job) -> None:
        """Partial-offload encode work: every modality routed away from the
        fusion tier is charged ONCE, at arrival, to the encoding tier's
        station counters (the virtual-clock analogue of running the remote
        encoder — images included, matching the live backend's executed
        off-fusion ``encode_image``)."""
        req, fusion = job.request, job.fusion
        routes = job.decision.routes
        for nm, m in req.modalities.items():
            routed = routes.get(nm, fusion)
            if routed == fusion:
                continue
            enc_cfg = self.models[routed]
            spec = self.specs[routed]
            toks = cm.modality_tokens(enc_cfg, m)
            if toks <= 0:
                continue
            enc = cm.request_phase_costs(enc_cfg, toks, 0, 0, spec)["prefill"]
            kv = cm._kv_bytes_per_token(enc_cfg) * toks
            mem = (cm.weights_bytes(enc_cfg) / max(spec.servers, 1)
                   + kv) * enc.seconds
            st = self.stations[routed]
            st.flops += enc.flops
            st.mem_byte_s += mem
            self.encode_flops[routed] = (self.encode_flops.get(routed, 0.0)
                                         + enc.flops)

    # -- stations ------------------------------------------------------------

    def enqueue(self, t: float, job: Job) -> None:
        st = self.stations[job.tier]
        st.utilization_update(t)
        if st.busy < st.servers:
            self.start_service(t, st, job)
        else:
            st.queue.append(job)

    def start_service(self, t: float, st: Station, job: Job) -> None:
        st.busy += 1
        job.in_service = True
        # compute once per (job, tier) and cache — _on_service_done reads
        # the cached values, so resources are charged exactly once. Warm
        # (prefix-hit / resumed-session) state is decided here, the
        # analytic analogue of engine admission: the service then pays
        # suffix-only prefill.
        if job.payload.get("cost_tier") != job.tier:
            warm = self._warm_state(job)
            cached = 0
            if warm is not None:
                kind, cached = warm
                rec = job.record
                rec.warm = kind
                rec.warm_tokens += cached
                rec.mark(kind, job.tier)
                if kind == "resume":
                    self.resumed_sessions += 1
                else:
                    self.prefix_hits += 1
            self._store_prefixes(job)
            c = self._service_request(job, cached_tokens=cached)
            job.payload.update(service_s=c["seconds"],
                               service_flops=c["flops"],
                               service_mem=c["mem_byte_s"],
                               service_prefill_s=c["prefill_s"],
                               service_decode_flops=c["decode_flops"],
                               service_context=c["context_tokens"],
                               cost_tier=job.tier)
            if "spec_rounds" in c:
                job.payload["spec_stats"] = {
                    k: c[k] for k in ("spec_rounds", "spec_drafted",
                                      "spec_accepted", "spec_draft_flops",
                                      "spec_draft_hbm", "spec_draft_s")}
            else:
                job.payload.pop("spec_stats", None)
        job.record.mark("serve", job.tier)
        if job.payload.get("spec_stats"):
            # one draft/verify triplet per request (not per round) so the
            # analytic trace matches the live co-drive's marks
            spx = job.payload["speculate"]
            job.record.mark("draft", spx["draft"])
            job.record.mark("verify", job.tier)
        job.payload["t_serve"] = t
        self.active[job.tier].append(job)
        sec = job.payload["service_s"]
        # fault injection: the node serving this job dies mid-flight and the
        # failure is detected after a heartbeat timeout, then retried. The
        # Bernoulli draw keeps its historical rng-stream position (one draw
        # per service start whenever fail_rate > 0); plan crash windows
        # stack on top without consuming the stream, and slow windows
        # stretch the service time of attempts started inside them.
        plan = self.rt.plan
        fail = False
        if st.fail_rate > 0:
            self.fault_draws += 1  # every service start re-draws the fault
            fail = self.rng.random() < st.fail_rate
        if plan is not None:
            if not fail and plan.crashed(st.name, self.rt.rel(t)):
                fail = True
            sec *= plan.slow_multiplier(st.name, self.rt.rel(t))
        if fail:
            detect = self.serving.heartbeat_timeout_s
            self.rt._push(t + detect, "service_failed", job=job,
                          station=st.name)
        else:
            self.rt._push(t + sec, "service_done", job=job, station=st.name)

    def _next_from_queue(self, t: float, st: Station):
        st.utilization_update(t)
        st.busy -= 1
        if st.queue:
            job = st.queue.pop(0)
            self.start_service(t, st, job)

    def _active_remove(self, tier: str, job: Job) -> None:
        try:
            self.active[tier].remove(job)
        except ValueError:
            pass

    @staticmethod
    def _drop_stale(job: Job, station: str) -> bool:
        """True if this completion event belongs to a service the job was
        migrated away from (one marker per release, so releasing twice —
        preempt then hedge-retire — drops exactly the two stale events)."""
        stale = job.payload.get("preempted", [])
        if station in stale:
            stale.remove(station)
            return True
        return False

    def _on_service_failed(self, ev: Event):
        st = self.stations[ev.payload["station"]]
        job: Job = ev.payload["job"]
        if self._drop_stale(job, ev.payload["station"]):
            # migrated away mid-service; this station was released at
            # migration time and the stale completion event is dropped
            return
        self._active_remove(ev.payload["station"], job)
        self._next_from_queue(ev.t, st)
        # shared retry/terminal-failure/degradation path (bounded by the
        # retry budget — a permanently dead tier can no longer livelock)
        self.rt.handle_service_failure(ev.t, job, ev.payload["station"])

    def _on_service_done(self, ev: Event):
        tier = ev.payload["station"]
        st = self.stations[tier]
        job: Job = ev.payload["job"]
        if self._drop_stale(job, tier):
            return  # stale event: see _on_service_failed
        self._active_remove(tier, job)
        self._next_from_queue(ev.t, st)
        if job.record.done:
            return  # the hedged twin finished first
        job.record.done = True
        self._maybe_park(job)
        req = job.request
        flops = job.payload["service_flops"]
        mem = job.payload["service_mem"]
        st.flops += flops
        st.mem_byte_s += mem
        sstats = job.payload.get("spec_stats")
        if sstats:
            # the verify loop is done: commit the acceptance ledger and
            # charge the draft tier's station for its proposal work
            # (counters only, like off-fusion ``encode`` — drafting rides
            # between the draft tier's own decode steps)
            spx = job.payload["speculate"]
            job.record.mark("accept", tier)
            job.record.drafted_tokens += int(sstats["spec_drafted"])
            job.record.accepted_tokens += int(sstats["spec_accepted"])
            dst = self.stations.get(spx["draft"])
            if dst is not None:
                dst.flops += sstats["spec_draft_flops"]
                dst.mem_byte_s += sstats["spec_draft_hbm"]
        spec = self.specs[tier]
        # return path: response tokens ride the serving tier's downlink
        down = cm.downlink_seconds(req.decode_tokens, spec)
        latency = ev.t + down - req.arrival_s
        on_time = latency <= req.slo_s
        correct = self.acc.sample(self.rng, req.difficulty, tier, on_time,
                                  capability=spec.capability)
        self.rt.finish(job, tier, latency, correct=correct,
                       tier_flops={tier: flops}, tier_mem_bytes={tier: mem})

    # -- resilience hooks ----------------------------------------------------

    def retry_limit(self, tier: str) -> int:
        return self.serving.retry_limit

    def abandon(self, job: Job) -> None:
        """Terminal failure: make sure the job can't consume a server
        later from some station queue (its in-service state was already
        released by the failure path)."""
        for st in self.stations.values():
            if job in st.queue:
                st.queue.remove(job)

    def parked_session_ids(self, tier: str) -> List[str]:
        store = self.parked.get(tier)
        return list(store.ids()) if store is not None else []

    def session_rescue_extract(self, t: float, sid: str, src: str):
        rec = self.parked[src].resume(sid)
        if rec is None:
            return None
        return float(rec.nbytes), rec

    def session_rescue_install(self, t: float, sid: str, dst: str,
                               payload) -> None:
        self.parked[dst].park(sid, payload)

    def advance(self) -> bool:
        return False  # purely event-driven: no events left means done

    def audit_residue(self) -> List[str]:
        """Invariant check at teardown: stations idle, nothing in service."""
        out: List[str] = []
        for name, st in sorted(self.stations.items()):
            if st.busy:
                out.append(f"station {name!r} left busy={st.busy}")
            if st.queue:
                out.append(f"station {name!r} left {len(st.queue)} queued")
        for tier, jobs in sorted(self.active.items()):
            if jobs:
                out.append(f"tier {tier!r} left {len(jobs)} jobs in service")
        return out


# ---------------------------------------------------------------------------
# Live backend (monotonic clock + real TierEngines)
# ---------------------------------------------------------------------------


class LiveBackend:
    """Real execution: a replicated :class:`EnginePool` per tier.

    * **Replicated tiers** — each tier runs ``TierSpec.servers`` engine
      replicas behind an :class:`~repro.serving.pool.EnginePool` (local
      in-process engines and/or spawn-process workers, selected by
      transport). New submissions go to the least-loaded replica with a
      deterministic tie-break; a parked session's turn sticks to the
      replica holding its KV, and a prompt extending a cached prefix
      prefers the replica that stored it. A bare ``TierEngine`` dict is
      accepted and wrapped in single-replica local pools — that path is
      bit-identical to the pre-pool backend.
    * **Executed partial offload** — an image routed off the fusion tier is
      encoded by the routed pool's least-loaded replica (in the fusion
      model's patch geometry so tokens are identical to a fusion-local
      encode) and only the compact embeddings reach the fusion prefill.
    * **Streaming + EDF admission** — requests carry an EDF deadline
      (arrival + SLO) into the engine's admission queue; tokens stream back
      through the engine's ``on_token`` hook (piped up from process
      replicas), giving true per-request TTFT.
    * **Hedging** — the runtime's shared hedge_check fires on the monotonic
      clock; a clone runs on the least-loaded other tier's pool and the
      loser is cancelled wherever its replica lives.
    * **Fault recovery** — with ``fail_rate`` > 0, an enqueued request may
      kill its replica: after the heartbeat timeout that ONE replica is
      rebuilt from its last ``snapshot()``, its restored in-flight slots
      re-home onto sibling replicas inside the tier (wire round trip, no
      WAN) before any cross-tier rescue, and the submissions since the
      snapshot are replayed (``record.done`` drops duplicates). A process
      replica that dies mid-flight surfaces its rids as *lost*; they
      re-enter through the shared failure path and land on a sibling.
    """

    virtual_clock = False
    fallback_bandwidth_bps = 300e6

    def __init__(self, engines: Dict, topology: ClusterTopology,
                 fail_rate: float = 0.0, seed: int = 0,
                 snapshot_every: int = 4):
        from repro.serving.pool import EnginePool
        from repro.serving.transport import LocalTransport

        # accept prebuilt pools or bare engines (wrapped 1:1)
        self.pools: Dict[str, EnginePool] = {
            t: (v if isinstance(v, EnginePool)
                else EnginePool(t, [LocalTransport(v)]))
            for t, v in engines.items()}
        # primary local engine per tier: the single-replica back-compat
        # surface (tests/benches read counters off ``server.engines``)
        self.engines = {t: p.primary_engine for t, p in self.pools.items()
                        if p.primary_engine is not None}
        self.topology = topology
        self.fail_rate = fail_rate
        self.rng = np.random.default_rng(seed)
        self.snapshot_every = snapshot_every
        self.restores = 0  # fault-recovery counter (tests/benchmarks)
        self.rehomes = 0  # intra-tier slot moves after a replica fault
        self.replica_losses = 0  # rids resubmitted off dead process replicas
        self.offloaded_encodes = 0  # images encoded away from their fusion
        self.fault_draws = 0  # fault-rng draws (one per engine submission)
        self._inflight: Dict[str, Dict[int, Job]] = {
            t: {} for t in self.pools}
        # snapshot/replay discipline is replica-granular: (tier, replica)
        self._snapshots: Dict[Tuple[str, int], dict] = {}
        self._since_snap: Dict[Tuple[str, int], List[Job]] = {}
        self.rt: Optional[ClusterRuntime] = None
        self._chaos = fail_rate > 0  # snapshot discipline needed?
        self._idle_cap_s = 0.0
        for tier, pool in self.pools.items():
            pool.wire_hooks(self._make_on_admit(tier),
                            self._make_on_token(tier),
                            self._make_on_warm(tier),
                            self._make_on_park(tier))

    def bind(self, runtime: ClusterRuntime) -> None:
        self.rt = runtime
        # snapshot/replay discipline is paid whenever faults can consume
        # the snapshots: a Bernoulli fail_rate OR plan crash windows
        self._chaos = self.fail_rate > 0 or (
            runtime.plan is not None and runtime.plan.has_crashes)
        if self._chaos and not all(p.supports_restore
                                   for p in self.pools.values()):
            raise ValueError(
                "chaos injection (fail_rate / crash plans) needs host-side "
                "snapshot/restore; process-transport replicas have none — "
                "use the local transport")
        # idle-wait cap: 0 = purely event-driven (sleep until the next
        # scheduled event); a positive ServingConfig.idle_poll_s caps the
        # doze, and process replicas force a cap so their pipes keep
        # draining while the parent waits
        caps = [p.serving.idle_poll_s for p in self.pools.values()
                if p.serving.idle_poll_s > 0]
        cap = min(caps) if caps else 0.0
        if any(tr.kind == "process" for p in self.pools.values()
               for tr in p.transports):
            cap = min(cap, 0.02) if cap > 0 else 0.02
        self._idle_cap_s = cap
        # byzantine wires: arm every replica's event/finish stream with the
        # runtime's chaos + shared stats (local transports gain the
        # sequenced delivery guard; process guards get chaos attached)
        if runtime.wire_chaos is not None:
            now_rel = lambda: runtime.rel(time.monotonic())  # noqa: E731
            for pool in self.pools.values():
                pool.arm_wire_chaos(runtime.wire_chaos, runtime.wire_stats,
                                    now_rel)

    def handlers(self):
        return {"node_fault": self._on_node_fault}

    # -- state the scheduler observes --------------------------------------

    def tier_loads(self) -> Dict[str, float]:
        return {t: p.load() for t, p in self.pools.items()}

    def queue_depths(self) -> Dict[str, int]:
        return {t: p.queue_depth() for t, p in self.pools.items()}

    def kv_headroom(self) -> Dict[str, float]:
        """Per-tier free fraction of the KV pool (real page accounting on
        paged engines, slot-granular on dense ones; best replica)."""
        return {t: p.kv_headroom() for t, p in self.pools.items()}

    def replica_loads(self) -> Dict[str, List[float]]:
        """Raw per-replica occupancy vectors (scheduler-visible imbalance
        signal; the tier-level EWMA still smooths ``tier_loads``)."""
        return {t: p.replica_loads() for t, p in self.pools.items()}

    def score_cost_s(self, policy_name: str) -> float:
        return 0.0  # the real scoring time already elapsed on the clock

    def embed_bytes(self, tier: str) -> float:
        return cm.embedding_bytes(self.pools[tier].cfg)

    # -- engine callbacks ---------------------------------------------------

    def _make_on_admit(self, tier: str):
        def on_admit(rid: int, t: float):
            job = self._inflight[tier].get(rid)
            if job is not None and not job.in_service:
                job.in_service = True
                job.record.mark("serve", tier)
        return on_admit

    def _make_on_warm(self, tier: str):
        def on_warm(rid: int, kind: str, cached: int, suffix: int):
            job = self._inflight[tier].get(rid)
            if job is None or job.record.done:
                return
            rec = job.record
            rec.warm = kind
            rec.warm_tokens += cached
            rec.mark(kind, tier)
        return on_warm

    def _make_on_park(self, tier: str):
        def on_park(rid: int, sid: str):
            job = self._inflight[tier].get(rid)
            if job is None or job.record.done:
                return  # a losing hedge twin parking late: rows kept, no mark
            job.record.mark("park", tier)
        return on_park

    def _make_on_token(self, tier: str):
        first_down = {t.name: cm.downlink_seconds(1, t)
                      for t in self.topology.tiers}

        def on_token(rid: int, token: int, t: float):
            job = self._inflight[tier].get(rid)
            if job is None or job.record.done:
                return
            rec = job.record
            if rec.ttft_s <= 0.0:
                # first streamed token from ANY attempt; a remote tier's
                # token must ride the downlink back to the user
                rec.ttft_s = t - job.request.arrival_s + first_down[tier]
        return on_token

    # -- partial offload ----------------------------------------------------

    def encode(self, t: float, job: Job) -> None:
        req, fusion = job.request, job.fusion
        fus_cfg = self.pools[fusion].cfg
        if fus_cfg.frontend != "vision_stub":
            return
        for nm, m in req.modalities.items():
            if m.kind != "image" or m.data is None:
                continue
            routed = job.decision.routes.get(nm, fusion)
            if routed == fusion:
                continue  # fusion prefill encodes its own image at enqueue
            # EXECUTED partial offload: the routed tier's least-loaded
            # replica runs the frontend (device work, counted on that
            # engine) and only the compact embeddings travel to the
            # fusion prefill
            emb = self.pools[routed].encode_image(
                np.asarray(m.data), fus_cfg.num_patches,
                fus_cfg.frontend_dim)
            job.payload.setdefault("extras", {})["patches"] = emb
            self.offloaded_encodes += 1

    # -- admission ----------------------------------------------------------

    def _maybe_fault(self, t: float, job: Job, tier: str,
                     replica: int) -> None:
        """EVERY submission re-draws the fault rng — including retried
        ones, which reach this path again through the runtime (they used
        to be replayed engine-side without a draw, diverging from the
        analytic backend's per-retry draws), and migrated injections (the
        analytic carrier re-enters start_service and draws there). An
        attempt whose retry budget is already spent faults too: the shared
        failure path then emits the terminal failed Outcome, matching the
        analytic backend's bounded retries. Plan crash windows stack on
        the Bernoulli draw without consuming the rng stream. The fault
        kills ONE replica — the one this submission landed on."""
        pool = self.pools[tier]
        fail = False
        if self.fail_rate > 0:
            self.fault_draws += 1
            fail = self.rng.random() < self.fail_rate
        plan = self.rt.plan
        if not fail and plan is not None \
                and plan.crashed(tier, self.rt.rel(t)):
            fail = True
        if fail:
            # replica dies mid-flight; detected after heartbeat timeout
            self.rt._push(t + pool.serving.heartbeat_timeout_s,
                          "node_fault", job=job, tier=tier, replica=replica)

    def _choose_replica(self, pool, job: Job) -> int:
        """Replica pick for a fresh submission. Session affinity first (a
        parked turn resumes on the replica holding its KV), then prefix
        affinity, then least-loaded. Pre-encode extras approximate the
        final fingerprint — affinity is a routing hint, never correctness."""
        req = job.request
        ids = np.asarray(req.modalities["text"].data, np.int32)
        fp = extras_fingerprint(dict(job.payload.get("extras", {})))
        sid = req.session if self.rt.sessions else None
        return pool.choose(ids, fp, sid)

    def enqueue(self, t: float, job: Job) -> None:
        tier = job.tier
        pool = self.pools[tier]
        r = self._choose_replica(pool, job)
        if self._chaos:
            self._maybe_fault(t, job, tier, r)
            # snapshot cadence (a full host copy of the KV pool) is only
            # paid when faults can actually consume the snapshots; it is
            # replica-granular — a fault only rolls back the replica it hit
            key = (tier, r)
            if len(self._since_snap.get(key, ())) >= self.snapshot_every \
                    or key not in self._snapshots:
                self._snapshots[key] = pool.snapshot_replica(r)
                self._since_snap[key] = []
            self._since_snap[key].append(job)
        spx = job.payload.get("speculate")
        if spx is not None and tier == spx["target"]:
            if self._spec_drive(t, pool, r, tier, job, spx):
                return  # submitted (and possibly fully decoded) in-drive
        self._engine_submit(pool, r, tier, job)

    def _spec_drive(self, t: float, pool, r: int, tier: str, job: Job,
                    spx: Dict) -> bool:
        """Cross-tier speculative decoding, live: admit the request on the
        TARGET replica, shadow-admit its prompt on a DRAFT replica, then
        co-drive draft→verify rounds synchronously (``step()`` only runs
        from ``advance``/poll, so nothing races the slots). Every exit path
        degrades to the plain fused decode: the target slot is always left
        in a state ``step()`` can finish, and the draft shadow is always
        cancelled. Returns True once the job was submitted to the target —
        the caller must not submit it again."""
        from repro.serving.transport import LocalTransport

        dpool = self.pools.get(spx["draft"])
        if dpool is None:
            return False
        # co-driving needs direct engine access on BOTH sides: the chosen
        # target replica if it is local, else any local one with room
        rt_idx = None
        if isinstance(pool.transports[r], LocalTransport):
            rt_idx = r
        else:
            for i, tr in enumerate(pool.transports):
                if isinstance(tr, LocalTransport) and tr.alive \
                        and tr.free_slots() > 0:
                    rt_idx = i
                    break
        rd_idx = None
        for i, tr in enumerate(dpool.transports):
            if isinstance(tr, LocalTransport) and tr.alive \
                    and tr.free_slots() > 0:
                rd_idx = i
                break
        if rt_idx is None or rd_idx is None:
            return False  # process-only replicas / no room: plain decode
        teng = pool.transports[rt_idx].engine
        deng = dpool.transports[rd_idx].engine
        req = job.request
        rid = req.rid
        self._engine_submit(pool, rt_idx, tier, job)
        teng._admit()
        slot_t = teng.spec_slot(rid)
        if slot_t is None:
            # queued behind a full engine (or finished straight out of
            # prefill): the normal step()/harvest path takes over
            return True
        k = int(spx["k"])
        ids, extras, _ = self._prepare_prompt(dpool.transports[rd_idx], job)
        slot_d = deng.spec_admit_quiet(rid, ids,
                                       max_new=req.decode_tokens + k + 2,
                                       extras=extras)
        if slot_d is None:
            return True  # no draft shadow: plain fused decode
        # the draft continues from the target's ACTUAL first token
        deng.spec_set_pending(rid, teng.slots[slot_t].generated[-1])
        job.record.mark("draft", spx["draft"])
        teng.spec_begin(rid)
        drafted = accepted = 0
        wc = self.rt.wire_chaos
        draft_link = f"draft:{spx['draft']}"
        try:
            while True:
                d = deng.spec_draft(rid, k)
                if d is None or len(d) == 0:
                    break  # draft out of room: target finishes plainly
                if wc is not None:
                    # the draft block crosses a wire to the verifier: frame
                    # it through the checksummed transport format so a
                    # corrupted block is detected (never verified against
                    # garbage) and the round falls back to plain decode
                    from repro.serving.transport import (TransportError,
                                                         msg_from_bytes,
                                                         msg_to_bytes)
                    frame = msg_to_bytes("draft", np.asarray(d))
                    if wc.decide("corrupt", draft_link,
                                 self.rt.rel(time.monotonic())):
                        frame = wc.tamper(frame, draft_link)
                        wc.bump("corrupt_injected")
                    try:
                        _, d = msg_from_bytes(frame)
                    except TransportError:
                        wc.bump("corrupt_detected")
                        break  # lost round: target finishes plainly
                res = teng.spec_verify(rid, d)
                if res is None:
                    break
                drafted += res["drafted"]
                accepted += res["accepted"]
                if res["finished"]:
                    break
                if not deng.spec_sync(rid, res["committed"]):
                    break  # draft cache exhausted mid-request
        finally:
            # the shadow never outlives the drive, and a surviving target
            # slot gets its full-budget page reservation back for step()
            deng.cancel(rid)
            if teng.spec_slot(rid) is not None:
                teng.spec_release(rid)
        job.record.mark("verify", tier)
        job.record.mark("accept", tier)
        job.record.drafted_tokens += drafted
        job.record.accepted_tokens += accepted
        return True

    def _engine_submit(self, pool, r: int, tier: str, job: Job) -> None:
        req = job.request
        tokens, extras, truncated = self._prepare_prompt(
            pool.transports[r], job)
        job.record.truncated |= truncated
        self._inflight[tier][req.rid] = job
        pool.submit_to(r, req.rid, tokens, max_new=req.decode_tokens,
                       extras=extras, deadline=req.arrival_s + req.slo_s,
                       session=(req.session if self.rt.sessions else None))

    def _prepare_prompt(self, eng, job: Job):
        """Tokens + extras for one engine, against its REAL budget.

        The prompt budget is ``max_seq - max_new - vision_prefix`` — every
        token past it is dropped WITH a recorded ``truncated`` flag (the
        old server silently clipped at ``max_seq // 2``).

        An attempt whose extras hold no usable embeddings — the image was
        routed here, or this is a hedge clone with nothing shipped for it,
        or shipped patches are in another model's geometry — encodes the
        image on ITS OWN engine: like the analytic backend, a clone pays
        the full prefill; the image is never silently dropped.
        """
        req = job.request
        ids = np.asarray(req.modalities["text"].data, np.int32)
        extras = dict(job.payload.get("extras", {}))
        img = req.modalities.get("image")
        if (eng.cfg.frontend == "vision_stub" and img is not None
                and img.data is not None):
            want = (eng.cfg.num_patches, eng.cfg.frontend_dim)
            patches = extras.get("patches")
            if patches is None or tuple(np.shape(patches)) != want:
                extras["patches"] = eng.encode_image(np.asarray(img.data))
        prefix = eng.cfg.num_patches if ("patches" in extras) else 0
        budget = max(1, eng.serving.max_seq - req.decode_tokens - prefix)
        truncated = len(ids) > budget
        if truncated:
            ids = ids[:budget]
        return ids, extras, truncated

    # -- fault recovery -----------------------------------------------------

    def _on_node_fault(self, ev: Event):
        job: Job = ev.payload["job"]
        tier = ev.payload["tier"]
        r = ev.payload.get("replica", 0)
        if job.record.done:
            # the request resolved during the detect window; the failure
            # still feeds the breaker (the node really died)
            self.rt.handle_service_failure(ev.t, job, tier)
            return
        pool = self.pools[tier]
        # rebuild the crashed REPLICA on a standby from its last snapshot,
        # then replay the submissions the snapshot doesn't contain —
        # sibling replicas never notice
        pool.restore_replica(r, self._snapshots[(tier, r)])
        self.restores += 1
        moved: set = set()
        if pool.n_alive > 1:
            # first line of defense is INSIDE the tier: ship the restored
            # in-flight slots to sibling replicas over the wire format —
            # same model, no WAN hop, and the (likely unhealthy) standby
            # sheds its decode load
            for rid in pool.slot_rids_on(r):
                j2 = self._inflight[tier].get(rid)
                if j2 is None or j2 is job or j2.record.done \
                        or j2.record.migrated:
                    continue
                dst = pool.move_slot(rid, r)
                if dst is None:
                    break  # no sibling has a free slot: stop probing
                if dst == -1:
                    # extracted but nobody could take it: cold resubmit
                    # on the least-loaded survivor
                    self._replay(pool, pool.least_loaded(skip=r), tier, j2)
                    moved.add(rid)
                    continue
                self.rehomes += 1
                j2.record.mark("rehome", tier)
                moved.add(rid)
        if self.rt.migrate:
            # anything still stuck on the standby may re-home ACROSS tiers:
            # prefilled cache rows ship instead of re-running; jobs with no
            # compatible target stay put
            for rid in pool.slot_rids_on(r):
                if rid in moved:
                    continue
                j2 = self._inflight[tier].get(rid)
                if j2 is None or j2 is job or j2.record.done \
                        or j2.record.migrated:
                    continue
                dst = self._rehome_target(tier)
                if dst is None:
                    break
                if self.rt._try_migrate(ev.t, j2, j2, dst, remove=True):
                    moved.add(rid)
        have = set(pool.rids_on(r)) | moved
        frid = job.request.rid
        replay, self._since_snap[(tier, r)] = \
            self._since_snap.get((tier, r), []), []
        for j in replay:
            rid = j.request.rid
            if j.record.done or rid in have or rid == frid:
                continue
            have.add(rid)
            self._replay(pool, r, tier, j)
        # the faulted submission itself re-enters through the runtime's
        # shared failure path: the fault rng is re-drawn for the retry
        # (draw-per-submission parity with the analytic backend) and the
        # retry budget / backoff / shed / terminal-failure rules apply
        # identically to both backends
        self.rt.handle_service_failure(ev.t, job, tier)

    def _replay(self, pool, r: int, tier: str, j: Job) -> None:
        """Replayed submissions re-register for the NEXT fault on their
        replica but never trigger a snapshot mid-recovery (matching the
        single-engine replay semantics)."""
        j.in_service = False
        self._since_snap.setdefault((tier, r), []).append(j)
        self._engine_submit(pool, r, tier, j)

    def _rehome_target(self, src: str) -> Optional[str]:
        cands = [n for n, p in self.pools.items()
                 if n != src and self.can_migrate(src, n)
                 and p.has_free_slot()]
        if not cands:
            return None
        occ = self.occupancy()
        return min(cands, key=lambda n: (occ.get(n, 0), n))

    # -- prefix & session KV reuse ------------------------------------------

    def session_tier(self, sid: str) -> Optional[str]:
        for tier, pool in self.pools.items():
            if pool.has_session(sid):
                return tier
        return None

    def session_extract(self, t: float, job: Job, src: str
                        ) -> Optional[float]:
        """Pop the REAL parked payload and ship its wire bytes (the same
        serialized form KV migration uses, prompt tokens included)."""
        pool = self.pools.get(src)
        if pool is None:
            return None
        wire = pool.resume_session_wire(job.request.session)
        if wire is None:
            return None
        job.payload["session_wire"] = wire
        return float(len(wire))

    def session_install(self, t: float, job: Job) -> None:
        tampered = job.payload.pop("wire_tampered", False)
        job.payload.pop("wire_dropped", False)
        wire = job.payload.pop("session_wire", None)
        if wire is None:
            return  # dropped on the wire: the turn cold-prefills
        ok = self.pools[job.tier].adopt_session_wire(job.request.session,
                                                     wire)
        if tampered:
            # adopt deserializes through the CRC'd wire format: a tampered
            # payload MUST have been rejected there
            wc = self.rt.wire_chaos
            wc.bump("corrupt_detected" if not ok else "corrupt_undetected")

    def parked_sessions(self) -> Dict[str, int]:
        return {tier: pool.session_count()
                for tier, pool in self.pools.items()}

    # -- cross-tier KV migration --------------------------------------------

    def can_migrate(self, src: str, dst: str) -> bool:
        ps, pd = self.pools.get(src), self.pools.get(dst)
        return (src != dst and ps is not None and pd is not None
                and ps.cfg.name == pd.cfg.name
                and ps.serving.max_seq == pd.serving.max_seq)

    def occupancy(self) -> Dict[str, int]:
        return {t: p.occupancy() for t, p in self.pools.items()}

    def preempt_candidate(self, tier: str, t: float) -> Optional[Job]:
        """Decoding slot with the most remaining token budget (never one
        already hedged or previously migrated)."""
        pool = self.pools[tier]
        best, best_key = None, None
        for rid, rem in pool.decode_slots():
            j = self._inflight[tier].get(rid)
            if j is None or j.record.done or j.record.migrated or j.hedged:
                continue
            if rem < 2:
                continue  # about to finish: not worth shipping
            key = (rem, -rid)
            if best is None or key > best_key:
                best, best_key = j, key
        return best

    def migrate_extract(self, t: float, donor: Job, carrier: Job, dst: str,
                        *, remove: bool = False) -> Optional[float]:
        """REAL extract: serialize the donor slot through the versioned wire
        format and ship the actual bytes (the same payload is deserialized
        and injected on arrival)."""
        pool = self.pools.get(donor.tier)
        if pool is None:
            return None
        wire = pool.extract_wire(donor.request.rid, remove=remove)
        if wire is None:
            return None
        carrier.payload["migration_wire"] = wire
        if remove:
            self._inflight[donor.tier].pop(donor.request.rid, None)
        return float(len(wire))

    def migrate_inject(self, t: float, carrier: Job) -> None:
        tampered = carrier.payload.pop("wire_tampered", False)
        carrier.payload.pop("wire_dropped", False)
        wire = carrier.payload.pop("migration_wire", None)
        donor = carrier.payload.pop("migration_donor", None)
        if carrier.record.done:
            carrier.payload.pop("migration_nbytes", None)
            return  # the donor finished during the transport window
        tier = carrier.tier
        pool = self.pools[tier]
        try:
            if wire is None:
                raise MigrationError("no payload shipped")
            r = pool.inject_wire(wire, carrier.request.rid)
        except MigrationError:
            # target full / died mid-transfer / CORRUPT WIRE (the payload
            # CRC raises before any engine state mutates): fall back to a
            # fresh prefill submission on the same tier (still completes,
            # just slower — the donor keeps decoding so the race survives,
            # and the request is NOT reported as migrated)
            if tampered:
                self.rt.wire_chaos.bump("corrupt_detected")
            carrier.payload.pop("migration_nbytes", None)
            self.rt._enqueue_service(t, carrier)
            return
        if tampered:
            # a flipped byte slid past every checksum: garbage KV is now
            # serving — exactly what the auditor must flag
            self.rt.wire_chaos.bump("corrupt_undetected")
        self.rt.commit_migration(carrier)
        if donor is not None:
            # the injected copy resumes at the donor's exact position on a
            # fresher tier: retire the donor instead of decoding the tail
            # twice (it already won if it finished during transport, above)
            dpool = self.pools.get(donor.tier)
            if dpool is not None:
                dpool.cancel(donor.request.rid)
            self._inflight[donor.tier].pop(donor.request.rid, None)
        rec = carrier.record
        rec.mark("enqueue", tier)
        rec.mark("serve", tier)
        carrier.in_service = True
        self._inflight[tier][carrier.request.rid] = carrier
        if self._chaos:
            # same fault/snapshot discipline as enqueue: make sure the
            # RECEIVING replica has a snapshot (taken AFTER the injection,
            # so recovery restores the migrated slot), register the carrier
            # for replay in case a later fault restores an older snapshot,
            # and let the migrated service fault like any other submission
            # (the analytic carrier draws in start_service too)
            key = (tier, r)
            if len(self._since_snap.get(key, ())) >= self.snapshot_every \
                    or key not in self._snapshots:
                self._snapshots[key] = pool.snapshot_replica(r)
                self._since_snap[key] = []
            self._since_snap[key].append(carrier)
            self._maybe_fault(t, carrier, tier, r)

    # -- driving the engines -----------------------------------------------

    def _harvest(self, tier: str, fins) -> None:
        if not fins:
            return
        now = time.monotonic()
        ws = self.rt.wire_stats
        for st in fins:
            job = self._inflight[tier].pop(st.rid, None)
            if job is None:
                continue  # cancelled attempt / replayed duplicate
            if job.record.done:
                # per-rid delivery ledger: a duplicated/hedged finish can
                # never double-serve or double-charge — the single ``done``
                # cell is the idempotence bit, counted so dup suppression
                # is machine-visible
                ws["dup_finishes_suppressed"] = \
                    ws.get("dup_finishes_suppressed", 0) + 1
                continue
            job.record.done = True
            job.record.tokens = list(st.generated)
            spec = self.rt.specs[tier]
            # return path: the full response rides the tier's downlink
            down = cm.downlink_seconds(len(st.generated), spec)
            latency = (st.t_done or now) + down - job.request.arrival_s
            self.rt.finish(job, tier, latency)
            sid = job.request.session if self.rt.sessions else None
            # cancel the losing hedge twin wherever it is — and drop any
            # session state a twin parked elsewhere before cancellation
            # (the winner's tier holds the authoritative park; a loser's
            # generated tokens are not this conversation's history)
            for other, pool2 in self.pools.items():
                if other == tier:
                    continue
                if st.rid in self._inflight[other]:
                    pool2.cancel(st.rid)
                    self._inflight[other].pop(st.rid, None)
                if sid is not None and pool2.has_session(sid):
                    pool2.drop_session(sid)

    def _on_replica_lost(self, tier: str, rid: int) -> None:
        """A process replica died with this rid in flight (no host-side
        snapshot exists for process workers). The request re-enters through
        the shared failure path and its retry lands cold on a surviving
        sibling via the usual least-loaded pick."""
        job = self._inflight[tier].pop(rid, None)
        if job is None or job.record.done:
            return
        self.replica_losses += 1
        self.rt.handle_service_failure(time.monotonic(), job, tier)

    # -- resilience hooks ----------------------------------------------------

    def retry_limit(self, tier: str) -> int:
        return self.pools[tier].serving.retry_limit

    def abandon(self, job: Job) -> None:
        """Terminal failure: cancel every replica copy of the request and
        drop it from the in-flight maps, so ``advance`` can drain (a
        permanently faulting submission used to livelock the server)."""
        rid = job.request.rid
        for tier, pool in self.pools.items():
            if rid in self._inflight[tier]:
                pool.cancel(rid)
                self._inflight[tier].pop(rid, None)

    def parked_session_ids(self, tier: str) -> List[str]:
        pool = self.pools.get(tier)
        return pool.session_ids() if pool is not None else []

    def session_rescue_extract(self, t: float, sid: str, src: str):
        pool = self.pools.get(src)
        if pool is None:
            return None
        wire = pool.resume_session_wire(sid)
        if wire is None:
            return None
        return float(len(wire)), wire

    def session_rescue_install(self, t: float, sid: str, dst: str,
                               wire) -> None:
        self.pools[dst].adopt_session_wire(sid, wire)

    def audit_residue(self) -> List[str]:
        """Invariant check at teardown: no in-flight requests, no owned
        rids, clean delivery ledgers, every local engine quiescent (slots
        free, queue empty) and its paged KV pool conserving pages."""
        from repro.serving.transport import LocalTransport

        out: List[str] = []
        for tier in sorted(self.pools):
            pool = self.pools[tier]
            stuck = sorted(self._inflight[tier])
            if stuck:
                out.append(f"{tier}: rids {stuck} still in flight")
            if pool._owner:
                out.append(f"{tier}: pool still owns rids "
                           f"{sorted(pool._owner)}")
            out.extend(pool.delivery_audit())
            for i, tr in enumerate(pool.transports):
                if not isinstance(tr, LocalTransport) or not tr.alive:
                    continue
                eng = tr.engine
                busy = [s.rid for s in eng.slots if s is not None]
                if busy:
                    out.append(f"{tier}/{i}: leaked engine slots for rids "
                               f"{busy}")
                if eng.waiting:
                    out.append(f"{tier}/{i}: {len(eng.waiting)} requests "
                               f"stuck in the admission queue")
                if eng.pool is not None:
                    try:
                        eng.pool.check()  # free XOR referenced, per page
                    except AssertionError as e:
                        out.append(f"{tier}/{i}: page pool conservation "
                                   f"violated: {e}")
        return out

    def advance(self) -> bool:
        plan = self.rt.plan
        if plan is not None and self.rt.t0 is not None:
            # slow-node windows: throttle the replicas' step cadence while
            # the window is open (the live analogue of the analytic
            # backend's stretched service times)
            now_rel = self.rt.rel(time.monotonic())
            for tier, pool in self.pools.items():
                pool.set_throttle(plan.slow_multiplier(tier, now_rel))
        if self.rt.health is not None:
            for tier, pool in self.pools.items():
                self.rt.health.heartbeat(tier, bool(pool.heartbeat_ok()))
        any_active = False
        for tier, pool in self.pools.items():
            # local replicas step here; process replicas step in their own
            # workers and this only drains their pipes — tiers genuinely
            # overlap their device work
            fins, active, lost = pool.poll()
            any_active |= active
            self._harvest(tier, fins)
            for rid in lost:
                self._on_replica_lost(tier, rid)
        if any_active:
            return True
        if self.rt.events:
            # idle but future events are scheduled (paced arrivals, hedge
            # checks, fault detections): doze until the earliest one
            # instead of burning a core. ``idle_poll_s`` caps the doze
            # (0 = fully event-driven); process pipes keep a small cap so
            # token streams drain while the parent waits
            dt = self.rt.events[0].t - time.monotonic()
            if dt > 0:
                ddl = self.rt.run_deadline
                if ddl is not None:
                    dt = min(dt, max(ddl - time.monotonic(), 0.0))
                cap = self._idle_cap_s
                if dt > 0:
                    time.sleep(min(dt, cap) if cap > 0 else dt)
            return True
        return any(self._inflight[t] for t in self._inflight)
