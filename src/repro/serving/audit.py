"""Runtime invariant auditor: turn "the chaos bench looked fine" into a
machine-checked claim.

:class:`InvariantAuditor` hangs off a :class:`ClusterRuntime`
(``ClusterRuntime(..., audit=True)``) and, at completion/teardown,
asserts the invariants that must hold under ANY fault schedule — tier
crashes, byzantine wires, replica losses:

* **exactly-once completion** — every submitted request reached exactly
  ONE terminal ``Outcome`` (never zero, never two: a duplicated finish
  frame or a replayed resubmit must not double-serve or double-charge);
* **clean token streams** — every delivery guard's ledger closed with no
  unresolved gap, no held reordered frame and no undrained messages
  (duplicate/gap-free delivery is enforced AT the wire, so a clean
  ledger is the stream-level invariant);
* **no stuck plumbing** — no WAN link ``Station`` left busy or queued,
  no backend in-flight entries, no pool ownership rows for finished
  requests;
* **resource conservation** — every engine slot free, waiting queues
  empty, and the paged KV pool's refcount/free-list conservation
  (``PagePool.check``: each page free XOR referenced) intact, so chaos
  can never leak pages or slots;
* **no undetected corruption** — every wire the chaos layer tampered
  with was caught by a checksum (``wire_stats["corrupt_undetected"]``
  must be zero).

The auditor only *reads* runtime/backend/engine state; backends expose
their residue via an ``audit_residue() -> List[str]`` hook. The verdict
is a plain dict (``{"clean": bool, "violations": [...], ...}``) — the
soak bench commits it to ``BENCH_cluster.json`` and tests assert on it.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List

__all__ = ["InvariantAuditor"]


class InvariantAuditor:
    """Read-only invariant checks over one runtime and its backend."""

    def __init__(self, runtime):
        self.rt = runtime
        self.last: Dict = {}

    # -- checks --------------------------------------------------------------

    def _check_outcomes(self) -> List[str]:
        out: List[str] = []
        rt = self.rt
        counts = Counter(o.rid for o in rt.outcomes)
        for rid, c in sorted(counts.items()):
            if c > 1:
                out.append(f"rid {rid} reached {c} terminal Outcomes")
            if rid not in rt.records:
                out.append(f"rid {rid} has an Outcome but no record")
        for rid, rec in sorted(rt.records.items()):
            n = counts.get(rid, 0)
            if n == 0:
                out.append(f"rid {rid} submitted but reached no terminal "
                           f"Outcome")
            if n >= 1 and not rec.done:
                out.append(f"rid {rid} has an Outcome but record.done is "
                           f"False")
        return out

    def _check_stations(self) -> List[str]:
        out: List[str] = []
        for name, st in sorted(self.rt.links.items()):
            if st.busy:
                out.append(f"link station {name!r} left busy={st.busy}")
            if st.queue:
                out.append(f"link station {name!r} left {len(st.queue)} "
                           f"queued transfers")
        return out

    def _check_wire(self) -> List[str]:
        ws = self.rt.wire_stats
        n = ws.get("corrupt_undetected", 0)
        if n:
            return [f"{n} tampered wire(s) were injected WITHOUT a "
                    f"checksum failure (undetected corruption)"]
        return []

    def final_check(self) -> Dict:
        """Run every invariant; returns (and remembers) the verdict."""
        violations: List[str] = []
        violations += self._check_outcomes()
        violations += self._check_stations()
        violations += self._check_wire()
        residue = getattr(self.rt.backend, "audit_residue", None)
        if residue is not None:
            violations += residue()
        self.last = {
            "clean": not violations,
            "violations": violations,
            "requests": len(self.rt.records),
            "outcomes": len(self.rt.outcomes),
            "wire": dict(self.rt.wire_stats),
        }
        return self.last
