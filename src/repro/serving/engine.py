"""Live continuous-batching serving engine (runs real models).

One engine per tier. Fixed-slot design: ``max_batch`` decode slots share a
static-shaped KV cache (per-slot write indices — see models/*); prompts are
prefilled one request at a time into a free slot, decode advances ALL active
slots each step. Finished slots are freed and immediately refilled
(continuous batching). Greedy or temperature sampling.

Fault tolerance: every mutation of engine state is journaled; ``snapshot()``/
``restore()`` allow a failed tier to be rebuilt on a standby (exercised in
tests), and a watchdog marks the engine unhealthy if a step exceeds the
heartbeat timeout.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServingConfig


@dataclass
class SeqState:
    rid: int
    prompt_len: int
    generated: List[int] = field(default_factory=list)
    max_new: int = 32
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


class TierEngine:
    def __init__(self, model, params, serving: ServingConfig = ServingConfig(),
                 eos_id: int = 2, sample_temp: float = 0.0, seed: int = 0):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.serving = serving
        self.eos_id = eos_id
        self.temp = sample_temp
        self.rng = np.random.default_rng(seed)

        b, t = serving.max_batch, serving.max_seq
        self.cache = model.init_cache(b, t)
        self.slots: List[Optional[SeqState]] = [None] * b
        self.positions = np.zeros((b,), np.int64)  # absolute next position
        self.waiting: List[Dict[str, Any]] = []
        self.finished: List[SeqState] = []
        self.journal: List[tuple] = []  # (op, payload) event journal
        self.healthy = True
        self.last_heartbeat = time.monotonic()
        self.steps = 0

        self._decode = jax.jit(model.decode_step)
        self._prefill1 = jax.jit(lambda p, batch: model.prefill(p, batch, t))

    # ------------------------------------------------------------------

    def submit(self, rid: int, tokens: np.ndarray, max_new: int = 32,
               extras: Optional[Dict[str, np.ndarray]] = None) -> None:
        self.journal.append(("submit", {"rid": rid, "tokens": tokens,
                                        "max_new": max_new,
                                        "extras": extras}))
        self.waiting.append({"rid": rid, "tokens": np.asarray(tokens),
                             "max_new": max_new, "extras": extras or {},
                             "t": time.monotonic()})

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _insert_cache(self, cache1, slot: int) -> None:
        """Copy a batch-1 prefill cache into slot ``slot`` of the pool."""
        def ins(pool, one):
            if pool.ndim == one.ndim and pool.shape[0] == len(self.slots):
                # batch-leading leaves: pos (B,T), index (B,)
                return pool.at[slot].set(one[0])
            # layer-stacked leaves: (L, B, ...) — batch is axis 1
            return pool.at[:, slot].set(one[:, 0])
        self.cache = jax.tree.map(ins, self.cache, cache1)

    def _admit(self) -> None:
        while self.waiting:
            slot = self._free_slot()
            if slot is None:
                return
            job = self.waiting.pop(0)
            toks = job["tokens"][None]  # (1, S)
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            for k, v in job["extras"].items():
                batch[k] = jnp.asarray(v)[None]
            logits, cache1 = self._prefill1(self.params, batch)
            self._insert_cache(cache1, slot)
            prefix = 0
            if self.cfg.frontend == "vision_stub" and "patches" in batch:
                prefix = self.cfg.num_patches
            st = SeqState(rid=job["rid"], prompt_len=toks.shape[1] + prefix,
                          max_new=job["max_new"], t_submit=job["t"])
            first = self._sample(np.asarray(logits)[0])
            st.generated.append(int(first))
            st.t_first_token = time.monotonic()
            self.slots[slot] = st
            self.positions[slot] = st.prompt_len
            self.journal.append(("admit", {"rid": st.rid, "slot": slot}))

    def _sample(self, logits: np.ndarray) -> int:
        if self.temp <= 0:
            return int(np.argmax(logits))
        z = logits / self.temp
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        b = len(self.slots)
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
            positions[i] = self.positions[i]
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens),
             "positions": jnp.asarray(positions)})
        logits = np.asarray(logits)
        now = time.monotonic()
        for i in active:
            st = self.slots[i]
            self.positions[i] += 1
            nxt = self._sample(logits[i])
            st.generated.append(nxt)
            hit_cap = self.positions[i] + 1 >= self.serving.max_seq
            if (nxt == self.eos_id or len(st.generated) >= st.max_new
                    or hit_cap):
                st.done = True
                st.t_done = now
                self.finished.append(st)
                self.journal.append(("finish", {"rid": st.rid}))
                self.slots[i] = None
        self.steps += 1
        self.last_heartbeat = now
        return len(active)

    def run_until_drained(self, max_steps: int = 100_000) -> List[SeqState]:
        while (self.waiting or any(s is not None for s in self.slots)):
            if self.steps >= max_steps:
                break
            self.step()
        return self.finished

    # -- fault tolerance ----------------------------------------------------

    def heartbeat_ok(self) -> bool:
        dt = time.monotonic() - self.last_heartbeat
        self.healthy = dt <= self.serving.heartbeat_timeout_s or self.steps == 0
        return self.healthy

    def snapshot(self) -> dict:
        return {
            "cache": jax.tree.map(np.asarray, self.cache),
            "slots": [dataclasses.replace(s) if s else None for s in self.slots],
            "positions": self.positions.copy(),
            "waiting": list(self.waiting),
            "steps": self.steps,
        }

    def restore(self, snap: dict) -> None:
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])
        self.slots = [dataclasses.replace(s) if s else None
                      for s in snap["slots"]]
        self.positions = snap["positions"].copy()
        self.waiting = list(snap["waiting"])
        self.steps = snap["steps"]
        self.healthy = True
        self.last_heartbeat = time.monotonic()
