"""Live continuous-batching serving engine (runs real models).

One engine per tier. Fixed-slot design: ``max_batch`` decode slots share a
static-shaped KV cache (per-slot write indices — see models/*); finished
slots are freed and immediately refilled (continuous batching).

The hot path is device-resident:

* **Fused decode** — one jitted ``lax.scan`` runs ``fused_steps`` decode
  steps per host call: on-device greedy/temperature sampling from a per-slot
  ``jax.random`` key array, per-slot alive flags masking EOS / token-budget /
  capacity, and in-place KV writes (the cache and key buffers are DONATED,
  so XLA updates them in place instead of copying the whole cache every
  step). The host receives a single ``(B, fused_steps)`` token block — one
  transfer and one Python bookkeeping pass per K tokens instead of per
  token.
* **Bucketed prefill** — waiting prompts are right-padded to power-of-two
  length buckets and all same-bucket requests prefill in ONE batched call;
  a jitted ``at[...].set`` scatter (donated pool) inserts every admitted
  row into its slot, replacing the per-request retrace and the full-tree
  cache copy. Families whose cache embeds recurrent/ring state (ssm,
  hybrid) or non-neutral pad tokens (moe) batch same-length groups instead
  of padded buckets.

``ServingConfig(fused_steps=1)`` keeps the legacy per-token path (host-side
numpy sampling, one dispatch + ``np.asarray(logits)`` sync per token, one
retraced prefill per request) for parity testing and as the perf baseline.
Token-for-token parity is guaranteed for every family with the default MoE
impl; a capacity-dropping MoE impl is batch-content-coupled by construction
(which tokens drop depends on the other rows in flight), so under it any
two scheduling policies — including legacy vs fused — may diverge.

Fault tolerance: every mutation of engine state is journaled; ``snapshot()``/
``restore()`` allow a failed tier to be rebuilt on a standby (exercised in
tests), and a watchdog marks the engine unhealthy if a step exceeds the
heartbeat timeout.

Cross-tier KV migration: ``extract_slot(rid)`` serializes ONE request's
cache rows (the same axis-aware leaf walk the prefill scatter uses), its
``SeqState`` and its sampling key into a :class:`SlotPayload` with a
versioned, dtype/shape-tagged wire format; ``inject_slot(payload)`` resumes
it in a free slot of a compatible engine without re-prefilling.
"""
from __future__ import annotations

import copy
import dataclasses
import json
import struct
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServingConfig
from repro.serving.paged import PagePool, pages_needed
from repro.serving.prefix import (ParkedSession, PrefixStore, SessionStore,
                                  extension_suffix, extras_fingerprint,
                                  prefix_buckets)

# Families whose decode cache is a full-capacity absolute-position buffer:
# right-padded bucket prefill is exact for them (pad entries are masked via
# the ``pos`` leaf; causal attention keeps real-token activations identical).
# ssm/hybrid carry recurrent/ring state that pads would shift — they batch
# exact same-length groups instead. moe prefills per request: a capacity-
# dropping MoE impl routes over the flattened batch, so ANY cross-request
# batching (padded or not) would couple which tokens drop across requests.
_PADDED_FAMILIES = ("dense", "vlm", "encdec")


def _next_bucket(n: int, lo: int = 16) -> int:
    """Smallest power-of-two >= n (floored at ``lo`` to bound trace count)."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclass
class SeqState:
    rid: int
    prompt_len: int
    generated: List[int] = field(default_factory=list)
    max_new: int = 32
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    # multi-turn session id: when set, the slot's state is parked into the
    # engine's session store at finish so the next turn resumes it
    session: Optional[str] = None


# ---------------------------------------------------------------------------
# cross-tier KV migration: per-slot cache wire format
# ---------------------------------------------------------------------------

#: bump when the on-wire layout changes; injectors reject other versions
#: v2: CRC32 on the header blob and on every raw buffer (key + each leaf)
#: so byte corruption anywhere on the wire deterministically raises
#: MigrationError instead of silently injecting garbage KV
MIGRATION_WIRE_VERSION = 2
_WIRE_MAGIC = b"MOAKV"
_WIRE_HDR = struct.Struct("<HII")  # (version, header len, header crc32)


class MigrationError(RuntimeError):
    """A slot payload cannot be extracted or injected: unknown wire version,
    wrong model/family, mismatched cache geometry, or no free slot. Raised
    BEFORE any engine state is mutated, so a failed injection leaves the
    target engine untouched (callers fall back to a fresh prefill)."""


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype tag, including the ml_dtypes extras jax uses. An
    unknown tag (corrupt header, sender with newer dtypes) raises
    MigrationError so callers keep their re-prefill fallback."""
    try:
        return np.dtype(name)
    except TypeError:
        try:
            import ml_dtypes  # ships with jax

            return np.dtype(getattr(ml_dtypes, name))
        except (AttributeError, TypeError) as e:
            raise MigrationError(
                f"unknown dtype tag {name!r} in slot payload") from e


@dataclass
class SlotPayload:
    """One request's migratable state: its per-slot cache rows (every leaf
    sliced along that leaf's logical batch axis — dense/vlm/moe KV, ssm
    conv+state, hybrid ring/rglru leaves), its ``SeqState``, the absolute
    next position, and the per-slot sampling key. ``to_bytes``/``from_bytes``
    are the versioned, dtype/shape-tagged wire format the live backend
    actually ships across tiers."""

    version: int
    model: str
    family: str
    max_seq: int
    seq: SeqState
    position: int
    key: np.ndarray  # per-slot jax.random key data
    leaves: Dict[str, np.ndarray]  # keystr(cache path) -> per-slot row
    # prompt token ids (session park/resume needs to know exactly which
    # tokens the cache rows cover); absent on wires from older senders
    prompt_tokens: Optional[np.ndarray] = None
    # fingerprint of the prefill extras (vision patches) occupying cache
    # positions — a resume must present identical extras to reuse the rows
    extras_fp: bytes = b""
    _wire: Optional[bytes] = field(default=None, repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        """Exact on-wire size (serialized lazily, cached)."""
        return len(self.to_bytes())

    def to_bytes(self) -> bytes:
        if self._wire is not None:
            return self._wire
        seq = self.seq
        names = sorted(self.leaves)
        key_bytes = np.ascontiguousarray(self.key).tobytes()
        leaf_bytes = [np.ascontiguousarray(self.leaves[n]).tobytes()
                      for n in names]
        head = {
            "version": self.version,
            "model": self.model,
            "family": self.family,
            "max_seq": self.max_seq,
            "position": self.position,
            "seq": {
                "rid": seq.rid, "prompt_len": seq.prompt_len,
                "generated": list(seq.generated), "max_new": seq.max_new,
                "done": seq.done, "t_submit": seq.t_submit,
                "t_first_token": seq.t_first_token, "t_done": seq.t_done,
                "session": seq.session,
            },
            "prompt_tokens": (None if self.prompt_tokens is None
                              else [int(t) for t in self.prompt_tokens]),
            "extras_fp": self.extras_fp.hex(),
            "key": {"dtype": str(self.key.dtype),
                    "shape": list(self.key.shape),
                    "crc": zlib.crc32(key_bytes)},
            "leaves": [{"name": n, "dtype": str(self.leaves[n].dtype),
                        "shape": list(self.leaves[n].shape),
                        "crc": zlib.crc32(raw)}
                       for n, raw in zip(names, leaf_bytes)],
        }
        blob = json.dumps(head).encode("utf-8")
        parts = [_WIRE_MAGIC,
                 _WIRE_HDR.pack(self.version, len(blob), zlib.crc32(blob)),
                 blob, key_bytes]
        parts += leaf_bytes
        self._wire = b"".join(parts)
        return self._wire

    @classmethod
    def from_bytes(cls, wire: bytes) -> "SlotPayload":
        m = len(_WIRE_MAGIC)
        if wire[:m] != _WIRE_MAGIC:
            raise MigrationError("not a slot payload (bad magic)")
        if len(wire) < m + _WIRE_HDR.size:
            raise MigrationError("truncated slot payload")
        version, hlen, hcrc = _WIRE_HDR.unpack_from(wire, m)
        if version != MIGRATION_WIRE_VERSION:
            raise MigrationError(
                f"wire format version {version} != supported "
                f"{MIGRATION_WIRE_VERSION}")
        off = m + _WIRE_HDR.size
        if off + hlen > len(wire):
            raise MigrationError("truncated slot payload header")
        blob = wire[off:off + hlen]
        # the header checksum gates json parsing: corrupt bytes raise here,
        # deterministically, before anything is interpreted
        if zlib.crc32(blob) != hcrc:
            raise MigrationError("slot payload header checksum mismatch")

        def pull(dtype_s: str, shape, crc) -> np.ndarray:
            nonlocal off
            dt = _np_dtype(dtype_s)
            if any(int(d) < 0 for d in shape):
                raise MigrationError(f"corrupt leaf shape {shape}")
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            end = off + n * dt.itemsize
            if end > len(wire):
                raise MigrationError("truncated slot payload")
            raw = wire[off:end]
            if crc is not None and zlib.crc32(raw) != crc:
                raise MigrationError("slot payload buffer checksum mismatch")
            arr = np.frombuffer(raw, dtype=dt).reshape(shape).copy()
            off = end
            return arr

        # any malformation beyond this point (bad json, missing header
        # fields, bogus shapes) is a corrupt wire, never a crash: callers
        # rely on MigrationError to fall back to a fresh prefill
        try:
            head = json.loads(blob.decode("utf-8"))
            off += hlen
            key = pull(head["key"]["dtype"], head["key"]["shape"],
                       head["key"].get("crc"))
            leaves = {d["name"]: pull(d["dtype"], d["shape"], d.get("crc"))
                      for d in head["leaves"]}
            s = head["seq"]
            seq = SeqState(rid=s["rid"], prompt_len=s["prompt_len"],
                           generated=list(s["generated"]),
                           max_new=s["max_new"], done=s["done"],
                           t_submit=s["t_submit"],
                           t_first_token=s["t_first_token"],
                           t_done=s["t_done"],
                           session=s.get("session"))
            pt = head.get("prompt_tokens")
            return cls(version=version, model=head["model"],
                       family=head["family"], max_seq=head["max_seq"],
                       seq=seq, position=head["position"], key=key,
                       leaves=leaves,
                       prompt_tokens=(None if pt is None
                                      else np.asarray(pt, np.int32)),
                       extras_fp=bytes.fromhex(head.get("extras_fp", "")),
                       _wire=bytes(wire))
        except MigrationError:
            raise
        except (KeyError, ValueError, TypeError, OverflowError) as e:
            raise MigrationError(f"corrupt slot payload: {e!r}") from e


class TierEngine:
    def __init__(self, model, params, serving: ServingConfig = ServingConfig(),
                 eos_id: int = 2, sample_temp: float = 0.0, seed: int = 0):
        # shallow copy: the engine sets per-engine decode flags below and
        # must not leak them into a model object shared with other engines
        model = copy.copy(model)
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.serving = serving
        self.eos_id = eos_id
        self.temp = sample_temp
        self.rng = np.random.default_rng(seed)
        self.fused_steps = max(1, serving.fused_steps)
        if serving.decode_impl != "auto" and hasattr(model, "decode_impl"):
            model.decode_impl = serving.decode_impl
        if (self.fused_steps > 1 and serving.unroll_decode_layers
                and hasattr(model, "decode_unroll")):
            model.decode_unroll = True

        b, t = serving.max_batch, serving.max_seq
        self.cache = model.init_cache(b, t)
        self.slots: List[Optional[SeqState]] = [None] * b
        self.positions = np.zeros((b,), np.int64)  # absolute next position
        self.waiting: List[Dict[str, Any]] = []
        self.finished: List[SeqState] = []
        self.journal: List[tuple] = []  # (op, payload) event journal
        self.healthy = True
        # chaos knob: a slow-node fault window sets this > 1 and each step
        # sleeps (throttle-1)x its own duration — the live analogue of the
        # analytic backend's stretched service times. The cap bounds the
        # stretch of outlier steps (compiles, host deschedules) so a slow
        # NODE never emulates a dead one
        self.throttle = 1.0
        self.throttle_sleep_cap_s = 0.5
        self.last_heartbeat = time.monotonic()
        self.steps = 0
        # perf counters (read by benchmarks/serving_bench.py and launch/serve)
        self.decode_tokens = 0
        self.prefill_tokens = 0
        self.encode_tokens = 0  # encode-only entry point (partial offload)
        # prefix & session KV reuse counters: tokens whose prefill was
        # SKIPPED because their cache rows were copied from the prefix
        # store / a parked session (prefill_tokens counts only suffixes)
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.resumed_sessions = 0
        self.resumed_tokens = 0
        self.parks = 0
        # cross-tier speculative decoding: tokens this engine PROPOSED as a
        # draft, draft tokens this engine ACCEPTED while verifying as a
        # target, and verify rounds run (accepted/drafted is the live
        # acceptance rate the scheduler's EWMA tracks)
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.spec_rounds = 0
        # cluster-runtime hooks: admission + per-token streaming callbacks
        # (rid, t) and (rid, token, t); None = standalone engine
        self.on_admit: Optional[Callable[[int, float], None]] = None
        self.on_token: Optional[Callable[[int, int, float], None]] = None
        # warm-admission + session-park hooks: (rid, kind, cached, suffix)
        # with kind in {"prefix", "resume"}, and (rid, sid)
        self.on_warm: Optional[Callable[[int, str, int, int], None]] = None
        self.on_park: Optional[Callable[[int, str], None]] = None
        self._encode_jits: Dict[tuple, Any] = {}

        self._decode = jax.jit(model.decode_step)
        self._prefill1 = jax.jit(lambda p, batch: model.prefill(p, batch, t))
        # per-slot sampling keys live on device across the engine's lifetime
        self._keys = jax.random.split(jax.random.PRNGKey(seed), b)
        # batch axis per cache leaf (hybrid rglru leaves carry batch at
        # axis 2 — the generic scatter below follows the logical axes)
        axes = model.cache_axes(b, t)
        self._cache_batch_axis = jax.tree.map(
            lambda a: a.index("batch"), axes,
            is_leaf=lambda x: isinstance(x, tuple))
        # seq (time) axis per cache leaf, -1 for per-slot state without a
        # time dimension (recurrent h/conv, pos bookkeeping): the prefix
        # store slices KV rows along this axis, the same cache_axes walk
        # extract_slot uses for the batch axis
        self._cache_seq_axis = jax.tree.map(
            lambda a: a.index("seq") if "seq" in a else -1, axes,
            is_leaf=lambda x: isinstance(x, tuple))
        self._axis_by_name = {
            jax.tree_util.keystr(pb[0]): (pb[1], ps[1])
            for pb, ps in zip(
                jax.tree_util.tree_leaves_with_path(self._cache_batch_axis),
                jax.tree_util.tree_leaves_with_path(self._cache_seq_axis))}
        # prefix & session KV reuse: prefixes of admitted prompts are
        # positionally addressable (sliceable at any boundary) only for the
        # full-capacity absolute-position families; ssm/hybrid state is a
        # point-in-time snapshot, so only whole processed sequences park
        self._sliceable = self.cfg.family in ("dense", "vlm", "moe")
        self.prefix_store = PrefixStore(serving.prefix_cache_mb * 1e6,
                                        min_prefix=serving.prefix_min_tokens)
        self.sessions = SessionStore(serving.session_cache_mb * 1e6)
        self._slot_prompt: List[Optional[np.ndarray]] = [None] * b
        self._slot_extras_fp: List[bytes] = [b""] * b

        # -- paged KV pool: block allocator + copy-free CoW prefix sharing --
        # sliceable families move their big KV leaves into a shared physical
        # pool ((L, P, page, K, hd) + an int32 page table per slot); the
        # recurrent families keep exact-length dense state but share the pool
        # ACCOUNTING so cross-family tiers report comparable headroom.
        self.pool: Optional[PagePool] = None
        self._paged_names: tuple = ()
        self._dense_spec_tree = None  # dense cache template (paged engines)
        self._pt: Optional[np.ndarray] = None  # host page tables (B, n_pt)
        self._slot_pages: List[List[int]] = [[] for _ in range(b)]
        self._page_pressure = False  # set when admission starved for pages
        self._slot_page_charge = 1  # accounting charge (recurrent families)
        self._n_pt = 0
        self._slots_hw = 0  # dense-mode occupied-slot high-water (gauges)
        if serving.paged:
            ps = serving.kv_page_size
            self._n_pt = serving.pages_per_slot
            self._dense_spec_tree = jax.eval_shape(
                lambda: model.init_cache(b, t))
            page_bytes = 0.0
            if self._sliceable:
                paged_names = []
                for path, leaf in jax.tree_util.tree_leaves_with_path(
                        self._dense_spec_tree):
                    name = jax.tree_util.keystr(path)
                    if name in ("['pos']", "['index']"):
                        continue  # per-slot bookkeeping stays dense
                    bax, sax = self._axis_by_name[name]
                    if sax == bax + 1 and leaf.shape[sax] == t:
                        paged_names.append(name)
                        rowb = (float(np.prod(leaf.shape, dtype=np.float64))
                                / leaf.shape[bax] / leaf.shape[sax]
                                * jnp.dtype(leaf.dtype).itemsize)
                        page_bytes += rowb * ps
                self._paged_names = tuple(sorted(paged_names))
                npages = serving.pool_pages + 1  # + pinned null page 0

                def repage(path, leaf):
                    name = jax.tree_util.keystr(path)
                    if name not in self._paged_names:
                        return leaf
                    bax = self._axis_by_name[name][0]
                    shape = (leaf.shape[:bax] + (npages, ps)
                             + leaf.shape[bax + 2:])
                    return jnp.zeros(shape, leaf.dtype)

                self.cache = jax.tree_util.tree_map_with_path(
                    repage, self.cache)
                self.cache["pages"] = jnp.zeros((b, self._n_pt), jnp.int32)
                self._pt = np.zeros((b, self._n_pt), np.int32)
            else:
                # per-slot state is point-in-time (no positional pages);
                # charge each slot the pages its largest time-axis leaf
                # would occupy so admission answers to the same pool
                max_rows = 0
                slot_bytes = 0.0
                for name, leaf, bax in self._leaf_rows():
                    slot_bytes += leaf.nbytes / leaf.shape[bax]
                    sax = self._axis_by_name[name][1]
                    if sax >= 0 and name not in ("['pos']", "['index']"):
                        max_rows = max(max_rows, leaf.shape[sax])
                self._slot_page_charge = max(
                    1, pages_needed(max_rows, ps, t))
                page_bytes = slot_bytes / self._slot_page_charge
            self.pool = PagePool(serving.pool_pages, ps,
                                 page_bytes=page_bytes)
        # dense per-slot row geometry (name -> (shape, dtype)) for payload
        # validation — structure-independent, so paged and dense engines
        # speak the same migration wire format
        spec_src = (self._dense_spec_tree if self._dense_spec_tree is not None
                    else self.cache)
        self._row_specs: Dict[str, tuple] = {}
        for path, leaf in jax.tree_util.tree_leaves_with_path(spec_src):
            name = jax.tree_util.keystr(path)
            bax = self._axis_by_name[name][0]
            self._row_specs[name] = (
                leaf.shape[:bax] + leaf.shape[bax + 1:], str(leaf.dtype))
        if self.pool is not None:
            # dropping a store reference and freeing its physical pages must
            # never diverge: EVERY store removal (LRU eviction, overwrite,
            # explicit pop) decrefs through this hook
            self.prefix_store.lru.on_evict = self._on_prefix_evict
        self._warm_chunk_recurrent = None
        if (serving.chunked_recurrent_suffix
                and self.cfg.family in ("ssm", "hybrid")
                and hasattr(model, "decode_chunk_recurrent")):
            self._warm_chunk_recurrent = jax.jit(
                model.decode_chunk_recurrent, donate_argnums=(1,))

        self._warm_scan = jax.jit(self._make_warm_scan(),
                                  donate_argnums=(1,), static_argnums=(4,))
        max_seq = self.serving.max_seq
        self._warm_chunk = jax.jit(
            lambda p, c, batch, teff: model.decode_chunk(
                p, c, batch, ctx=(teff if teff < max_seq else None)),
            donate_argnums=(1,), static_argnums=(3,))
        # context buckets need linear cache placement (pos == write index),
        # which holds for the full-capacity-"pos" families only — ring
        # windows (hybrid), recurrent state (ssm) and the encdec cross
        # cache keep full-width attention
        self._ctx_buckets = (serving.context_buckets
                             and self.cfg.family in ("dense", "vlm", "moe"))
        self._fused = jax.jit(self._make_fused(), donate_argnums=(1, 2),
                              static_argnums=(6, 7))
        self._prefill_insert = jax.jit(
            self._make_prefill_insert(), donate_argnums=(1,),
            static_argnums=(6,) if self._pt is not None else ())
        # speculative-decoding jits, built lazily on first use: the verify
        # chunk (decode_chunk/-_recurrent with all_logits) and the batch-1
        # autoregressive draft scan
        self._spec_chunk_fn = None
        self._spec_draft_fn = None

    # ------------------------------------------------------------------
    # jitted hot-path builders
    # ------------------------------------------------------------------

    def _make_fused(self):
        """K decode steps in one lax.scan: decode -> on-device sample ->
        per-slot EOS/budget/cap masking. Cache + keys are donated by the
        caller so the KV buffers update in place.

        ``teff`` (static) is the context bucket: attention reads only the
        leading ``teff`` cache entries (a static slice XLA fuses into the
        attention loads — writes and bookkeeping stay full-width, so the
        cache layout never changes). Valid because placement is linear for
        the bucket-eligible families, every admit rewrites a slot's full
        row (entries past a prompt carry pos=-1), and the engine picks
        ``teff`` above the longest position reached inside the block.
        """
        model = self.model
        temp, eos = float(self.temp), int(self.eos_id)
        max_seq = int(self.serving.max_seq)
        # ssm/hybrid carry recurrent state (and a ring window whose write
        # index wraps onto LIVE entries): a dead slot's decode_step would
        # keep mutating it, corrupting the very rows session parking
        # extracts at finish. Freeze the whole dead row for those families;
        # the full-capacity-pos families only need the pos/index freeze
        # (dead writes land on pos=-1 entries, which reads mask out).
        freeze_rows = self.cfg.family in ("ssm", "hybrid")
        bax_tree = self._cache_batch_axis

        def fused(params, cache, keys, tokens, positions, budgets, teff,
                  k_steps):
            # ``k_steps`` (static) shrinks the block below ``fused_steps``
            # under page pressure: the host re-checks admission the moment a
            # finishing request can free pages (continuous admission splits
            # the scan at the pressure boundary instead of waiting K steps)
            ctx = teff if teff < max_seq else None

            def body(carry, _):
                cache, keys, tok, pos, alive, produced = carry
                logits, cache2 = model.decode_step(
                    params, cache, {"tokens": tok[:, None], "positions": pos},
                    ctx=ctx)
                if temp > 0:
                    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
                    keys2, sub = split[:, 0], split[:, 1]
                    sampled = jax.vmap(jax.random.categorical)(
                        sub, logits / temp)
                else:
                    keys2 = keys
                    sampled = jnp.argmax(logits, axis=-1)
                sampled = sampled.astype(jnp.int32)
                produced = produced + alive.astype(jnp.int32)
                pos2 = jnp.where(alive, pos + 1, pos)
                # same stop rules the host applies per appended token
                alive2 = (alive & (sampled != eos) & (produced < budgets)
                          & (pos2 + 1 < max_seq))
                tok2 = jnp.where(alive, sampled, tok)
                if freeze_rows:
                    # keep a dead slot's ENTIRE cache row at its
                    # time-of-death state (recurrent h/conv + ring KV +
                    # bookkeeping); rows are small for these families
                    def keep(new, old, bax):
                        shape = [1] * new.ndim
                        shape[bax] = alive.shape[0]
                        return jnp.where(alive.reshape(shape), new, old)

                    cache2 = jax.tree.map(keep, cache2, cache, bax_tree)
                elif "pos" in cache2:
                    # freeze write bookkeeping of dead slots: their KV writes
                    # land on a slot whose pos stays -1 (masked), instead of
                    # marching the ring index over live-looking entries
                    cache2 = dict(cache2)
                    cache2["pos"] = jnp.where(alive[:, None], cache2["pos"],
                                              cache["pos"])
                    cache2["index"] = jnp.where(alive, cache2["index"],
                                                cache["index"])
                return (cache2, keys2, tok2, pos2, alive2, produced), tok2

            alive0 = budgets > 0
            produced0 = jnp.zeros_like(budgets)
            (cache, keys, *_), toks = jax.lax.scan(
                body, (cache, keys, tokens, positions, alive0, produced0),
                None, length=k_steps)
            return jnp.transpose(toks), cache, keys  # (B, k_steps)

        return fused

    def _make_prefill_insert(self):
        """Batched prefill + donated multi-row scatter into the slot pool.

        ``slots`` (R,) are the destination slot ids (duplicates allowed only
        for padded rows carrying identical values); ``total`` (R,) is each
        row's true sequence length INCLUDING any vision prefix.

        Paged engines take an extra ``pt_rows`` (R, n_pt) page-table
        argument: the big KV leaves scatter THROUGH the tables into the
        physical pool (a row's unreserved tail maps to the null page — its
        writes are garbage-by-construction and masked via ``pos``), while
        pos/index keep the per-slot scatter.
        """
        model = self.model
        capacity = self.serving.max_seq
        pad_ok = self.cfg.family in _PADDED_FAMILIES

        def remask(cache1, total):
            if pad_ok and "pos" in cache1:
                cache1 = dict(cache1)
                cap = cache1["pos"].shape[1]
                # pad entries carry absolute positions >= the row's true
                # length -> mark empty; next write lands at the true end
                cache1["pos"] = jnp.where(cache1["pos"] < total[:, None],
                                          cache1["pos"], -1)
                cache1["index"] = (jnp.minimum(total, cap) % cap).astype(
                    jnp.int32)
            return cache1

        if self._pt is None:
            def fn(params, pool, batch, slots, total):
                logits, cache1 = model.prefill(params, batch, capacity)
                cache1 = remask(cache1, total)

                def ins(pool_leaf, one, bax):
                    idx = (slice(None),) * bax + (slots,)
                    return pool_leaf.at[idx].set(one.astype(pool_leaf.dtype))

                pool = jax.tree.map(ins, pool, cache1,
                                    self._cache_batch_axis)
                return logits, pool

            return fn

        n_pt, page = self._n_pt, self.serving.kv_page_size
        paged_names = self._paged_names
        axis_by_name = self._axis_by_name

        def fn_paged(params, pool, batch, slots, total, pt_rows, npg):
            # ``npg`` (static) is the group's reserved-page high-water: only
            # the leading npg pages per row scatter into the pool (the tail
            # past a prompt's reservation is all null-page writes — skipping
            # it keeps the scatter near the dense path's cost)
            logits, cache1 = model.prefill(params, batch, capacity)
            cache1 = remask(cache1, total)
            pool = dict(pool)
            pages_leaf = pool.pop("pages")
            pt_sl = pt_rows[:, :npg]

            def ins(path, pool_leaf, one):
                name = jax.tree_util.keystr(path)
                bax = axis_by_name[name][0]
                if name in paged_names:
                    one = one.reshape(one.shape[:bax + 1] + (n_pt, page)
                                      + one.shape[bax + 2:])
                    one = one[(slice(None),) * (bax + 1)
                              + (slice(0, npg),)]
                    idx = (slice(None),) * bax + (pt_sl,)
                else:
                    idx = (slice(None),) * bax + (slots,)
                return pool_leaf.at[idx].set(one.astype(pool_leaf.dtype))

            pool = jax.tree_util.tree_map_with_path(ins, pool, cache1)
            pool["pages"] = pages_leaf.at[slots].set(pt_rows)
            return logits, pool

        return fn_paged

    def _make_warm_scan(self):
        """Suffix prefill for prefix-cache hits / resumed sessions: run the
        model's own ``decode_step`` over the suffix tokens in ONE jitted
        ``lax.scan`` against a batch-1 cache holding the reused rows.

        This is exact by construction — it is the very path the engine
        decodes with, so KV/state updates and logits match what a cold full
        prefill followed by decode would produce (bit-identical for the
        full-capacity KV families, within fp noise for ssm/hybrid
        recurrences) — and it costs one host dispatch per suffix instead of
        one per token. One trace is compiled per (suffix length, context
        bucket); sliceable families right-pad the suffix to the
        power-of-two ladder (pad writes land past the true end and are
        masked via ``pos``, exactly like bucketed prefill pads). ``teff``
        is the same context-bucket hint fused decode uses: attention reads
        only the leading ``teff`` cache entries instead of all of
        ``max_seq``.
        """
        model = self.model
        max_seq = int(self.serving.max_seq)

        def fn(params, cache, tokens, positions, teff):  # (T,), (T,)
            ctx = teff if teff < max_seq else None

            def body(c, tp):
                tok, pos = tp
                logits, c2 = model.decode_step(
                    params, c, {"tokens": tok[None, None],
                                "positions": pos[None]}, ctx=ctx)
                return c2, logits[0]

            cache, logits = jax.lax.scan(body, cache, (tokens, positions))
            return logits, cache

        return fn

    def _context_bucket(self, needed: int) -> int:
        """Smallest {2^n, 1.5*2^n} ladder value covering ``needed``
        positions (each bucket is one cached trace)."""
        teff = 32
        while teff < needed:
            teff = teff * 3 // 2 if teff & (teff - 1) == 0 else teff * 4 // 3
        return min(teff, self.serving.max_seq)

    # ------------------------------------------------------------------

    def submit(self, rid: int, tokens: np.ndarray, max_new: int = 32,
               extras: Optional[Dict[str, np.ndarray]] = None,
               deadline: Optional[float] = None,
               session: Optional[str] = None) -> None:
        """Queue a prompt. ``deadline`` (monotonic seconds) enables
        EDF-ordered admission: the waiting queue is drained
        earliest-deadline-first instead of FIFO. ``session`` names a
        multi-turn session: the finished turn's slot state is parked so a
        later turn whose prompt extends this conversation resumes it
        (prefilling only the new tokens) instead of re-prefilling the whole
        history."""
        self.journal.append(("submit", {"rid": rid, "tokens": tokens,
                                        "max_new": max_new,
                                        "extras": extras,
                                        "deadline": deadline,
                                        "session": session}))
        self.waiting.append({"rid": rid, "tokens": np.asarray(tokens),
                             "max_new": max_new, "extras": extras or {},
                             "deadline": deadline, "session": session,
                             "t": time.monotonic()})

    def cancel(self, rid: int) -> bool:
        """Abort a request wherever it is (waiting or mid-decode). The
        cluster runtime uses this to retire the losing hedge twin; the freed
        slot is refilled at the next admission."""
        for i, j in enumerate(self.waiting):
            if j["rid"] == rid:
                del self.waiting[i]
                self.journal.append(("cancel", {"rid": rid}))
                return True
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[i] = None  # KV rows are overwritten on next admit
                self._release_pages(i)
                self.journal.append(("cancel", {"rid": rid}))
                return True
        return False

    # -- cross-tier KV migration -------------------------------------------

    def _leaf_rows(self):
        """Yield ``(name, leaf, batch_axis)`` per cache leaf — the same
        axis-aware walk the prefill scatter uses (``cache_axes``-driven, so
        hybrid rglru leaves with batch at axis 2 come out right)."""
        flat = jax.tree_util.tree_leaves_with_path(self.cache)
        axes = jax.tree.leaves(self._cache_batch_axis)
        for (path, leaf), bax in zip(flat, axes):
            yield jax.tree_util.keystr(path), leaf, bax

    def _slot_payload(self, slot: int) -> SlotPayload:
        """Serialize slot ``slot``'s full migratable state (cache rows,
        SeqState, position, sampling key, prompt tokens). The rows stay
        DEVICE-resident (``jnp.take`` copies out of the donated pool); the
        wire format converts to host bytes lazily, so a payload parked and
        resumed on the same tier never round-trips through the host."""
        if self._pt is not None:
            leaves = self._gather_slot_rows(slot)
        else:
            leaves = {name: jnp.take(leaf, slot, axis=bax)
                      for name, leaf, bax in self._leaf_rows()}
        return SlotPayload(
            version=MIGRATION_WIRE_VERSION, model=self.cfg.name,
            family=self.cfg.family, max_seq=self.serving.max_seq,
            seq=self._copy_seq(self.slots[slot]),
            position=int(self.positions[slot]),
            key=np.asarray(self._keys[slot]), leaves=leaves,
            prompt_tokens=self._slot_prompt[slot],
            extras_fp=self._slot_extras_fp[slot])

    def extract_slot(self, rid: int, *, remove: bool = False) -> SlotPayload:
        """Serialize one request's migratable state (see ``SlotPayload``).
        ``remove=True`` frees the slot (preemption / re-homing); the default
        keeps the donor decoding (hedged clone races the original)."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s is not None and s.rid == rid), None)
        if slot is None:
            raise MigrationError(
                f"rid {rid} holds no decode slot on this engine")
        payload = self._slot_payload(slot)
        if remove:
            self.slots[slot] = None  # KV rows overwritten on the next admit
            self._release_pages(slot)
        self.journal.append(("extract", {"rid": rid, "removed": remove}))
        return payload

    def inject_slot(self, payload: SlotPayload) -> int:
        """Install a migrated request into a free slot and resume its decode
        exactly where the donor left off (no prefill — ``prefill_tokens``
        does not move). Validates the wire version, model spec and every
        leaf's shape/dtype BEFORE touching the cache; any mismatch raises
        :class:`MigrationError` and leaves this engine unchanged."""
        if payload.version != MIGRATION_WIRE_VERSION:
            raise MigrationError(
                f"wire format version {payload.version} != supported "
                f"{MIGRATION_WIRE_VERSION}")
        if payload.model != self.cfg.name or payload.family != self.cfg.family:
            raise MigrationError(
                f"payload from {payload.model!r} ({payload.family}) cannot be "
                f"injected into {self.cfg.name!r} ({self.cfg.family}): KV "
                f"caches are model-specific")
        if any(s is not None and s.rid == payload.seq.rid for s in self.slots):
            raise MigrationError(
                f"rid {payload.seq.rid} already occupies a slot here")
        slot = self._free_slot()
        if slot is None:
            raise MigrationError("no free decode slot to inject into")
        rows = dict(payload.leaves)
        if set(self._row_specs) != set(rows):
            raise MigrationError(
                f"cache leaf mismatch: payload has {sorted(rows)}, engine "
                f"expects {sorted(self._row_specs)}")
        for name, (want, dtype) in self._row_specs.items():
            row = rows[name]
            if tuple(row.shape) != tuple(want):
                raise MigrationError(
                    f"leaf {name}: payload row shape {tuple(row.shape)} != "
                    f"engine row shape {tuple(want)} (max_seq "
                    f"{payload.max_seq} vs {self.serving.max_seq}?)")
            if str(row.dtype) != dtype:
                raise MigrationError(
                    f"leaf {name}: payload dtype {row.dtype} != engine "
                    f"dtype {dtype}")
        if self.pool is not None:
            seq = payload.seq
            total = min(payload.position
                        + max(int(seq.max_new) - len(seq.generated), 0) + 1,
                        self.serving.max_seq)
            pages = self._reserve_pages(self._page_need(total))
            if pages is None:
                raise MigrationError(
                    "no free KV pages to inject into (pool exhausted)")
            self._assign_pages(slot, pages)
        self._install_rows(slot, rows)
        self.slots[slot] = self._copy_seq(payload.seq)
        self.positions[slot] = payload.position
        self._keys = self._keys.at[slot].set(jnp.asarray(payload.key))
        self._slot_prompt[slot] = (None if payload.prompt_tokens is None
                                   else np.asarray(payload.prompt_tokens))
        self._slot_extras_fp[slot] = payload.extras_fp
        self.journal.append(("inject", {"rid": payload.seq.rid, "slot": slot}))
        return slot

    # -- prefix & session KV reuse -----------------------------------------

    def _job_fp(self, job: Dict[str, Any]) -> bytes:
        """Extras fingerprint of a waiting job, computed once and cached."""
        fp = job.get("_fp")
        if fp is None:
            fp = extras_fingerprint(job["extras"])
            job["_fp"] = fp
        return fp

    def _rows_compatible(self, rows: Dict[str, np.ndarray]) -> bool:
        """True when ``rows`` (keystr -> per-slot row) matches this engine's
        DENSE per-slot row geometry exactly (same leaves, shapes, dtypes).
        Paged and dense engines share the geometry — the wire format is
        structure-independent, so payloads migrate across pool designs."""
        if set(rows) != set(self._row_specs):
            return False
        for name, (shape, dtype) in self._row_specs.items():
            row = rows[name]
            if (tuple(row.shape) != tuple(shape)
                    or str(row.dtype) != dtype):
                return False
        return True

    def _payload_resumable(self, p: SlotPayload) -> bool:
        return (p.version == MIGRATION_WIRE_VERSION
                and p.model == self.cfg.name
                and p.family == self.cfg.family
                and p.max_seq == self.serving.max_seq
                and self._rows_compatible(p.leaves))

    @staticmethod
    def _payload_tokens(p: SlotPayload) -> Optional[np.ndarray]:
        """The tokens a payload's cache rows cover: the prompt plus every
        generated token except the last (sampled but never fed)."""
        if p.prompt_tokens is None:
            return None
        prompt = np.asarray(p.prompt_tokens, np.int32)
        gen = np.asarray(p.seq.generated[:-1], np.int32)
        return np.concatenate([prompt, gen]) if gen.size else prompt

    def rids(self) -> List[int]:
        """Every request currently on this engine (queued + in a slot), in
        queue-then-slot order — the replica-pool fault path replays against
        this set."""
        out = [j["rid"] for j in self.waiting]
        out.extend(s.rid for s in self.slots if s is not None)
        return out

    def free_slot_count(self) -> int:
        """Open slots (the pool's load-balance / re-home capacity probe)."""
        return sum(s is None for s in self.slots)

    def park_session(self, rid: int, sid: Optional[str] = None) -> bool:
        """Mark a queued or in-flight request so its slot state parks under
        ``sid`` when it finishes (``submit(session=...)`` does this up
        front). Returns False when the rid is unknown or no sid is set."""
        for j in self.waiting:
            if j["rid"] == rid:
                j["session"] = sid or j.get("session")
                return j["session"] is not None
        for s in self.slots:
            if s is not None and s.rid == rid:
                s.session = sid or s.session
                return s.session is not None
        return False

    def resume_session(self, sid: str) -> Optional[ParkedSession]:
        """Pop a parked session (the caller consumes its rows). Admission
        does this internally; it is public for cross-tier moves."""
        return self.sessions.resume(sid)

    def adopt_session(self, sid: str, payload: SlotPayload) -> bool:
        """Install a session payload parked on ANOTHER engine (the sticky
        router moved it here). Incompatible payloads are refused — the next
        turn then falls back to a cold prefill."""
        if not self._payload_resumable(payload):
            return False
        tokens = self._payload_tokens(payload)
        if tokens is None:
            return False
        nbytes = sum(v.nbytes for v in payload.leaves.values())
        ok = self.sessions.park(sid, ParkedSession(
            tokens=tokens, extras_fp=payload.extras_fp,
            nbytes=float(nbytes), data=payload))
        if ok:
            self.journal.append(("adopt", {"sid": sid}))
        return ok

    def _park(self, slot: int, st: SeqState) -> None:
        """Park a finishing slot's state under its session id (called from
        ``_finish_slot`` while the cache rows are still intact)."""
        if not st.session or not self.sessions.enabled:
            return
        if self._slot_prompt[slot] is None:
            return  # injected without prompt tokens: nothing to match later
        payload = self._slot_payload(slot)
        tokens = self._payload_tokens(payload)
        nbytes = sum(v.nbytes for v in payload.leaves.values())
        ok = self.sessions.park(st.session, ParkedSession(
            tokens=tokens, extras_fp=self._slot_extras_fp[slot],
            nbytes=float(nbytes), data=payload))
        if ok:
            self.parks += 1
            self.journal.append(("park", {"rid": st.rid, "sid": st.session}))
            if self.on_park is not None:
                self.on_park(st.rid, st.session)

    def _warm_plan(self, job: Dict[str, Any]) -> Optional[Dict[str, Any]]:
        """Reuse plan for a waiting job: a parked session its prompt
        extends, else a stored prefix it extends; None means cold prefill.
        ``rows``/``start`` describe the cache state to re-inject;
        ``time_len`` is set when ``rows`` are seq-sliced pieces that must be
        pasted into zeroed full-capacity rows."""
        tokens = np.asarray(job["tokens"])
        cap = self.serving.max_seq
        sid = job.get("session")
        if sid and self.sessions.enabled:
            parked = self.sessions.peek(sid)
            if parked is not None and parked.extras_fp == self._job_fp(job):
                suffix = extension_suffix(parked.tokens, tokens)
                p = parked.data
                if (suffix is not None and isinstance(p, SlotPayload)
                        and self._payload_resumable(p)
                        and p.position + len(suffix) + 1 < cap):
                    # the parked rows are popped at COMMIT time (in
                    # _admit_warm_hits) — page reservation may still defer
                    # this admission, and a deferred plan must not have
                    # consumed the session
                    # cached counts the cache POSITIONS reused (vision
                    # prefix included) — the same accounting the analytic
                    # backend's context-token mirror reports
                    return {"kind": "resume", "rows": p.leaves,
                            "start": p.position, "time_len": None,
                            "suffix": suffix, "cached": p.position,
                            "sid": sid}
        if self.prefix_store.enabled:
            e = self.prefix_store.lookup(tokens, self._job_fp(job))
            if e is None:
                return None
            suffix = np.asarray(tokens[len(e.tokens):])
            if e.sliceable:
                vis = self._prompt_prefix(job["extras"])
                start = vis + len(e.tokens)
                if start + len(suffix) + 1 < cap:
                    plan = {"kind": "prefix", "rows": e.data, "start": start,
                            "time_len": start, "suffix": suffix,
                            "cached": start}
                    if self._pt is not None:
                        # paged entry: {"pages", "t_len"} — rows are
                        # gathered (and full pages CoW-shared) at admit
                        plan["rows"] = None
                        plan["pages_entry"] = e.data
                    return plan
            else:
                start = int(e.data["position"])
                rows = e.data["rows"]
                if (self._rows_compatible(rows)
                        and start + len(suffix) + 1 < cap):
                    return {"kind": "prefix", "rows": rows, "start": start,
                            "time_len": None, "suffix": suffix,
                            "cached": start}
        return None

    def _admit_warm_hits(self) -> None:
        i = 0
        while i < len(self.waiting):
            slot = self._free_slot()
            if slot is None:
                return
            job = self.waiting[i]
            plan = self._warm_plan(job)
            if plan is None:
                i += 1
                continue
            if self.pool is not None and not self._reserve_warm(job, plan):
                # starved for pages: stop admitting (FIFO within warm hits)
                # and let the fused block split at the pressure boundary
                self._page_pressure = True
                return
            if plan.get("sid"):
                self.sessions.resume(plan["sid"])  # rows consumed this turn
            del self.waiting[i]
            self._admit_warm(job, slot, plan)

    def _reserve_warm(self, job: Dict[str, Any],
                      plan: Dict[str, Any]) -> bool:
        """Reserve ``job``'s full page budget for a warm admission. FULL
        pages strictly behind the reused frontier are CoW-shared from the
        store entry (incref, no copy); the boundary page and the growth tail
        come from fresh pages. All-or-nothing: on failure every reference
        taken here is dropped."""
        start = int(plan["start"])
        total = min(start + len(plan["suffix"]) + int(job["max_new"]),
                    self.serving.max_seq)
        need = self._page_need(total)
        ent = plan.get("pages_entry")
        shared: List[int] = []
        if ent is not None:
            ps = self.serving.kv_page_size
            shared = [int(p) for p in ent["pages"][:int(ent["t_len"]) // ps]]
            # pin BEFORE allocating: _reserve_pages may evict this very
            # store entry under pressure, and its pages must survive
            self.pool.incref(shared)
        fresh = self._reserve_pages(need - len(shared))
        if fresh is None:
            if shared:
                self.pool.decref(shared)
            return False
        plan["_pages"] = shared + fresh
        return True

    def _admit_warm(self, job: Dict[str, Any], slot: int,
                    plan: Dict[str, Any]) -> None:
        """Copy reused cache rows into a batch-1 cache, prefill ONLY the
        suffix through the jitted decode scan, and scatter the result into
        ``slot``. ``prefill_tokens`` moves by the suffix length alone."""
        cap = self.serving.max_seq
        pages = plan.pop("_pages", None)
        if pages is not None:
            self._assign_pages(slot, pages)
        rows = plan["rows"]
        if rows is None and plan.get("pages_entry") is not None:
            # copy-free hit: the shared pages are already mapped into the
            # slot's table; gather them once into seq-sliced dense pieces
            # for the batch-1 suffix prefill below
            rows = self._gather_prefix_rows(plan["pages_entry"])
        t_len = plan["time_len"]
        start = int(plan["start"])
        suffix = np.asarray(plan["suffix"], np.int32)

        def build(path, leaf):
            name = jax.tree_util.keystr(path)
            bax, sax = self._axis_by_name[name]
            shape = leaf.shape[:bax] + leaf.shape[bax + 1:]
            row = rows.get(name)
            if row is not None and tuple(row.shape) == tuple(shape):
                # explicit COPY: cache1 is donated to the suffix-prefill
                # jit, and a store-held row must survive its admission
                # (non-sliceable prefix entries are reused across hits)
                out = jnp.array(row, leaf.dtype)
            elif row is not None:  # seq-sliced piece -> paste into zeros
                rsax = sax - (1 if sax > bax else 0)
                sl = [slice(None)] * len(shape)
                sl[rsax] = slice(0, row.shape[rsax])
                out = jnp.zeros(shape, leaf.dtype).at[tuple(sl)].set(
                    jnp.asarray(row, leaf.dtype))
            elif name == "['pos']":  # synthesized: linear placement
                pos = np.full(shape, -1, np.int32)
                pos[:t_len] = np.arange(t_len, dtype=np.int32)
                out = jnp.asarray(pos)
            elif name == "['index']":
                out = jnp.asarray(t_len % cap, jnp.int32)
            else:  # unreachable for known caches; keep the walk total
                out = jnp.zeros(shape, leaf.dtype)
            return jnp.expand_dims(out, bax)

        tmpl = (self._dense_spec_tree if self._dense_spec_tree is not None
                else self.cache)
        cache1 = jax.tree_util.tree_map_with_path(build, tmpl)
        n = len(suffix)
        total = start + n
        np_ = n
        if self._sliceable:
            # pad to the power-of-two ladder (bounds traces, like bucketed
            # prefill); pad writes land past the true end and are re-masked
            np_ = min(_next_bucket(n, lo=8), cap - start)
        toks = np.full((np_,), suffix[-1], np.int32)
        toks[:n] = suffix
        positions = start + np.arange(np_, dtype=np.int32)
        teff = (self._context_bucket(start + np_ + 1) if self._ctx_buckets
                else self.serving.max_seq)
        if self._sliceable:
            # ONE multi-token pass over the suffix (a weights pass per
            # suffix, not per token): decode_chunk writes the S new KV
            # rows and attends the reused prefix by absolute position
            batch = {"tokens": jnp.asarray(toks[None]),
                     "positions": jnp.asarray(positions[None])}
            if np_ > n:
                batch["lengths"] = jnp.asarray([n], jnp.int32)
            logits1, cache1 = self._warm_chunk(self.params, cache1, batch,
                                               teff)
            first_logits = np.asarray(logits1)[0]
        elif self._warm_chunk_recurrent is not None:
            # chunked recurrent suffix prefill: ONE pass seeding the ssd /
            # rglru chunk kernels from the cached state — a weights pass
            # per suffix instead of per token (np_ == n here: recurrent
            # state admits no padding, every token advances the scan)
            batch = {"tokens": jnp.asarray(toks[None]),
                     "positions": jnp.asarray(positions[None])}
            logits1, cache1 = self._warm_chunk_recurrent(self.params,
                                                         cache1, batch)
            first_logits = np.asarray(logits1)[0]
        else:
            # point-in-time state families step their own decode path over
            # the exact suffix (recurrent state admits no padding)
            logits_all, cache1 = self._warm_scan(self.params, cache1,
                                                 jnp.asarray(toks),
                                                 jnp.asarray(positions),
                                                 teff)
            first_logits = np.asarray(logits_all)[n - 1]
        if np_ > n and "pos" in cache1:
            cache1 = dict(cache1)
            cache1["pos"] = jnp.where(cache1["pos"] < total,
                                      cache1["pos"], -1)
            cache1["index"] = jnp.full_like(cache1["index"], total % cap)
        self._insert_cache(cache1, slot)
        self._start_seq(job, slot, total, first_logits,
                        prefill_count=n,
                        warm=(plan["kind"], int(plan["cached"])))

    def _store_prefixes(self, slot: int, job: Dict[str, Any]) -> None:
        """Deposit a just-admitted slot's cache rows into the prefix store
        at bucket-aligned prefix lengths (sliceable families) or the exact
        processed length (ssm/hybrid point-in-time state)."""
        if not self.prefix_store.enabled:
            return
        tokens = np.asarray(job["tokens"])
        vis = self._prompt_prefix(job["extras"])
        if vis + len(tokens) > self.serving.max_seq:
            return  # rolled/truncated cache rows don't map to positions
        fp = self._job_fp(job)
        store = self.prefix_store
        if not self._sliceable:
            if store.contains(tokens, fp):
                return
            rows = {name: np.asarray(jnp.take(leaf, slot, axis=bax))
                    for name, leaf, bax in self._leaf_rows()}
            nb = float(sum(r.nbytes for r in rows.values()))
            store.insert(tokens, fp, nb,
                         {"rows": rows, "position": int(vis + len(tokens))},
                         sliceable=False)
            return
        need = [L for L in prefix_buckets(len(tokens), store.min_prefix)
                if not store.contains(tokens[:L], fp)]
        if not need:
            return
        if self._pt is not None:
            # copy-free deposit: the entry is a page-id list increfing the
            # slot's own pages — no row duplication. The boundary page is
            # increfed for CONTENT liveness only (warm hits CoW-share just
            # the full pages strictly behind t_len; the depositor keeps
            # writing rows >= t_len on that page, which readers never see
            # because they slice to t_len).
            ps = self.serving.kv_page_size
            for L in need:
                t_len = vis + L
                npg = pages_needed(t_len, ps, self.serving.max_seq)
                pages = [int(p) for p in self._pt[slot][:npg]]
                self.pool.incref(pages)
                ok = store.insert(tokens[:L], fp,
                                  npg * self.pool.page_bytes,
                                  {"pages": pages, "t_len": t_len},
                                  sliceable=True)
                if not ok:
                    self.pool.decref(pages)
            return
        # rows stay device-resident: jnp.take copies out of the (donated)
        # pool and the bucket slices are device slices — depositing a
        # prefix never round-trips the KV through the host
        rows = {name: jnp.take(leaf, slot, axis=bax)
                for name, leaf, bax in self._leaf_rows()
                if name not in ("['pos']", "['index']")}
        for L in need:
            t_len = vis + L
            data = {}
            nb = 0.0
            for name, row in rows.items():
                bax, sax = self._axis_by_name[name]
                rsax = sax - (1 if sax > bax else 0)
                sl = [slice(None)] * row.ndim
                sl[rsax] = slice(0, t_len)
                piece = row[tuple(sl)]
                nb += piece.nbytes
                data[name] = piece
            store.insert(tokens[:L], fp, nb, data, sliceable=True)

    def encode_image(self, image: np.ndarray, num_patches: int = 0,
                     frontend_dim: int = 0) -> np.ndarray:
        """Encode-only entry point (executed partial offload): run the
        vision frontend on THIS tier's device and return compact patch
        embeddings in the target ``(num_patches, frontend_dim)`` geometry
        (defaults to this engine's own model).

        The stub frontend tiles the normalized pixels into the patch grid —
        bit-identical to what a fusion-local prefill would compute, so
        routing an image off the fusion tier never changes the generated
        tokens; only the compact embeddings travel."""
        img = np.asarray(image)
        p = num_patches or self.cfg.num_patches
        fd = frontend_dim or self.cfg.frontend_dim
        key = (p, fd, int(img.size))
        fn = self._encode_jits.get(key)
        if fn is None:
            need = p * fd
            rep = max(1, int(np.ceil(need / max(img.size, 1))))

            def _enc(x):
                flat = x.reshape(-1).astype(jnp.float32) / 255.0
                return jnp.tile(flat, rep)[:need].reshape(p, fd)

            fn = jax.jit(_enc)
            self._encode_jits[key] = fn
        out = np.asarray(fn(jnp.asarray(img)))
        self.encode_tokens += p
        self.journal.append(("encode", {"patches": p}))
        return out

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # -- paged KV pool ------------------------------------------------------

    def _page_need(self, total_rows: int) -> int:
        """Pages a request occupying ``total_rows`` cache rows reserves.
        Recurrent families charge a fixed per-slot amount (their state has
        no positional pages)."""
        if not self._sliceable:
            return self._slot_page_charge
        return pages_needed(total_rows, self.serving.kv_page_size,
                            self.serving.max_seq)

    def _reserve_pages(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh pages, reclaiming prefix-store LRU entries
        under pressure (store-held pages are spare capacity: a live request
        always beats a cached prefix). None when the pool is truly short."""
        if n <= 0:
            return []
        pool = self.pool
        if not pool.can_alloc(n) and self._pt is not None:
            while not pool.can_alloc(n):
                if self.prefix_store.evict_oldest() is None:
                    break
        return pool.alloc(n)

    def _assign_pages(self, slot: int, pages: List[int]) -> None:
        """Record ``slot``'s page list and host page table. The DEVICE page
        table row is written by the insert path that follows (prefill
        scatter / batch-1 insert / migration install)."""
        self._slot_pages[slot] = list(pages)
        if self._pt is not None:
            row = np.zeros((self._n_pt,), np.int32)
            row[:len(pages)] = pages
            self._pt[slot] = row

    def _release_pages(self, slot: int) -> None:
        """Drop ``slot``'s page references (pages whose last reader this was
        rejoin the free list) and retarget its DEVICE page table at the null
        page: a freed slot keeps stepping inside the fused block, and its
        dead writes must never land on a page that may be re-allocated."""
        if self.pool is None:
            return
        if self._slot_pages[slot]:
            self.pool.decref(self._slot_pages[slot])
            self._slot_pages[slot] = []
        if self._pt is not None:
            self._pt[slot] = 0
            cache = dict(self.cache)
            cache["pages"] = cache["pages"].at[slot].set(
                jnp.zeros((self._n_pt,), jnp.int32))
            self.cache = cache

    def _on_prefix_evict(self, entry) -> None:
        """Store removal hook: decref a paged entry's shared pages."""
        data = getattr(entry, "data", None)
        if isinstance(data, dict) and "pages" in data:
            self.pool.decref(data["pages"])

    def _gather_slot_rows(self, slot: int) -> Dict[str, Any]:
        """Dense-geometry per-slot rows gathered THROUGH the page table —
        the paged engine's side of the (unchanged) migration wire format.
        Rows past the written frontier come from the null page; their
        positions are -1, so they are masked wherever they land."""
        n_pt, page = self._n_pt, self.serving.kv_page_size
        pt = jnp.asarray(self._pt[slot])
        rows = {}
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.cache):
            name = jax.tree_util.keystr(path)
            if name == "['pages']":
                continue
            bax = self._axis_by_name[name][0]
            if name in self._paged_names:
                g = jnp.take(leaf, pt, axis=bax)  # (..., n_pt, page, ...)
                rows[name] = g.reshape(g.shape[:bax] + (n_pt * page,)
                                       + g.shape[bax + 2:])
            else:
                rows[name] = jnp.take(leaf, slot, axis=bax)
        return rows

    def _gather_prefix_rows(self, ent: Dict[str, Any]) -> Dict[str, Any]:
        """Materialize a paged prefix-store entry ({"pages", "t_len"}) into
        the seq-sliced dense pieces the warm-admission build step pastes —
        the deposit itself was copy-free (page increfs, no row copies)."""
        page = self.serving.kv_page_size
        t_len = int(ent["t_len"])
        npg = len(ent["pages"])
        pt = jnp.asarray(np.asarray(ent["pages"], np.int32))
        rows = {}
        for path, leaf in jax.tree_util.tree_leaves_with_path(self.cache):
            name = jax.tree_util.keystr(path)
            if name not in self._paged_names:
                continue
            bax = self._axis_by_name[name][0]
            g = jnp.take(leaf, pt, axis=bax)
            g = g.reshape(g.shape[:bax] + (npg * page,) + g.shape[bax + 2:])
            rows[name] = g[(slice(None),) * bax + (slice(0, t_len),)]
        return rows

    def _install_rows(self, slot: int, rows: Dict[str, Any]) -> None:
        """Scatter dense-geometry per-slot rows into ``slot`` (through the
        page table on paged engines). Inverse of ``_gather_slot_rows``."""
        if self._pt is None:
            def put(path, leaf, bax):
                row = rows[jax.tree_util.keystr(path)]
                idx = (slice(None),) * bax + (slot,)
                return leaf.at[idx].set(jnp.asarray(row))

            self.cache = jax.tree_util.tree_map_with_path(
                put, self.cache, self._cache_batch_axis)
            return
        n_pt, page = self._n_pt, self.serving.kv_page_size
        pt = jnp.asarray(self._pt[slot])
        pool = dict(self.cache)
        pages_leaf = pool.pop("pages")

        def put(path, leaf):
            name = jax.tree_util.keystr(path)
            row = jnp.asarray(rows[name])
            bax = self._axis_by_name[name][0]
            if name in self._paged_names:
                row = row.reshape(row.shape[:bax] + (n_pt, page)
                                  + row.shape[bax + 1:])
                idx = (slice(None),) * bax + (pt,)
            else:
                idx = (slice(None),) * bax + (slot,)
            return leaf.at[idx].set(row.astype(leaf.dtype))

        pool = jax.tree_util.tree_map_with_path(put, pool)
        pool["pages"] = pages_leaf.at[slot].set(pt)
        self.cache = pool

    def kv_gauges(self) -> Dict[str, float]:
        """KV occupancy gauges the scheduler observes: pages_total / free /
        shared + high-water. Dense engines synthesize slot-granular numbers
        so both pool designs report comparable headroom."""
        if self.pool is not None:
            return self.pool.gauges()
        npp = max(1, -(-self.serving.max_seq // self.serving.kv_page_size))
        used = sum(1 for s in self.slots if s is not None)
        self._slots_hw = max(self._slots_hw, used)
        return {"pages_total": len(self.slots) * npp,
                "pages_free": (len(self.slots) - used) * npp,
                "pages_shared": 0,
                "pages_high_water": self._slots_hw * npp,
                "page_bytes": 0.0}

    def kv_headroom(self) -> float:
        """Free fraction of the KV pool in [0, 1]."""
        g = self.kv_gauges()
        return g["pages_free"] / max(1, g["pages_total"])

    def _insert_cache(self, cache1, slot: int) -> None:
        """Copy a batch-1 prefill cache into slot ``slot`` (through the
        page table on paged engines — shared CoW pages receive the very
        bytes that were gathered out of them, unreserved tail entries land
        on the null page)."""
        if self._pt is not None:
            n_pt, page = self._n_pt, self.serving.kv_page_size
            pt = jnp.asarray(self._pt[slot])
            pool = dict(self.cache)
            pages_leaf = pool.pop("pages")

            def insp(path, pool_leaf, one):
                name = jax.tree_util.keystr(path)
                bax = self._axis_by_name[name][0]
                row = one[(slice(None),) * bax + (0,)]
                if name in self._paged_names:
                    row = row.reshape(row.shape[:bax] + (n_pt, page)
                                      + row.shape[bax + 1:])
                    idx = (slice(None),) * bax + (pt,)
                else:
                    idx = (slice(None),) * bax + (slot,)
                return pool_leaf.at[idx].set(row.astype(pool_leaf.dtype))

            pool = jax.tree_util.tree_map_with_path(insp, pool, cache1)
            pool["pages"] = pages_leaf.at[slot].set(pt)
            self.cache = pool
            return

        def ins(pool, one, bax):
            idx = (slice(None),) * bax + (slot,)
            sel = (slice(None),) * bax + (0,)
            return pool.at[idx].set(one[sel].astype(pool.dtype))
        self.cache = jax.tree.map(ins, self.cache, cache1,
                                  self._cache_batch_axis)

    def _start_seq(self, job: Dict[str, Any], slot: int, prompt_len: int,
                   first_logits: np.ndarray,
                   prefill_count: Optional[int] = None,
                   warm: Optional[tuple] = None) -> None:
        """Shared admit bookkeeping: first token, done-check, journal.
        ``prefill_count`` overrides the prefill-token charge (a warm admit
        prefilled only its suffix); ``warm`` = (kind, cached_tokens) tags
        prefix-hit / resumed-session admissions."""
        st = SeqState(rid=job["rid"], prompt_len=prompt_len,
                      max_new=job["max_new"], t_submit=job["t"],
                      session=job.get("session"))
        self._slot_prompt[slot] = np.asarray(job["tokens"], np.int32)
        self._slot_extras_fp[slot] = (
            self._job_fp(job)
            if (st.session and self.sessions.enabled)
            or self.prefix_store.enabled else b"")
        first = self._sample(first_logits)
        st.generated.append(int(first))
        st.t_first_token = time.monotonic()
        self.slots[slot] = st
        self.positions[slot] = prompt_len
        charged = prompt_len if prefill_count is None else prefill_count
        self.prefill_tokens += charged
        self.decode_tokens += 1
        if warm is not None:
            kind, cached = warm
            if kind == "resume":
                self.resumed_sessions += 1
                self.resumed_tokens += cached
            else:
                self.prefix_hits += 1
                self.prefix_hit_tokens += cached
            self.journal.append(("warm", {"rid": st.rid, "kind": kind,
                                          "cached": cached,
                                          "suffix": charged}))
            if self.on_warm is not None:
                self.on_warm(st.rid, kind, cached, charged)
        self.journal.append(("admit", {"rid": st.rid, "slot": slot}))
        if self.on_admit is not None:
            self.on_admit(st.rid, st.t_first_token)
        if self.on_token is not None:
            self.on_token(st.rid, int(first), st.t_first_token)
        self._store_prefixes(slot, job)
        # a request may be complete straight out of prefill (EOS first
        # token, max_new == 1, or a prompt already at capacity)
        if (first == self.eos_id or len(st.generated) >= st.max_new
                or prompt_len + 1 >= self.serving.max_seq):
            self._finish_slot(slot, time.monotonic())

    def _finish_slot(self, slot: int, now: float) -> None:
        st = self.slots[slot]
        st.done = True
        st.t_done = now
        self._park(slot, st)  # while the slot's cache rows are intact
        self.finished.append(st)
        self.journal.append(("finish", {"rid": st.rid}))
        self.slots[slot] = None
        self._release_pages(slot)

    def _prompt_prefix(self, extras: Dict[str, Any]) -> int:
        if self.cfg.frontend == "vision_stub" and "patches" in extras:
            return self.cfg.num_patches
        return 0

    # -- cross-tier speculative decoding (draft-and-verify) -----------------
    #
    # The cluster runtime drives one verify loop per speculated request:
    #
    #   target: submit + _admit (a NORMAL slot)    draft: spec_admit_quiet
    #   target: spec_begin (trim pages to the written frontier)
    #   loop:   draft.spec_draft(k) -> target.spec_verify(block)
    #           -> draft.spec_sync(committed)
    #   target: spec_release (restore the eager full-budget reservation
    #           before the slot returns to the fused step() path)
    #
    # spec_verify feeds [pending, d_1..d_k] through ONE chunked decode with
    # per-position logits, samples the target's OWN token at every position
    # under the slot's key stream (one split per COMMITTED token — the
    # fused path's per-step math), commits the longest prefix on which the
    # draft agreed plus the target's correction token, and rolls the cache
    # back past the first mismatch (dense: pos/index rewind before the
    # batch-1 insert; recurrent: re-feed the committed prefix from the
    # untouched pre-verify rows; paged: decref the speculative tail pages).
    # The committed stream is BY CONSTRUCTION the target-only stream, so
    # speculation changes latency, never output.

    def _make_spec_draft(self):
        """K autoregressive decode steps on a BATCH-1 cache copy — the
        draft side of speculation. Same per-step split/sample math as the
        fused block; the cache copy is discarded, so proposing never
        mutates the draft slot (only verified commits do, via spec_sync)."""
        model = self.model
        temp = float(self.temp)
        max_seq = int(self.serving.max_seq)

        def draft(params, cache1, key, tok, pos, teff, k):
            ctx = teff if teff < max_seq else None

            def body(carry, _):
                cache1, key, tok, pos = carry
                logits, cache2 = model.decode_step(
                    params, cache1,
                    {"tokens": tok[None, None], "positions": pos[None]},
                    ctx=ctx)
                if temp > 0:
                    key, sub = jax.random.split(key, 2)
                    nxt = jax.random.categorical(sub, logits[0] / temp)
                else:
                    nxt = jnp.argmax(logits[0], axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (cache2, key, nxt, pos + 1), nxt

            (_, key, *_), toks = jax.lax.scan(
                body, (cache1, key, jnp.asarray(tok, jnp.int32),
                       jnp.asarray(pos, jnp.int32)), None, length=k)
            return toks, key

        return draft

    def _ensure_spec_chunk(self):
        if self._spec_chunk_fn is not None:
            return self._spec_chunk_fn
        model, max_seq = self.model, self.serving.max_seq
        if self._sliceable:
            fn = lambda p, c, b, teff: model.decode_chunk(
                p, c, b, ctx=(teff if teff < max_seq else None),
                all_logits=True)
        else:
            fn = lambda p, c, b, teff: model.decode_chunk_recurrent(
                p, c, b, all_logits=True)
        self._spec_chunk_fn = jax.jit(fn, donate_argnums=(1,),
                                      static_argnums=(3,))
        return self._spec_chunk_fn

    def _spec_cache1(self, slot: int):
        """Batch-1 cache holding fresh COPIES of ``slot``'s rows (gathered
        through the page table on paged engines) — safe to donate to the
        verify/draft jits; the slot itself is untouched until an explicit
        ``_insert_cache``."""
        if self._pt is not None:
            rows = self._gather_slot_rows(slot)
        else:
            rows = {name: jnp.take(leaf, slot, axis=bax)
                    for name, leaf, bax in self._leaf_rows()}
        tmpl = (self._dense_spec_tree if self._dense_spec_tree is not None
                else self.cache)

        def build(path, leaf):
            name = jax.tree_util.keystr(path)
            bax = self._axis_by_name[name][0]
            return jnp.expand_dims(rows[name].astype(leaf.dtype), bax)

        return jax.tree_util.tree_map_with_path(build, tmpl)

    def spec_slot(self, rid: int) -> Optional[int]:
        """Slot currently serving ``rid`` (None: queued/finished/unknown)."""
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                return i
        return None

    def _spec_resize_pages(self, slot: int, rows: int) -> bool:
        """Resize ``slot``'s page reservation to exactly cover ``rows``
        cache rows. The verify loop grows ahead of each chunk and shrinks
        back to the committed frontier afterwards — the decref IS the
        rejected speculative tail's release. Only pages strictly beyond
        the written frontier ever trim, so CoW pages shared with the
        prefix store (always behind the frontier) are never touched.
        Returns False when the pool cannot grow."""
        if self._pt is None:
            return True  # dense engine / recurrent flat charge: no-op
        need = self._page_need(min(int(rows), self.serving.max_seq))
        have = self._slot_pages[slot]
        if need > len(have):
            fresh = self._reserve_pages(need - len(have))
            if fresh is None:
                return False
            have.extend(fresh)
        elif need < len(have):
            tail = have[need:]
            del have[need:]
            self.pool.decref(tail)
        row = np.zeros((self._n_pt,), np.int32)
        row[:len(have)] = have
        self._pt[slot] = row
        cache = dict(self.cache)
        cache["pages"] = cache["pages"].at[slot].set(jnp.asarray(row))
        self.cache = cache
        # allocator invariants: rejected-tail pages really came back, the
        # table matches the reservation, and every pool page is free XOR
        # referenced (refcount leaks fail loudly here, not at eviction)
        assert len(self._slot_pages[slot]) == need, (
            f"slot {slot}: reservation {len(self._slot_pages[slot])} != "
            f"needed {need} pages")
        self.pool.check()
        return True

    def spec_admit_quiet(self, rid: int, tokens: np.ndarray, max_new: int,
                         extras: Optional[Dict[str, Any]] = None
                         ) -> Optional[int]:
        """Admit a DRAFT-side shadow of a speculated request: a normal slot
        (single-job legacy prefill path) admitted with the streaming hooks
        muted — the target's hooks are the request's real event stream and
        the runtime must not see admit/token events twice. Returns the
        slot, or None when admission failed (no slot / no pages / finished
        straight out of prefill), in which case no trace remains."""
        slot = self._free_slot()
        if slot is None:
            return None
        job = {"rid": int(rid), "tokens": np.asarray(tokens),
               "max_new": int(max_new), "extras": extras or {},
               "deadline": None, "session": None, "t": time.monotonic()}
        if self.pool is not None:
            vis = self._prompt_prefix(job["extras"])
            total = min(vis + len(job["tokens"]) + int(max_new),
                        self.serving.max_seq)
            pages = self._reserve_pages(self._page_need(total))
            if pages is None:
                return None
            self._assign_pages(slot, pages)
        hooks = (self.on_admit, self.on_token, self.on_warm, self.on_park)
        self.on_admit = self.on_token = self.on_warm = self.on_park = None
        try:
            toks = job["tokens"][None]
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            for k, v in job["extras"].items():
                batch[k] = jnp.asarray(v)[None]
            logits, cache1 = self._prefill1(self.params, batch)
            self._insert_cache(cache1, slot)
            prefix = self._prompt_prefix(job["extras"])
            self._start_seq(job, slot, toks.shape[1] + prefix,
                            np.asarray(logits)[0])
        finally:
            self.on_admit, self.on_token, self.on_warm, self.on_park = hooks
        st = self.slots[slot]
        if st is None or st.rid != rid:
            # finished straight out of prefill (EOS / budget / cap): a
            # draft shadow has no consumer — drop the finished record
            self.finished = [f for f in self.finished if f.rid != rid]
            return None
        self.journal.append(("spec_admit", {"rid": rid, "slot": slot}))
        return slot

    def spec_set_pending(self, rid: int, token: int) -> None:
        """Overwrite the draft slot's pending (last sampled, not yet in
        cache) token with the TARGET's — after admission and after every
        verify round the draft must continue from what the target actually
        committed, not from its own independent sample."""
        slot = self.spec_slot(rid)
        if slot is None:
            return
        self.slots[slot].generated[-1] = int(token) % self.cfg.vocab_size

    def spec_begin(self, rid: int) -> bool:
        """Start speculating on a target slot: trim the eager full-budget
        page reservation down to the written frontier so verify rounds can
        grow/shrink page-exactly. Balanced by ``spec_release``."""
        slot = self.spec_slot(rid)
        if slot is None:
            return False
        self._spec_resize_pages(slot, int(self.positions[slot]))
        self.journal.append(("spec_begin", {"rid": rid}))
        return True

    def spec_release(self, rid: int) -> None:
        """Stop speculating: restore the slot's eager full-budget page
        reservation (remaining decode + the pending token's row) so the
        fused ``step()`` path can run it to completion without mid-decode
        page faults — its writes assume the admission-time reservation."""
        slot = self.spec_slot(rid)
        if slot is None:
            return
        st = self.slots[slot]
        total = min(int(self.positions[slot])
                    + max(0, st.max_new - len(st.generated)) + 1,
                    self.serving.max_seq)
        ok = self._spec_resize_pages(slot, total)
        # the verify loop only ever GREW past the frontier with pages it
        # returns before anyone else allocates, so the regrow cannot starve
        assert ok, f"spec_release could not restore rid {rid}'s reservation"
        self.journal.append(("spec_release", {"rid": rid}))

    def spec_draft(self, rid: int, k: int) -> Optional[np.ndarray]:
        """Propose ``k`` tokens for ``rid`` by running the batch-1 draft
        scan on a COPY of the slot's cache. The slot itself (rows,
        position, generated) is not advanced — ``spec_sync`` does that once
        the target reports what it committed."""
        slot = self.spec_slot(rid)
        if slot is None:
            return None
        st = self.slots[slot]
        p = int(self.positions[slot])
        k = min(int(k), self.serving.max_seq - 1 - p)
        if k <= 0:
            return None
        cache1 = self._spec_cache1(slot)
        teff = (self._context_bucket(p + k + 1) if self._ctx_buckets
                else self.serving.max_seq)
        if self._spec_draft_fn is None:
            self._spec_draft_fn = jax.jit(self._make_spec_draft(),
                                          donate_argnums=(1,),
                                          static_argnums=(5, 6))
        toks, key2 = self._spec_draft_fn(
            self.params, cache1, self._keys[slot], int(st.generated[-1]),
            p, teff, k)
        if self.temp > 0:
            self._keys = self._keys.at[slot].set(key2)
        self.drafted_tokens += k
        self.journal.append(("spec_draft", {"rid": rid, "k": k}))
        return np.asarray(toks)

    def spec_verify(self, rid: int, draft) -> Optional[Dict[str, Any]]:
        """Verify a draft block against this TARGET slot in one chunked
        forward. Feeds ``[pending, d_1..d_k]`` at positions ``p..p+k``,
        samples the target's own token at every position under the slot's
        key stream (one split per COMMITTED token — exactly what the fused
        path would have consumed, so a rejected draft never desyncs the
        stream), commits the agreeing prefix + the correction token, and
        rolls back everything past the first mismatch. Returns the round's
        bookkeeping, or None when the slot is gone / nothing can verify."""
        slot = self.spec_slot(rid)
        if slot is None:
            return None
        st = self.slots[slot]
        p = int(self.positions[slot])
        draft = [int(x) for x in np.asarray(draft).reshape(-1)]
        k = min(len(draft), self.serving.max_seq - 1 - p)
        if k <= 0:
            return None
        draft = draft[:k]
        s = k + 1
        while s > 1 and not self._spec_resize_pages(slot, p + s):
            k -= 1
            s -= 1
            draft = draft[:k]  # pool-starved: verify a shorter block
        if not self._spec_resize_pages(slot, p + s):
            return None
        cache1 = self._spec_cache1(slot)
        toks = np.asarray([int(st.generated[-1])] + draft, np.int32)
        poss = p + np.arange(s, dtype=np.int32)
        batch = {"tokens": jnp.asarray(toks[None]),
                 "positions": jnp.asarray(poss[None])}
        teff = (self._context_bucket(p + s + 1) if self._ctx_buckets
                else self.serving.max_seq)
        chunk = self._ensure_spec_chunk()
        logits_all, cache2 = chunk(self.params, cache1, batch, teff)
        logits_all = np.asarray(logits_all)[0]  # (s, V)
        cap = self.serving.max_seq
        key = self._keys[slot]
        commits: List[int] = []
        finished = False
        for i in range(s):
            if self.temp > 0:
                key, sub = jax.random.split(key, 2)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits_all[i]) / self.temp))
            else:
                nxt = int(np.argmax(logits_all[i]))
            commits.append(nxt)
            if (nxt == self.eos_id
                    or len(st.generated) + len(commits) >= st.max_new
                    or p + len(commits) + 1 >= cap):
                finished = True
                break
            if i < k and draft[i] != nxt:
                break  # first mismatch: the tail is dead
        m = len(commits)  # cache rows now valid: [pending] + accepted
        commit_end = p + m
        if self._sliceable:
            # rewind BEFORE the insert, mirroring the warm-admission pad
            # re-mask: rejected rows read as holes, the write index resumes
            # at the committed frontier
            cache2 = dict(cache2)
            cache2["pos"] = jnp.where(cache2["pos"] < commit_end,
                                      cache2["pos"], -1)
            cache2["index"] = jnp.full_like(cache2["index"],
                                            commit_end % cap)
            self._insert_cache(cache2, slot)
        elif m == s:
            self._insert_cache(cache2, slot)  # nothing to rewind
        else:
            # recurrent state is a point-in-time snapshot — it cannot
            # rewind. Re-feed ONLY the committed prefix from the slot's
            # untouched pre-verify rows (the donated cache1 was a copy).
            redo = self._spec_cache1(slot)
            rb = {"tokens": jnp.asarray(toks[None, :m]),
                  "positions": jnp.asarray(poss[None, :m])}
            _, redo = chunk(self.params, redo, rb, teff)
            self._insert_cache(redo, slot)
        if self.temp > 0:
            self._keys = self._keys.at[slot].set(jnp.asarray(key))
        self.positions[slot] = commit_end
        self._spec_resize_pages(slot, commit_end)  # decref rejected tail
        self.accepted_tokens += m - 1
        self.spec_rounds += 1
        self.last_heartbeat = time.monotonic()
        self.journal.append(("spec_verify", {"rid": rid, "drafted": k,
                                             "accepted": m - 1,
                                             "rolled_back": s - m}))
        # commit bookkeeping token-by-token with the step() stop rules —
        # committed tokens are real decode output (counters, streaming
        # hooks, finish), the rolled-back tail never counts toward
        # decode_tokens or max_new
        now = time.monotonic()
        for j, tok in enumerate(commits, start=1):
            if self.slots[slot] is not st:
                break  # a callback cancelled/finished the request
            st.generated.append(tok)
            self.decode_tokens += 1
            if self.on_token is not None:
                self.on_token(st.rid, tok, now)
            if (tok == self.eos_id or len(st.generated) >= st.max_new
                    or p + j + 1 >= cap):
                self._finish_slot(slot, now)
                break
        done = finished or self.slots[slot] is not st
        return {"committed": commits, "accepted": m - 1, "drafted": k,
                "rolled_back": s - m, "finished": done}

    def spec_sync(self, rid: int, committed: List[int]) -> bool:
        """Draft-side absorb of one verify round: replay the target's
        committed tokens into the draft cache in one chunk (the old pending
        token + all but the last commit), making the final commit the new
        pending token. The rejected tail was never installed here, so
        nothing rewinds. Returns False when the draft cache is out of room
        (caller stops speculating)."""
        slot = self.spec_slot(rid)
        if slot is None:
            return False
        st = self.slots[slot]
        committed = [int(t) % self.cfg.vocab_size for t in committed]
        m = len(committed)
        if m == 0:
            return True
        p = int(self.positions[slot])
        if p + m + 1 >= self.serving.max_seq:
            return False
        feed = np.asarray([int(st.generated[-1])] + committed[:-1], np.int32)
        cache1 = self._spec_cache1(slot)
        poss = p + np.arange(m, dtype=np.int32)
        batch = {"tokens": jnp.asarray(feed[None]),
                 "positions": jnp.asarray(poss[None])}
        teff = (self._context_bucket(p + m + 1) if self._ctx_buckets
                else self.serving.max_seq)
        if self._sliceable:
            _, cache1 = self._warm_chunk(self.params, cache1, batch, teff)
        elif self._warm_chunk_recurrent is not None:
            _, cache1 = self._warm_chunk_recurrent(self.params, cache1,
                                                   batch)
        else:
            _, cache1 = self._ensure_spec_chunk()(self.params, cache1,
                                                  batch, teff)
        self._insert_cache(cache1, slot)
        st.generated.extend(committed)
        self.positions[slot] = p + m
        self.journal.append(("spec_sync", {"rid": rid, "tokens": m}))
        return True

    # -- admission ----------------------------------------------------------

    def _admit(self) -> None:
        # page pressure is re-evaluated every admission pass: pages freed by
        # finished slots (or store eviction) clear it, a starved reservation
        # below re-raises it and the next fused block splits early
        self._page_pressure = False
        if any(j.get("deadline") is not None for j in self.waiting):
            # EDF admission: earliest deadline first, FIFO among ties /
            # deadline-free requests (stable sort keeps submit order)
            self.waiting.sort(key=lambda j: (
                j["deadline"] if j.get("deadline") is not None
                else float("inf"), j["t"]))
        # warm admissions first (EDF order within them): a prompt extending
        # a parked session or a stored prefix copies the cached rows and
        # prefills only its suffix; everything else falls through cold
        if self.waiting and (self.sessions.enabled
                             or self.prefix_store.enabled):
            self._admit_warm_hits()
        if self.fused_steps <= 1 or not self.serving.bucket_prefill:
            self._admit_legacy()
        else:
            self._admit_bucketed()

    def _admit_legacy(self) -> None:
        while self.waiting:
            slot = self._free_slot()
            if slot is None:
                return
            job = self.waiting[0]  # peek: only admitted once pages reserve
            if self.pool is not None:
                vis = self._prompt_prefix(job["extras"])
                total = min(vis + len(job["tokens"]) + int(job["max_new"]),
                            self.serving.max_seq)
                pages = self._reserve_pages(self._page_need(total))
                if pages is None:
                    self._page_pressure = True
                    return
                self._assign_pages(slot, pages)
            job = self.waiting.pop(0)
            toks = job["tokens"][None]  # (1, S)
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            for k, v in job["extras"].items():
                batch[k] = jnp.asarray(v)[None]
            logits, cache1 = self._prefill1(self.params, batch)
            self._insert_cache(cache1, slot)
            prefix = self._prompt_prefix(batch)
            self._start_seq(job, slot, toks.shape[1] + prefix,
                            np.asarray(logits)[0])

    def _admit_bucketed(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.waiting:
            return
        jobs = self.waiting[:len(free)]
        if self.pool is not None:
            # eager reservation: each job reserves its FULL page budget
            # (prompt + max_new, capped) up front, so an admitted request
            # can always run to completion — no mid-decode page faults, no
            # deadlock. Starved jobs stay queued; the fused block splits at
            # the pressure boundary and re-admits the moment pages free.
            admitted = []
            for job in jobs:
                vis = self._prompt_prefix(job["extras"])
                total = min(vis + len(job["tokens"]) + int(job["max_new"]),
                            self.serving.max_seq)
                pages = self._reserve_pages(self._page_need(total))
                if pages is None:
                    self._page_pressure = True
                    break
                job["_pages"] = pages
                admitted.append(job)
            jobs = admitted
            if not jobs:
                return
        del self.waiting[:len(jobs)]
        pad_ok = self.cfg.family in _PADDED_FAMILIES
        groups: Dict[tuple, List[dict]] = {}
        for j, job in enumerate(jobs):
            n = len(job["tokens"])
            prefix = self._prompt_prefix(job["extras"])
            if pad_ok:
                bucket = min(_next_bucket(n),
                             max(n, self.serving.max_seq - prefix))
            else:
                bucket = n  # exact-length groups (ssm / hybrid)
            sig = (bucket, tuple(sorted(
                (k, np.asarray(v).shape, str(np.asarray(v).dtype))
                for k, v in job["extras"].items())))
            if self.cfg.family == "moe":
                sig += (j,)  # one group per request (see _PADDED_FAMILIES)
            groups.setdefault(sig, []).append(job)
        for sig, grp in groups.items():
            slots = [free.pop(0) for _ in grp]
            self._admit_group(grp, slots, sig[0])

    def _admit_group(self, jobs: List[dict], slots: List[int],
                     bucket: int) -> None:
        r = len(jobs)
        # pad the row count to a power of two as well (bounds retraces to
        # O(log buckets * log max_batch)); pad rows replicate row 0 and
        # scatter to the same slot, so the duplicate writes are identical
        rp = _next_bucket(r, lo=1)
        toks = np.zeros((rp, bucket), np.int32)
        lengths = np.zeros((rp,), np.int32)
        for i in range(rp):
            job = jobs[i] if i < r else jobs[0]
            n = len(job["tokens"])
            toks[i, :n] = job["tokens"]
            lengths[i] = n
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        for k in jobs[0]["extras"]:
            batch[k] = jnp.asarray(np.stack(
                [np.asarray((jobs[i] if i < r else jobs[0])["extras"][k])
                 for i in range(rp)]))
        if int(lengths.min()) < bucket:  # any row right-padded
            batch["lengths"] = jnp.asarray(lengths)
        prefix = self._prompt_prefix(jobs[0]["extras"])
        total = lengths + prefix
        for job, slot in zip(jobs, slots):
            pages = job.pop("_pages", None)
            if pages is not None:
                self._assign_pages(slot, pages)
        slots_arr = np.asarray(slots + [slots[0]] * (rp - r), np.int32)
        if self._pt is not None:
            # pad rows replicate row 0's page table too — their duplicate
            # scatters write identical content to the same pages
            pt_rows = self._pt[slots_arr]
            npg = max(1, max(len(self._slot_pages[s]) for s in slots))
            logits, self.cache = self._prefill_insert(
                self.params, self.cache, batch, jnp.asarray(slots_arr),
                jnp.asarray(total), jnp.asarray(pt_rows), npg)
        else:
            logits, self.cache = self._prefill_insert(
                self.params, self.cache, batch, jnp.asarray(slots_arr),
                jnp.asarray(total))
        logits = np.asarray(logits)  # one host sync per admitted group
        for i, (job, slot) in enumerate(zip(jobs, slots)):
            self._start_seq(job, slot, int(total[i]), logits[i])

    # -- sampling / stepping ------------------------------------------------

    def _sample(self, logits: np.ndarray) -> int:
        if self.temp <= 0:
            return int(np.argmax(logits))
        z = logits / self.temp
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _throttle_sleep(self, t_in: float) -> None:
        if self.throttle > 1.0:
            # the sleep is capped per step: an outlier step duration is a
            # compile or a host deschedule, not model compute — stretching
            # it (throttle - 1)x would amplify a one-off stall into a
            # multi-second outage of the emulated-slow node
            time.sleep(min((self.throttle - 1.0)
                           * max(0.0, time.monotonic() - t_in),
                           self.throttle_sleep_cap_s))

    def step(self) -> int:
        """Admit + one decode block for all active slots. Returns #active."""
        t_in = time.monotonic()
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        if self.fused_steps <= 1:
            n = self._step_legacy(active)
            self._throttle_sleep(t_in)
            return n
        b = len(self.slots)
        tokens = np.zeros((b,), np.int32)
        positions = np.zeros((b,), np.int32)
        budgets = np.zeros((b,), np.int32)
        for i in active:
            st = self.slots[i]
            tokens[i] = st.generated[-1]
            positions[i] = self.positions[i]
            budgets[i] = max(0, st.max_new - len(st.generated))
        k = self.fused_steps
        if self.pool is not None and self._page_pressure and self.waiting:
            # continuous admission under page pressure: split the fused
            # block at the earliest point a slot can finish (and free its
            # pages), so starved requests admit mid-block instead of
            # waiting out a full K steps. k is a jit-static arg — each
            # distinct split length is one cached trace on the power-of-two
            # budget ladder of remaining tokens
            rem = min(max(1, self.slots[i].max_new
                          - len(self.slots[i].generated)) for i in active)
            k = max(1, min(k, rem))
        teff = self.serving.max_seq
        if self._ctx_buckets:
            # smallest bucket covering every position the block can write;
            # ladder = {2^n, 1.5*2^n} so the attended width tracks the live
            # context within ~33% (each bucket is one cached trace)
            teff = self._context_bucket(int(positions.max()) + k + 1)
        block, self.cache, self._keys = self._fused(
            self.params, self.cache, self._keys, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(budgets), teff, k)
        block = np.asarray(block)  # the ONLY host sync: (B, K) per K tokens
        now = time.monotonic()
        for i in active:
            st = self.slots[i]
            if st is None:
                continue  # cancelled mid-block by an on_token callback
            for j in range(k):
                nxt = int(block[i, j])
                st.generated.append(nxt)
                self.decode_tokens += 1
                self.positions[i] += 1
                if self.on_token is not None:
                    self.on_token(st.rid, nxt, now)
                hit_cap = self.positions[i] + 1 >= self.serving.max_seq
                if (nxt == self.eos_id or len(st.generated) >= st.max_new
                        or hit_cap):
                    self._finish_slot(i, now)
                    break
        self.steps += 1
        self.last_heartbeat = now
        self._throttle_sleep(t_in)
        return len(active)

    def _step_legacy(self, active: List[int]) -> int:
        """Per-token path: one dispatch + one logits transfer per token."""
        b = len(self.slots)
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
            positions[i] = self.positions[i]
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens),
             "positions": jnp.asarray(positions)})
        logits = np.asarray(logits)
        now = time.monotonic()
        for i in active:
            st = self.slots[i]
            if st is None:
                continue  # cancelled mid-step
            self.positions[i] += 1
            nxt = self._sample(logits[i])
            st.generated.append(nxt)
            self.decode_tokens += 1
            if self.on_token is not None:
                self.on_token(st.rid, nxt, now)
            hit_cap = self.positions[i] + 1 >= self.serving.max_seq
            if (nxt == self.eos_id or len(st.generated) >= st.max_new
                    or hit_cap):
                self._finish_slot(i, now)
        self.steps += 1
        self.last_heartbeat = now
        return len(active)

    def run_until_drained(self, max_steps: int = 100_000) -> List[SeqState]:
        while (self.waiting or any(s is not None for s in self.slots)):
            if self.steps >= max_steps:
                break
            self.step()
        return self.finished

    # -- fault tolerance ----------------------------------------------------

    def heartbeat_ok(self) -> bool:
        dt = time.monotonic() - self.last_heartbeat
        self.healthy = dt <= self.serving.heartbeat_timeout_s or self.steps == 0
        return self.healthy

    @staticmethod
    def _copy_seq(s: Optional[SeqState]) -> Optional[SeqState]:
        # generated must be deep-copied: a shallow replace() would alias the
        # live list, letting post-snapshot tokens leak into the snapshot
        return (dataclasses.replace(s, generated=list(s.generated))
                if s else None)

    def snapshot(self) -> dict:
        out = {
            "cache": jax.tree.map(np.asarray, self.cache),
            "slots": [self._copy_seq(s) for s in self.slots],
            "positions": self.positions.copy(),
            "waiting": list(self.waiting),
            "steps": self.steps,
            "keys": np.asarray(self._keys),
            "slot_prompt": [None if p is None else p.copy()
                            for p in self._slot_prompt],
            "slot_fp": list(self._slot_extras_fp),
        }
        if self.pool is not None:
            out["paged"] = {
                "pt": None if self._pt is None else self._pt.copy(),
                "slot_pages": [list(p) for p in self._slot_pages],
                "high_water": self.pool.high_water,
            }
        return out

    def restore(self, snap: dict) -> None:
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])
        self.slots = [self._copy_seq(s) for s in snap["slots"]]
        self.positions = snap["positions"].copy()
        self.waiting = list(snap["waiting"])
        self.steps = snap["steps"]
        if "keys" in snap:
            self._keys = jnp.asarray(snap["keys"])
        b = len(self.slots)
        self._slot_prompt = [None if p is None else p.copy()
                             for p in snap.get("slot_prompt",
                                               [None] * b)]
        self._slot_extras_fp = list(snap.get("slot_fp", [b""] * b))
        if self.pool is not None:
            # prefix-store entries hold page refs into the PRE-failure pool;
            # drain them first (decrefs fire against the old pool), then
            # derive a fresh allocator from the snapshot's ownership lists
            while self.prefix_store.evict_oldest() is not None:
                pass
            pv = snap.get("paged") or {}
            self._slot_pages = [list(p) for p in
                                pv.get("slot_pages",
                                       [[] for _ in self.slots])]
            old = self.pool
            self.pool = PagePool(self.serving.pool_pages,
                                 self.serving.kv_page_size,
                                 page_bytes=old.page_bytes)
            self.pool.reown([p for sp in self._slot_pages for p in sp])
            self.pool.high_water = max(self.pool.high_water,
                                       int(pv.get("high_water", 0)))
            if self.prefix_store.lru.on_evict is None:
                self.prefix_store.lru.on_evict = self._on_prefix_evict
            if self._pt is not None:
                self._pt = (np.zeros_like(self._pt) if pv.get("pt") is None
                            else np.asarray(pv["pt"], np.int32).copy())
        self.healthy = True
        self.last_heartbeat = time.monotonic()
