"""Live continuous-batching serving engine (runs real models).

One engine per tier. Fixed-slot design: ``max_batch`` decode slots share a
static-shaped KV cache (per-slot write indices — see models/*); finished
slots are freed and immediately refilled (continuous batching).

The hot path is device-resident:

* **Fused decode** — one jitted ``lax.scan`` runs ``fused_steps`` decode
  steps per host call: on-device greedy/temperature sampling from a per-slot
  ``jax.random`` key array, per-slot alive flags masking EOS / token-budget /
  capacity, and in-place KV writes (the cache and key buffers are DONATED,
  so XLA updates them in place instead of copying the whole cache every
  step). The host receives a single ``(B, fused_steps)`` token block — one
  transfer and one Python bookkeeping pass per K tokens instead of per
  token.
* **Bucketed prefill** — waiting prompts are right-padded to power-of-two
  length buckets and all same-bucket requests prefill in ONE batched call;
  a jitted ``at[...].set`` scatter (donated pool) inserts every admitted
  row into its slot, replacing the per-request retrace and the full-tree
  cache copy. Families whose cache embeds recurrent/ring state (ssm,
  hybrid) or non-neutral pad tokens (moe) batch same-length groups instead
  of padded buckets.

``ServingConfig(fused_steps=1)`` keeps the legacy per-token path (host-side
numpy sampling, one dispatch + ``np.asarray(logits)`` sync per token, one
retraced prefill per request) for parity testing and as the perf baseline.
Token-for-token parity is guaranteed for every family with the default MoE
impl; a capacity-dropping MoE impl is batch-content-coupled by construction
(which tokens drop depends on the other rows in flight), so under it any
two scheduling policies — including legacy vs fused — may diverge.

Fault tolerance: every mutation of engine state is journaled; ``snapshot()``/
``restore()`` allow a failed tier to be rebuilt on a standby (exercised in
tests), and a watchdog marks the engine unhealthy if a step exceeds the
heartbeat timeout.

Cross-tier KV migration: ``extract_slot(rid)`` serializes ONE request's
cache rows (the same axis-aware leaf walk the prefill scatter uses), its
``SeqState`` and its sampling key into a :class:`SlotPayload` with a
versioned, dtype/shape-tagged wire format; ``inject_slot(payload)`` resumes
it in a free slot of a compatible engine without re-prefilling.
"""
from __future__ import annotations

import copy
import dataclasses
import json
import struct
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ServingConfig

# Families whose decode cache is a full-capacity absolute-position buffer:
# right-padded bucket prefill is exact for them (pad entries are masked via
# the ``pos`` leaf; causal attention keeps real-token activations identical).
# ssm/hybrid carry recurrent/ring state that pads would shift — they batch
# exact same-length groups instead. moe prefills per request: a capacity-
# dropping MoE impl routes over the flattened batch, so ANY cross-request
# batching (padded or not) would couple which tokens drop across requests.
_PADDED_FAMILIES = ("dense", "vlm", "encdec")


def _next_bucket(n: int, lo: int = 16) -> int:
    """Smallest power-of-two >= n (floored at ``lo`` to bound trace count)."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclass
class SeqState:
    rid: int
    prompt_len: int
    generated: List[int] = field(default_factory=list)
    max_new: int = 32
    done: bool = False
    t_submit: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


# ---------------------------------------------------------------------------
# cross-tier KV migration: per-slot cache wire format
# ---------------------------------------------------------------------------

#: bump when the on-wire layout changes; injectors reject other versions
MIGRATION_WIRE_VERSION = 1
_WIRE_MAGIC = b"MOAKV"


class MigrationError(RuntimeError):
    """A slot payload cannot be extracted or injected: unknown wire version,
    wrong model/family, mismatched cache geometry, or no free slot. Raised
    BEFORE any engine state is mutated, so a failed injection leaves the
    target engine untouched (callers fall back to a fresh prefill)."""


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype tag, including the ml_dtypes extras jax uses. An
    unknown tag (corrupt header, sender with newer dtypes) raises
    MigrationError so callers keep their re-prefill fallback."""
    try:
        return np.dtype(name)
    except TypeError:
        try:
            import ml_dtypes  # ships with jax

            return np.dtype(getattr(ml_dtypes, name))
        except (AttributeError, TypeError) as e:
            raise MigrationError(
                f"unknown dtype tag {name!r} in slot payload") from e


@dataclass
class SlotPayload:
    """One request's migratable state: its per-slot cache rows (every leaf
    sliced along that leaf's logical batch axis — dense/vlm/moe KV, ssm
    conv+state, hybrid ring/rglru leaves), its ``SeqState``, the absolute
    next position, and the per-slot sampling key. ``to_bytes``/``from_bytes``
    are the versioned, dtype/shape-tagged wire format the live backend
    actually ships across tiers."""

    version: int
    model: str
    family: str
    max_seq: int
    seq: SeqState
    position: int
    key: np.ndarray  # per-slot jax.random key data
    leaves: Dict[str, np.ndarray]  # keystr(cache path) -> per-slot row
    _wire: Optional[bytes] = field(default=None, repr=False, compare=False)

    @property
    def nbytes(self) -> int:
        """Exact on-wire size (serialized lazily, cached)."""
        return len(self.to_bytes())

    def to_bytes(self) -> bytes:
        if self._wire is not None:
            return self._wire
        seq = self.seq
        names = sorted(self.leaves)
        head = {
            "version": self.version,
            "model": self.model,
            "family": self.family,
            "max_seq": self.max_seq,
            "position": self.position,
            "seq": {
                "rid": seq.rid, "prompt_len": seq.prompt_len,
                "generated": list(seq.generated), "max_new": seq.max_new,
                "done": seq.done, "t_submit": seq.t_submit,
                "t_first_token": seq.t_first_token, "t_done": seq.t_done,
            },
            "key": {"dtype": str(self.key.dtype),
                    "shape": list(self.key.shape)},
            "leaves": [{"name": n, "dtype": str(self.leaves[n].dtype),
                        "shape": list(self.leaves[n].shape)} for n in names],
        }
        blob = json.dumps(head).encode("utf-8")
        parts = [_WIRE_MAGIC, struct.pack("<HI", self.version, len(blob)),
                 blob, np.ascontiguousarray(self.key).tobytes()]
        parts += [np.ascontiguousarray(self.leaves[n]).tobytes()
                  for n in names]
        self._wire = b"".join(parts)
        return self._wire

    @classmethod
    def from_bytes(cls, wire: bytes) -> "SlotPayload":
        m = len(_WIRE_MAGIC)
        if wire[:m] != _WIRE_MAGIC:
            raise MigrationError("not a slot payload (bad magic)")
        if len(wire) < m + struct.calcsize("<HI"):
            raise MigrationError("truncated slot payload")
        version, hlen = struct.unpack_from("<HI", wire, m)
        if version != MIGRATION_WIRE_VERSION:
            raise MigrationError(
                f"wire format version {version} != supported "
                f"{MIGRATION_WIRE_VERSION}")
        off = m + struct.calcsize("<HI")

        def pull(dtype_s: str, shape) -> np.ndarray:
            nonlocal off
            dt = _np_dtype(dtype_s)
            if any(int(d) < 0 for d in shape):
                raise MigrationError(f"corrupt leaf shape {shape}")
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            end = off + n * dt.itemsize
            if end > len(wire):
                raise MigrationError("truncated slot payload")
            arr = np.frombuffer(wire[off:end], dtype=dt).reshape(shape).copy()
            off = end
            return arr

        # any malformation beyond this point (bad json, missing header
        # fields, bogus shapes) is a corrupt wire, never a crash: callers
        # rely on MigrationError to fall back to a fresh prefill
        try:
            head = json.loads(wire[off:off + hlen].decode("utf-8"))
            off += hlen
            key = pull(head["key"]["dtype"], head["key"]["shape"])
            leaves = {d["name"]: pull(d["dtype"], d["shape"])
                      for d in head["leaves"]}
            s = head["seq"]
            seq = SeqState(rid=s["rid"], prompt_len=s["prompt_len"],
                           generated=list(s["generated"]),
                           max_new=s["max_new"], done=s["done"],
                           t_submit=s["t_submit"],
                           t_first_token=s["t_first_token"],
                           t_done=s["t_done"])
            return cls(version=version, model=head["model"],
                       family=head["family"], max_seq=head["max_seq"],
                       seq=seq, position=head["position"], key=key,
                       leaves=leaves, _wire=bytes(wire))
        except MigrationError:
            raise
        except (KeyError, ValueError, TypeError, OverflowError) as e:
            raise MigrationError(f"corrupt slot payload: {e!r}") from e


class TierEngine:
    def __init__(self, model, params, serving: ServingConfig = ServingConfig(),
                 eos_id: int = 2, sample_temp: float = 0.0, seed: int = 0):
        # shallow copy: the engine sets per-engine decode flags below and
        # must not leak them into a model object shared with other engines
        model = copy.copy(model)
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.serving = serving
        self.eos_id = eos_id
        self.temp = sample_temp
        self.rng = np.random.default_rng(seed)
        self.fused_steps = max(1, serving.fused_steps)
        if serving.decode_impl != "auto" and hasattr(model, "decode_impl"):
            model.decode_impl = serving.decode_impl
        if (self.fused_steps > 1 and serving.unroll_decode_layers
                and hasattr(model, "decode_unroll")):
            model.decode_unroll = True

        b, t = serving.max_batch, serving.max_seq
        self.cache = model.init_cache(b, t)
        self.slots: List[Optional[SeqState]] = [None] * b
        self.positions = np.zeros((b,), np.int64)  # absolute next position
        self.waiting: List[Dict[str, Any]] = []
        self.finished: List[SeqState] = []
        self.journal: List[tuple] = []  # (op, payload) event journal
        self.healthy = True
        self.last_heartbeat = time.monotonic()
        self.steps = 0
        # perf counters (read by benchmarks/serving_bench.py and launch/serve)
        self.decode_tokens = 0
        self.prefill_tokens = 0
        self.encode_tokens = 0  # encode-only entry point (partial offload)
        # cluster-runtime hooks: admission + per-token streaming callbacks
        # (rid, t) and (rid, token, t); None = standalone engine
        self.on_admit: Optional[Callable[[int, float], None]] = None
        self.on_token: Optional[Callable[[int, int, float], None]] = None
        self._encode_jits: Dict[tuple, Any] = {}

        self._decode = jax.jit(model.decode_step)
        self._prefill1 = jax.jit(lambda p, batch: model.prefill(p, batch, t))
        # per-slot sampling keys live on device across the engine's lifetime
        self._keys = jax.random.split(jax.random.PRNGKey(seed), b)
        # batch axis per cache leaf (hybrid rglru leaves carry batch at
        # axis 2 — the generic scatter below follows the logical axes)
        axes = model.cache_axes(b, t)
        self._cache_batch_axis = jax.tree.map(
            lambda a: a.index("batch"), axes,
            is_leaf=lambda x: isinstance(x, tuple))
        # context buckets need linear cache placement (pos == write index),
        # which holds for the full-capacity-"pos" families only — ring
        # windows (hybrid), recurrent state (ssm) and the encdec cross
        # cache keep full-width attention
        self._ctx_buckets = (serving.context_buckets
                             and self.cfg.family in ("dense", "vlm", "moe"))
        self._fused = jax.jit(self._make_fused(), donate_argnums=(1, 2),
                              static_argnums=(6,))
        self._prefill_insert = jax.jit(self._make_prefill_insert(),
                                       donate_argnums=(1,))

    # ------------------------------------------------------------------
    # jitted hot-path builders
    # ------------------------------------------------------------------

    def _make_fused(self):
        """K decode steps in one lax.scan: decode -> on-device sample ->
        per-slot EOS/budget/cap masking. Cache + keys are donated by the
        caller so the KV buffers update in place.

        ``teff`` (static) is the context bucket: attention reads only the
        leading ``teff`` cache entries (a static slice XLA fuses into the
        attention loads — writes and bookkeeping stay full-width, so the
        cache layout never changes). Valid because placement is linear for
        the bucket-eligible families, every admit rewrites a slot's full
        row (entries past a prompt carry pos=-1), and the engine picks
        ``teff`` above the longest position reached inside the block.
        """
        model, K = self.model, self.fused_steps
        temp, eos = float(self.temp), int(self.eos_id)
        max_seq = int(self.serving.max_seq)

        def fused(params, cache, keys, tokens, positions, budgets, teff):
            ctx = teff if teff < max_seq else None

            def body(carry, _):
                cache, keys, tok, pos, alive, produced = carry
                logits, cache2 = model.decode_step(
                    params, cache, {"tokens": tok[:, None], "positions": pos},
                    ctx=ctx)
                if temp > 0:
                    split = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
                    keys2, sub = split[:, 0], split[:, 1]
                    sampled = jax.vmap(jax.random.categorical)(
                        sub, logits / temp)
                else:
                    keys2 = keys
                    sampled = jnp.argmax(logits, axis=-1)
                sampled = sampled.astype(jnp.int32)
                produced = produced + alive.astype(jnp.int32)
                pos2 = jnp.where(alive, pos + 1, pos)
                # same stop rules the host applies per appended token
                alive2 = (alive & (sampled != eos) & (produced < budgets)
                          & (pos2 + 1 < max_seq))
                tok2 = jnp.where(alive, sampled, tok)
                if "pos" in cache2:
                    # freeze write bookkeeping of dead slots: their KV writes
                    # land on a slot whose pos stays -1 (masked), instead of
                    # marching the ring index over live-looking entries
                    cache2 = dict(cache2)
                    cache2["pos"] = jnp.where(alive[:, None], cache2["pos"],
                                              cache["pos"])
                    cache2["index"] = jnp.where(alive, cache2["index"],
                                                cache["index"])
                return (cache2, keys2, tok2, pos2, alive2, produced), tok2

            alive0 = budgets > 0
            produced0 = jnp.zeros_like(budgets)
            (cache, keys, *_), toks = jax.lax.scan(
                body, (cache, keys, tokens, positions, alive0, produced0),
                None, length=K)
            return jnp.transpose(toks), cache, keys  # (B, K)

        return fused

    def _make_prefill_insert(self):
        """Batched prefill + donated multi-row scatter into the slot pool.

        ``slots`` (R,) are the destination slot ids (duplicates allowed only
        for padded rows carrying identical values); ``total`` (R,) is each
        row's true sequence length INCLUDING any vision prefix.
        """
        model = self.model
        capacity = self.serving.max_seq
        pad_ok = self.cfg.family in _PADDED_FAMILIES

        def fn(params, pool, batch, slots, total):
            logits, cache1 = model.prefill(params, batch, capacity)
            if pad_ok and "pos" in cache1:
                cache1 = dict(cache1)
                cap = cache1["pos"].shape[1]
                # pad entries carry absolute positions >= the row's true
                # length -> mark empty; next write lands at the true end
                cache1["pos"] = jnp.where(cache1["pos"] < total[:, None],
                                          cache1["pos"], -1)
                cache1["index"] = (jnp.minimum(total, cap) % cap).astype(
                    jnp.int32)

            def ins(pool_leaf, one, bax):
                idx = (slice(None),) * bax + (slots,)
                return pool_leaf.at[idx].set(one.astype(pool_leaf.dtype))

            pool = jax.tree.map(ins, pool, cache1, self._cache_batch_axis)
            return logits, pool

        return fn

    # ------------------------------------------------------------------

    def submit(self, rid: int, tokens: np.ndarray, max_new: int = 32,
               extras: Optional[Dict[str, np.ndarray]] = None,
               deadline: Optional[float] = None) -> None:
        """Queue a prompt. ``deadline`` (monotonic seconds) enables
        EDF-ordered admission: the waiting queue is drained
        earliest-deadline-first instead of FIFO."""
        self.journal.append(("submit", {"rid": rid, "tokens": tokens,
                                        "max_new": max_new,
                                        "extras": extras,
                                        "deadline": deadline}))
        self.waiting.append({"rid": rid, "tokens": np.asarray(tokens),
                             "max_new": max_new, "extras": extras or {},
                             "deadline": deadline, "t": time.monotonic()})

    def cancel(self, rid: int) -> bool:
        """Abort a request wherever it is (waiting or mid-decode). The
        cluster runtime uses this to retire the losing hedge twin; the freed
        slot is refilled at the next admission."""
        for i, j in enumerate(self.waiting):
            if j["rid"] == rid:
                del self.waiting[i]
                self.journal.append(("cancel", {"rid": rid}))
                return True
        for i, s in enumerate(self.slots):
            if s is not None and s.rid == rid:
                self.slots[i] = None  # KV rows are overwritten on next admit
                self.journal.append(("cancel", {"rid": rid}))
                return True
        return False

    # -- cross-tier KV migration -------------------------------------------

    def _leaf_rows(self):
        """Yield ``(name, leaf, batch_axis)`` per cache leaf — the same
        axis-aware walk the prefill scatter uses (``cache_axes``-driven, so
        hybrid rglru leaves with batch at axis 2 come out right)."""
        flat = jax.tree_util.tree_leaves_with_path(self.cache)
        axes = jax.tree.leaves(self._cache_batch_axis)
        for (path, leaf), bax in zip(flat, axes):
            yield jax.tree_util.keystr(path), leaf, bax

    def extract_slot(self, rid: int, *, remove: bool = False) -> SlotPayload:
        """Serialize one request's migratable state (see ``SlotPayload``).
        ``remove=True`` frees the slot (preemption / re-homing); the default
        keeps the donor decoding (hedged clone races the original)."""
        slot = next((i for i, s in enumerate(self.slots)
                     if s is not None and s.rid == rid), None)
        if slot is None:
            raise MigrationError(
                f"rid {rid} holds no decode slot on this engine")
        leaves = {name: np.asarray(jnp.take(leaf, slot, axis=bax))
                  for name, leaf, bax in self._leaf_rows()}
        payload = SlotPayload(
            version=MIGRATION_WIRE_VERSION, model=self.cfg.name,
            family=self.cfg.family, max_seq=self.serving.max_seq,
            seq=self._copy_seq(self.slots[slot]),
            position=int(self.positions[slot]),
            key=np.asarray(self._keys[slot]), leaves=leaves)
        if remove:
            self.slots[slot] = None  # KV rows overwritten on the next admit
        self.journal.append(("extract", {"rid": rid, "removed": remove}))
        return payload

    def inject_slot(self, payload: SlotPayload) -> int:
        """Install a migrated request into a free slot and resume its decode
        exactly where the donor left off (no prefill — ``prefill_tokens``
        does not move). Validates the wire version, model spec and every
        leaf's shape/dtype BEFORE touching the cache; any mismatch raises
        :class:`MigrationError` and leaves this engine unchanged."""
        if payload.version != MIGRATION_WIRE_VERSION:
            raise MigrationError(
                f"wire format version {payload.version} != supported "
                f"{MIGRATION_WIRE_VERSION}")
        if payload.model != self.cfg.name or payload.family != self.cfg.family:
            raise MigrationError(
                f"payload from {payload.model!r} ({payload.family}) cannot be "
                f"injected into {self.cfg.name!r} ({self.cfg.family}): KV "
                f"caches are model-specific")
        if any(s is not None and s.rid == payload.seq.rid for s in self.slots):
            raise MigrationError(
                f"rid {payload.seq.rid} already occupies a slot here")
        slot = self._free_slot()
        if slot is None:
            raise MigrationError("no free decode slot to inject into")
        rows = dict(payload.leaves)
        expect = {name: (leaf, bax) for name, leaf, bax in self._leaf_rows()}
        if set(expect) != set(rows):
            raise MigrationError(
                f"cache leaf mismatch: payload has {sorted(rows)}, engine "
                f"expects {sorted(expect)}")
        for name, (leaf, bax) in expect.items():
            want = leaf.shape[:bax] + leaf.shape[bax + 1:]
            row = rows[name]
            if tuple(row.shape) != tuple(want):
                raise MigrationError(
                    f"leaf {name}: payload row shape {tuple(row.shape)} != "
                    f"engine row shape {tuple(want)} (max_seq "
                    f"{payload.max_seq} vs {self.serving.max_seq}?)")
            if str(row.dtype) != str(leaf.dtype):
                raise MigrationError(
                    f"leaf {name}: payload dtype {row.dtype} != engine "
                    f"dtype {leaf.dtype}")

        def put(path, leaf, bax):
            row = rows[jax.tree_util.keystr(path)]
            idx = (slice(None),) * bax + (slot,)
            return leaf.at[idx].set(jnp.asarray(row))

        self.cache = jax.tree_util.tree_map_with_path(
            put, self.cache, self._cache_batch_axis)
        self.slots[slot] = self._copy_seq(payload.seq)
        self.positions[slot] = payload.position
        self._keys = self._keys.at[slot].set(jnp.asarray(payload.key))
        self.journal.append(("inject", {"rid": payload.seq.rid, "slot": slot}))
        return slot

    def encode_image(self, image: np.ndarray, num_patches: int = 0,
                     frontend_dim: int = 0) -> np.ndarray:
        """Encode-only entry point (executed partial offload): run the
        vision frontend on THIS tier's device and return compact patch
        embeddings in the target ``(num_patches, frontend_dim)`` geometry
        (defaults to this engine's own model).

        The stub frontend tiles the normalized pixels into the patch grid —
        bit-identical to what a fusion-local prefill would compute, so
        routing an image off the fusion tier never changes the generated
        tokens; only the compact embeddings travel."""
        img = np.asarray(image)
        p = num_patches or self.cfg.num_patches
        fd = frontend_dim or self.cfg.frontend_dim
        key = (p, fd, int(img.size))
        fn = self._encode_jits.get(key)
        if fn is None:
            need = p * fd
            rep = max(1, int(np.ceil(need / max(img.size, 1))))

            def _enc(x):
                flat = x.reshape(-1).astype(jnp.float32) / 255.0
                return jnp.tile(flat, rep)[:need].reshape(p, fd)

            fn = jax.jit(_enc)
            self._encode_jits[key] = fn
        out = np.asarray(fn(jnp.asarray(img)))
        self.encode_tokens += p
        self.journal.append(("encode", {"patches": p}))
        return out

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _insert_cache(self, cache1, slot: int) -> None:
        """Legacy path: copy a batch-1 prefill cache into slot ``slot``."""
        def ins(pool, one, bax):
            idx = (slice(None),) * bax + (slot,)
            sel = (slice(None),) * bax + (0,)
            return pool.at[idx].set(one[sel].astype(pool.dtype))
        self.cache = jax.tree.map(ins, self.cache, cache1,
                                  self._cache_batch_axis)

    def _start_seq(self, job: Dict[str, Any], slot: int, prompt_len: int,
                   first_logits: np.ndarray) -> None:
        """Shared admit bookkeeping: first token, done-check, journal."""
        st = SeqState(rid=job["rid"], prompt_len=prompt_len,
                      max_new=job["max_new"], t_submit=job["t"])
        first = self._sample(first_logits)
        st.generated.append(int(first))
        st.t_first_token = time.monotonic()
        self.slots[slot] = st
        self.positions[slot] = prompt_len
        self.prefill_tokens += prompt_len
        self.decode_tokens += 1
        self.journal.append(("admit", {"rid": st.rid, "slot": slot}))
        if self.on_admit is not None:
            self.on_admit(st.rid, st.t_first_token)
        if self.on_token is not None:
            self.on_token(st.rid, int(first), st.t_first_token)
        # a request may be complete straight out of prefill (EOS first
        # token, max_new == 1, or a prompt already at capacity)
        if (first == self.eos_id or len(st.generated) >= st.max_new
                or prompt_len + 1 >= self.serving.max_seq):
            self._finish_slot(slot, time.monotonic())

    def _finish_slot(self, slot: int, now: float) -> None:
        st = self.slots[slot]
        st.done = True
        st.t_done = now
        self.finished.append(st)
        self.journal.append(("finish", {"rid": st.rid}))
        self.slots[slot] = None

    def _prompt_prefix(self, extras: Dict[str, Any]) -> int:
        if self.cfg.frontend == "vision_stub" and "patches" in extras:
            return self.cfg.num_patches
        return 0

    # -- admission ----------------------------------------------------------

    def _admit(self) -> None:
        if any(j.get("deadline") is not None for j in self.waiting):
            # EDF admission: earliest deadline first, FIFO among ties /
            # deadline-free requests (stable sort keeps submit order)
            self.waiting.sort(key=lambda j: (
                j["deadline"] if j.get("deadline") is not None
                else float("inf"), j["t"]))
        if self.fused_steps <= 1 or not self.serving.bucket_prefill:
            self._admit_legacy()
        else:
            self._admit_bucketed()

    def _admit_legacy(self) -> None:
        while self.waiting:
            slot = self._free_slot()
            if slot is None:
                return
            job = self.waiting.pop(0)
            toks = job["tokens"][None]  # (1, S)
            batch = {"tokens": jnp.asarray(toks, jnp.int32)}
            for k, v in job["extras"].items():
                batch[k] = jnp.asarray(v)[None]
            logits, cache1 = self._prefill1(self.params, batch)
            self._insert_cache(cache1, slot)
            prefix = self._prompt_prefix(batch)
            self._start_seq(job, slot, toks.shape[1] + prefix,
                            np.asarray(logits)[0])

    def _admit_bucketed(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free or not self.waiting:
            return
        jobs = self.waiting[:len(free)]
        del self.waiting[:len(jobs)]
        pad_ok = self.cfg.family in _PADDED_FAMILIES
        groups: Dict[tuple, List[dict]] = {}
        for j, job in enumerate(jobs):
            n = len(job["tokens"])
            prefix = self._prompt_prefix(job["extras"])
            if pad_ok:
                bucket = min(_next_bucket(n),
                             max(n, self.serving.max_seq - prefix))
            else:
                bucket = n  # exact-length groups (ssm / hybrid)
            sig = (bucket, tuple(sorted(
                (k, np.asarray(v).shape, str(np.asarray(v).dtype))
                for k, v in job["extras"].items())))
            if self.cfg.family == "moe":
                sig += (j,)  # one group per request (see _PADDED_FAMILIES)
            groups.setdefault(sig, []).append(job)
        for sig, grp in groups.items():
            slots = [free.pop(0) for _ in grp]
            self._admit_group(grp, slots, sig[0])

    def _admit_group(self, jobs: List[dict], slots: List[int],
                     bucket: int) -> None:
        r = len(jobs)
        # pad the row count to a power of two as well (bounds retraces to
        # O(log buckets * log max_batch)); pad rows replicate row 0 and
        # scatter to the same slot, so the duplicate writes are identical
        rp = _next_bucket(r, lo=1)
        toks = np.zeros((rp, bucket), np.int32)
        lengths = np.zeros((rp,), np.int32)
        for i in range(rp):
            job = jobs[i] if i < r else jobs[0]
            n = len(job["tokens"])
            toks[i, :n] = job["tokens"]
            lengths[i] = n
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        for k in jobs[0]["extras"]:
            batch[k] = jnp.asarray(np.stack(
                [np.asarray((jobs[i] if i < r else jobs[0])["extras"][k])
                 for i in range(rp)]))
        if int(lengths.min()) < bucket:  # any row right-padded
            batch["lengths"] = jnp.asarray(lengths)
        prefix = self._prompt_prefix(jobs[0]["extras"])
        total = lengths + prefix
        slots_arr = np.asarray(slots + [slots[0]] * (rp - r), np.int32)
        logits, self.cache = self._prefill_insert(
            self.params, self.cache, batch, jnp.asarray(slots_arr),
            jnp.asarray(total))
        logits = np.asarray(logits)  # one host sync per admitted group
        for i, (job, slot) in enumerate(zip(jobs, slots)):
            self._start_seq(job, slot, int(total[i]), logits[i])

    # -- sampling / stepping ------------------------------------------------

    def _sample(self, logits: np.ndarray) -> int:
        if self.temp <= 0:
            return int(np.argmax(logits))
        z = logits / self.temp
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def step(self) -> int:
        """Admit + one decode block for all active slots. Returns #active."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return 0
        if self.fused_steps <= 1:
            return self._step_legacy(active)
        b = len(self.slots)
        tokens = np.zeros((b,), np.int32)
        positions = np.zeros((b,), np.int32)
        budgets = np.zeros((b,), np.int32)
        for i in active:
            st = self.slots[i]
            tokens[i] = st.generated[-1]
            positions[i] = self.positions[i]
            budgets[i] = max(0, st.max_new - len(st.generated))
        teff = self.serving.max_seq
        if self._ctx_buckets:
            # smallest bucket covering every position the block can write;
            # ladder = {2^n, 1.5*2^n} so the attended width tracks the live
            # context within ~33% (each bucket is one cached trace)
            needed = int(positions.max()) + self.fused_steps + 1
            teff = 32
            while teff < needed:
                teff = teff * 3 // 2 if teff & (teff - 1) == 0 else teff * 4 // 3
            teff = min(teff, self.serving.max_seq)
        block, self.cache, self._keys = self._fused(
            self.params, self.cache, self._keys, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(budgets), teff)
        block = np.asarray(block)  # the ONLY host sync: (B, K) per K tokens
        now = time.monotonic()
        for i in active:
            st = self.slots[i]
            if st is None:
                continue  # cancelled mid-block by an on_token callback
            for j in range(self.fused_steps):
                nxt = int(block[i, j])
                st.generated.append(nxt)
                self.decode_tokens += 1
                self.positions[i] += 1
                if self.on_token is not None:
                    self.on_token(st.rid, nxt, now)
                hit_cap = self.positions[i] + 1 >= self.serving.max_seq
                if (nxt == self.eos_id or len(st.generated) >= st.max_new
                        or hit_cap):
                    self._finish_slot(i, now)
                    break
        self.steps += 1
        self.last_heartbeat = now
        return len(active)

    def _step_legacy(self, active: List[int]) -> int:
        """Per-token path: one dispatch + one logits transfer per token."""
        b = len(self.slots)
        tokens = np.zeros((b, 1), np.int32)
        positions = np.zeros((b,), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
            positions[i] = self.positions[i]
        logits, self.cache = self._decode(
            self.params, self.cache,
            {"tokens": jnp.asarray(tokens),
             "positions": jnp.asarray(positions)})
        logits = np.asarray(logits)
        now = time.monotonic()
        for i in active:
            st = self.slots[i]
            if st is None:
                continue  # cancelled mid-step
            self.positions[i] += 1
            nxt = self._sample(logits[i])
            st.generated.append(nxt)
            self.decode_tokens += 1
            if self.on_token is not None:
                self.on_token(st.rid, nxt, now)
            hit_cap = self.positions[i] + 1 >= self.serving.max_seq
            if (nxt == self.eos_id or len(st.generated) >= st.max_new
                    or hit_cap):
                self._finish_slot(i, now)
        self.steps += 1
        self.last_heartbeat = now
        return len(active)

    def run_until_drained(self, max_steps: int = 100_000) -> List[SeqState]:
        while (self.waiting or any(s is not None for s in self.slots)):
            if self.steps >= max_steps:
                break
            self.step()
        return self.finished

    # -- fault tolerance ----------------------------------------------------

    def heartbeat_ok(self) -> bool:
        dt = time.monotonic() - self.last_heartbeat
        self.healthy = dt <= self.serving.heartbeat_timeout_s or self.steps == 0
        return self.healthy

    @staticmethod
    def _copy_seq(s: Optional[SeqState]) -> Optional[SeqState]:
        # generated must be deep-copied: a shallow replace() would alias the
        # live list, letting post-snapshot tokens leak into the snapshot
        return (dataclasses.replace(s, generated=list(s.generated))
                if s else None)

    def snapshot(self) -> dict:
        return {
            "cache": jax.tree.map(np.asarray, self.cache),
            "slots": [self._copy_seq(s) for s in self.slots],
            "positions": self.positions.copy(),
            "waiting": list(self.waiting),
            "steps": self.steps,
            "keys": np.asarray(self._keys),
        }

    def restore(self, snap: dict) -> None:
        self.cache = jax.tree.map(jnp.asarray, snap["cache"])
        self.slots = [self._copy_seq(s) for s in snap["slots"]]
        self.positions = snap["positions"].copy()
        self.waiting = list(snap["waiting"])
        self.steps = snap["steps"]
        if "keys" in snap:
            self._keys = jnp.asarray(snap["keys"])
        self.healthy = True
        self.last_heartbeat = time.monotonic()
