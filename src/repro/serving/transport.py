"""Replica transports: how an :class:`~repro.serving.pool.EnginePool`
talks to ONE ``TierEngine`` replica.

Two implementations behind the same duck-typed surface:

* :class:`LocalTransport` — the replica lives in this process and every
  call is a direct method call on the engine. With a single replica this
  is bit-identical to the pre-pool serving path (same call order, same
  engine hooks), which keeps it the parity/debug baseline.
* :class:`ProcessTransport` — the replica runs in a worker process
  (``multiprocessing`` *spawn* context: fork is unsafe once jax has
  initialized). The worker rebuilds its engine deterministically from a
  :class:`ReplicaSpec` (same reduced model, same param seed as the
  in-process construction, so temp=0 tokens are identical), free-runs
  ``step()`` while it has work, and streams admit/token/warm/park events,
  finished sequences and utilization stats back over a pipe. Synchronous
  operations (image encode, slot extract/inject, session ship) are
  tagged RPCs handled between worker steps; ``SlotPayload`` and parked
  sessions cross the pipe in the existing versioned migration wire
  format (``SlotPayload.to_bytes``).

Every pipe message is framed by :func:`msg_to_bytes`: a magic tag, the
transport wire version and a CRC32 of the pickled body, so a mismatched
peer fails loudly and a corrupted frame raises :class:`TransportError`
*before* any untrusted bytes reach ``pickle.loads`` — and a garbage
length prefix can never trigger a giant allocation (``max_frame_bytes``
caps both parsing and the pipe reads).

Event/finish streams are additionally *sequenced*: the sender stamps a
monotonic per-replica sequence number on every frame and keeps a bounded
outbox; the receiving :class:`DeliveryGuard` suppresses duplicates,
restores order, and heals gaps by replaying from the outbox (a resync) —
exactly-once delivery over a byzantine wire.
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import struct
import time
import zlib
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.config import ServingConfig
from repro.serving.engine import MigrationError, SlotPayload

TRANSPORT_WIRE_VERSION = 2
_FRAME_MAGIC = b"MOAF"
_FRAME_HDR = struct.Struct("<HI")  # (version, crc32 of body)
# generous default: large enough for any slot payload the reduced models
# can produce, small enough that a garbage length can't OOM the host
DEFAULT_MAX_FRAME_BYTES = 256 * 1024 * 1024
# how many sequenced frames a sender keeps for gap replay
OUTBOX_DEPTH = 512

# event tuples streamed from a replica: ("admit", rid, t),
# ("token", rid, token, t), ("warm", rid, kind, cached, suffix),
# ("park", rid, sid)
EVENT_KINDS = ("admit", "token", "warm", "park")


class TransportError(RuntimeError):
    """A replica transport failed (dead worker, bad frame, RPC timeout)."""


@dataclass
class FinishedSeq:
    """Transport-neutral finished sequence (what ``_harvest`` consumes)."""
    rid: int
    generated: List[int]
    t_done: Optional[float]


def msg_to_bytes(kind: str, payload: Any) -> bytes:
    """Frame one transport message: magic + version + CRC32, then the
    pickled ``(kind, payload)`` body."""
    body = pickle.dumps((kind, payload), protocol=pickle.HIGHEST_PROTOCOL)
    return (_FRAME_MAGIC
            + _FRAME_HDR.pack(TRANSPORT_WIRE_VERSION, zlib.crc32(body))
            + body)


def msg_from_bytes(raw: bytes,
                   max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES
                   ) -> Tuple[str, Any]:
    """Parse + validate one frame; raises TransportError on any mismatch.

    Validation order matters: size cap, magic, version and CRC are all
    checked BEFORE the body reaches ``pickle.loads`` — corrupt or hostile
    bytes fail deterministically instead of crashing (or allocating) in
    the unpickler."""
    if len(raw) > max_frame_bytes:
        raise TransportError(
            f"oversized transport frame: {len(raw)} > {max_frame_bytes}")
    hdr_end = len(_FRAME_MAGIC) + _FRAME_HDR.size
    if len(raw) < hdr_end:
        raise TransportError(f"short transport frame: {len(raw)} bytes")
    if raw[:len(_FRAME_MAGIC)] != _FRAME_MAGIC:
        raise TransportError("bad transport frame magic")
    ver, crc = _FRAME_HDR.unpack(raw[len(_FRAME_MAGIC):hdr_end])
    if ver != TRANSPORT_WIRE_VERSION:
        raise TransportError(
            f"transport wire version {ver} != {TRANSPORT_WIRE_VERSION}")
    body = raw[hdr_end:]
    if zlib.crc32(body) != crc:
        raise TransportError("transport frame checksum mismatch")
    try:
        msg = pickle.loads(body)
    except Exception as e:  # truncated / corrupt frame
        raise TransportError(f"undecodable transport frame: {e}") from e
    if not isinstance(msg, tuple) or len(msg) != 2:
        raise TransportError(f"malformed transport frame: {type(msg)}")
    kind, payload = msg
    if not isinstance(kind, str):
        raise TransportError(f"malformed message kind: {kind!r}")
    return kind, payload


class DeliveryGuard:
    """Exactly-once, in-order receiver for one replica's sequenced
    event/finish stream — and the injection point for byzantine message
    chaos on that stream.

    The sender stamps a monotonic ``seq`` on every frame and keeps a
    bounded outbox. On receive: a seq at-or-below the ledger's high-water
    mark is a duplicate (suppressed); the next expected seq is delivered
    (plus any buffered successors); a gap buffers the frame and requests
    ONE resync, which replays the missing range from the sender's outbox
    through :meth:`redeliver` (chaos-exempt — a retransmission). If the
    gap outlives ``resync_patience`` heal sweeps it is abandoned: the
    ledger jumps forward so delivery stays live (the per-rid idempotence
    in ``_harvest`` keeps lost finishes recoverable).
    """

    def __init__(self, link: str, chaos=None,
                 stats: Optional[Dict[str, int]] = None,
                 now_rel: Optional[Callable[[], float]] = None,
                 resync: Optional[Callable[[int], None]] = None,
                 resync_patience: int = 0):
        self.link = link
        self.chaos = chaos
        self.stats = stats if stats is not None else {}
        self.now_rel = now_rel or (lambda: 0.0)
        self._resync = resync
        self.resync_patience = resync_patience
        self.last_seq = 0
        # sender's high-water mark, advertised out of band (local: at send
        # time; process: via stats frames) — how a dropped TAIL frame with
        # no successor is still detected as a gap
        self.expected = 0
        self._pending: Dict[int, Tuple[str, Any]] = {}
        self._held: Optional[Tuple[int, str, Any]] = None
        self._out: List[Tuple[str, Any]] = []
        self._gap_waited = -1  # -1: no outstanding gap / resync

    def _bump(self, key: str, n: int = 1) -> None:
        self.stats[key] = self.stats.get(key, 0) + n

    @property
    def _gapped(self) -> bool:
        return bool(self._pending) or self.expected > self.last_seq

    # -- wire side -----------------------------------------------------------

    def receive(self, seq: int, kind: str, payload: Any) -> None:
        """One sequenced frame off the wire (chaos applies here)."""
        if self.chaos is not None:
            t = self.now_rel()
            if self.chaos.decide("msg_drop", self.link, t):
                self._bump("msgs_dropped")
                return
            if self._held is None and self.chaos.decide(
                    "msg_reorder", self.link, t):
                self._held = (seq, kind, payload)
                self._bump("msgs_reordered")
                return
            if self.chaos.decide("msg_dup", self.link, t):
                self._bump("msgs_duped")
                self._accept(seq, kind, payload)
            self._accept(seq, kind, payload)
            if self._held is not None:
                held, self._held = self._held, None
                self._accept(*held)  # delivered AFTER its successor
        else:
            self._accept(seq, kind, payload)

    def redeliver(self, seq: int, kind: str, payload: Any) -> None:
        """Resync replay path: chaos-exempt, still exactly-once."""
        self._accept(seq, kind, payload)

    def _accept(self, seq: int, kind: str, payload: Any) -> None:
        self.expected = max(self.expected, seq)
        if seq <= self.last_seq or seq in self._pending:
            self._bump("dups_suppressed")
            return
        if seq == self.last_seq + 1:
            self.last_seq = seq
            self._out.append((kind, payload))
            while self.last_seq + 1 in self._pending:
                self.last_seq += 1
                self._out.append(self._pending.pop(self.last_seq))
            if not self._gapped:
                self._gap_waited = -1
            return
        if not self._pending:
            self._bump("gaps_detected")
        self._pending[seq] = (kind, payload)

    # -- receiver side -------------------------------------------------------

    def heal(self) -> None:
        """End-of-poll sweep: release a held reorder, then drive gap
        recovery (request a resync once; abandon if it never lands)."""
        if self._held is not None:
            held, self._held = self._held, None
            self._accept(*held)
        if not self._gapped:
            self._gap_waited = -1
            return
        if self._gap_waited < 0:
            self._gap_waited = 0
            if self._resync is not None:
                self._bump("resyncs")
                try:
                    self._resync(self.last_seq)
                except TransportError:
                    pass  # sender is dead; lost-rid recovery owns it now
            if not self._gapped:  # synchronous (in-process) replay landed
                self._gap_waited = -1
            return
        self._gap_waited += 1
        if self._gap_waited > self.resync_patience:
            self._bump("gaps_abandoned")
            while self._pending:
                self.last_seq = min(self._pending)
                self._out.append(self._pending.pop(self.last_seq))
            self.last_seq = max(self.last_seq, self.expected)
            self._gap_waited = -1

    def drain(self) -> List[Tuple[str, Any]]:
        out, self._out = self._out, []
        return out

    def audit(self, label: str) -> List[str]:
        """Invariant check at teardown: nothing held, no open gap."""
        out = []
        if self._held is not None:
            out.append(f"{label}: delivery guard still holding a reordered "
                       f"frame (seq {self._held[0]})")
        if self._pending:
            out.append(f"{label}: delivery guard has an unresolved gap "
                       f"(last_seq {self.last_seq}, "
                       f"pending {sorted(self._pending)})")
        if self._out:
            out.append(f"{label}: {len(self._out)} delivered frames were "
                       f"never drained")
        return out


@dataclass(frozen=True)
class ReplicaSpec:
    """Everything a worker needs to rebuild its engine deterministically.

    ``param_seed`` matches the in-process construction
    (``build_cluster_engines`` seeds tier *i* with ``PRNGKey(i)``), so a
    process replica serves the SAME weights as its local twin and temp=0
    decoding is token-identical across transports.
    """
    model: str
    serving: ServingConfig
    dtype: str = "float32"
    param_seed: int = 0
    eos_id: int = 2
    sample_temp: float = 0.0
    seed: int = 0
    name: str = "replica"


def _prefix_hit_len(store, tokens: np.ndarray, extras_fp: bytes) -> int:
    """Longest stored strict prefix of ``tokens`` (0 = miss) WITHOUT
    touching LRU recency — the affinity probe must not reorder the store
    the eventual admission will consult."""
    if not store.enabled:
        return 0
    tokens = np.asarray(tokens)
    for n in sorted(store._lengths, reverse=True):
        if n >= len(tokens) or n < store.min_prefix:
            continue
        if store.contains(tokens[:n], extras_fp):
            return n
    return 0


class LocalTransport:
    """In-process replica: direct calls on a live ``TierEngine``.

    By default hooks attach straight to the engine and ``poll`` harvests
    ``eng.finished`` directly — bit-identical to the pre-pool serving
    path. :meth:`arm_delivery` (armed by the pool when a fault plan
    carries message faults) reroutes the event/finish stream through a
    sequenced :class:`DeliveryGuard` with an in-process outbox, so
    byzantine drop/dup/reorder chaos exercises the SAME exactly-once
    machinery the process transport uses; injected faults all heal
    within the poll that produced them.
    """

    kind = "local"
    supports_restore = True

    def __init__(self, engine):
        self.engine = engine
        self.alive = True
        self._guard: Optional[DeliveryGuard] = None
        self._sink = (None, None, None, None)
        self._seq = 0
        self._outbox: deque = deque(maxlen=OUTBOX_DEPTH)

    # -- config surface -----------------------------------------------------

    @property
    def cfg(self):
        return self.engine.cfg

    @property
    def serving(self) -> ServingConfig:
        return self.engine.serving

    def wire_hooks(self, on_admit, on_token, on_warm, on_park) -> None:
        self._sink = (on_admit, on_token, on_warm, on_park)
        self._attach()

    def _attach(self) -> None:
        on_admit, on_token, on_warm, on_park = self._sink
        if self._guard is None:
            self.engine.on_admit = on_admit
            self.engine.on_token = on_token
            self.engine.on_warm = on_warm
            self.engine.on_park = on_park
        else:
            self.engine.on_admit = \
                lambda rid, t: self._gsend("ev", ("admit", rid, t))
            self.engine.on_token = \
                lambda rid, tok, t: self._gsend("ev", ("token", rid, tok, t))
            self.engine.on_warm = \
                lambda rid, k, c, s: self._gsend("ev", ("warm", rid, k, c, s))
            self.engine.on_park = \
                lambda rid, sid: self._gsend("ev", ("park", rid, sid))

    def arm_delivery(self, chaos, stats: Dict[str, int],
                     now_rel: Callable[[], float], link: str) -> None:
        """Route events/finishes through a sequenced delivery guard with
        byzantine chaos on the wire side. The resync path replays
        synchronously from the in-process outbox."""
        self._guard = DeliveryGuard(
            link, chaos=chaos, stats=stats, now_rel=now_rel,
            resync=self._replay, resync_patience=0)
        self._attach()

    def _gsend(self, kind: str, payload: Any) -> None:
        self._seq += 1
        self._outbox.append((self._seq, kind, payload))
        # advertise the sender high-water mark BEFORE the wire so even a
        # dropped tail frame is seen as a gap at the next heal
        self._guard.expected = max(self._guard.expected, self._seq)
        self._guard.receive(self._seq, kind, payload)

    def _replay(self, last_seq: int) -> None:
        for seq, kind, payload in self._outbox:
            if seq > last_seq:
                self._guard.redeliver(seq, kind, payload)

    # -- request plane ------------------------------------------------------

    def submit(self, rid: int, tokens, max_new: int, extras,
               deadline, session) -> None:
        self.engine.submit(rid, tokens, max_new=max_new, extras=extras,
                           deadline=deadline, session=session)

    def cancel(self, rid: int) -> None:
        self.engine.cancel(rid)

    def poll(self) -> Tuple[List[FinishedSeq], bool, List[int]]:
        """One engine step; returns (finished, any-activity, lost rids)."""
        eng = self.engine
        n = eng.step()
        if self._guard is None:
            fins = [FinishedSeq(st.rid, list(st.generated), st.t_done)
                    for st in eng.finished]
            eng.finished.clear()
        else:
            for st in eng.finished:
                self._gsend("fin",
                            FinishedSeq(st.rid, list(st.generated),
                                        st.t_done))
            eng.finished.clear()
            self._guard.heal()
            on_admit, on_token, on_warm, on_park = self._sink
            fins = []
            for kind, payload in self._guard.drain():
                if kind == "fin":
                    fins.append(payload)
                    continue
                ev = payload
                if ev[0] == "admit" and on_admit:
                    on_admit(ev[1], ev[2])
                elif ev[0] == "token" and on_token:
                    on_token(ev[1], ev[2], ev[3])
                elif ev[0] == "warm" and on_warm:
                    on_warm(ev[1], ev[2], ev[3], ev[4])
                elif ev[0] == "park" and on_park:
                    on_park(ev[1], ev[2])
            while self._outbox and self._outbox[0][0] <= self._guard.last_seq:
                self._outbox.popleft()
        active = bool(n) or bool(eng.waiting) \
            or any(s is not None for s in eng.slots)
        return fins, active, []

    # -- observation --------------------------------------------------------

    def free_slots(self) -> int:
        return sum(s is None for s in self.engine.slots)

    def total_slots(self) -> int:
        return len(self.engine.slots)

    def queue_len(self) -> int:
        return len(self.engine.waiting)

    def kv_headroom(self) -> float:
        return self.engine.kv_headroom()

    def occupancy(self) -> int:
        return len(self.engine.waiting) + sum(
            s is not None for s in self.engine.slots)

    def rids(self) -> List[int]:
        return self.engine.rids()

    def slot_rids(self) -> List[int]:
        return [s.rid for s in self.engine.slots if s is not None]

    def decode_slots(self) -> List[Tuple[int, int]]:
        """(rid, remaining decode budget) per occupied slot, slot order."""
        return [(s.rid, s.max_new - len(s.generated))
                for s in self.engine.slots if s is not None]

    def prefix_hit_len(self, tokens, extras_fp: bytes) -> int:
        return _prefix_hit_len(self.engine.prefix_store, tokens, extras_fp)

    def counters(self) -> Dict[str, int]:
        e = self.engine
        return {"decode_tokens": e.decode_tokens,
                "prefill_tokens": e.prefill_tokens,
                "encode_tokens": e.encode_tokens,
                "prefix_hits": e.prefix_hits,
                "prefix_hit_tokens": e.prefix_hit_tokens,
                "resumed_sessions": e.resumed_sessions,
                "resumed_tokens": e.resumed_tokens,
                "parks": e.parks,
                "drafted_tokens": e.drafted_tokens,
                "accepted_tokens": e.accepted_tokens,
                "spec_rounds": e.spec_rounds}

    @property
    def healthy(self) -> bool:
        return self.engine.healthy

    def heartbeat_ok(self) -> bool:
        return self.engine.heartbeat_ok()

    def set_throttle(self, mult: float) -> None:
        self.engine.throttle = mult

    # -- partial offload ----------------------------------------------------

    def encode_image(self, image, num_patches: int = 0,
                     frontend_dim: int = 0):
        return self.engine.encode_image(image, num_patches, frontend_dim)

    # -- slot / session wire ------------------------------------------------

    def extract_wire(self, rid: int, *, remove: bool = False) -> bytes:
        return self.engine.extract_slot(rid, remove=remove).to_bytes()

    def inject_wire(self, wire: bytes) -> None:
        self.engine.inject_slot(SlotPayload.from_bytes(wire))

    def has_session(self, sid: str) -> bool:
        return sid in self.engine.sessions

    def session_ids(self) -> List[str]:
        return list(self.engine.sessions.ids())

    def session_count(self) -> int:
        return len(self.engine.sessions)

    def resume_session_wire(self, sid: str) -> Optional[bytes]:
        parked = self.engine.resume_session(sid)
        if parked is None or not isinstance(parked.data, SlotPayload):
            return None
        return parked.data.to_bytes()

    def adopt_session_wire(self, sid: str, wire: bytes) -> bool:
        try:
            payload = SlotPayload.from_bytes(wire)
        except MigrationError:
            return False  # corrupt in transit: the turn cold-prefills
        return bool(self.engine.adopt_session(sid, payload))

    def drop_session(self, sid: str) -> None:
        self.engine.sessions.resume(sid)  # pop + discard

    # -- fault discipline ---------------------------------------------------

    def snapshot(self) -> dict:
        return self.engine.snapshot()

    def restore(self, snap: dict) -> None:
        self.engine.restore(snap)

    def close(self) -> None:
        self.alive = False


# ---------------------------------------------------------------------------
# process transport


def _worker_main(conn, spec_raw: bytes) -> None:
    """Worker entry: rebuild the engine from its spec, then free-run —
    step while there is work, drain the command pipe between steps, and
    stream events / finished sequences / stats upward."""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    try:
        kind, spec = msg_from_bytes(spec_raw)
        assert kind == "spec"
        import jax  # deferred: the worker picks its own platform above
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.serving.engine import TierEngine

        cfg = reduced_config(spec.model).replace(dtype=spec.dtype)
        model = build_model(cfg)
        eng = TierEngine(model, model.init(jax.random.PRNGKey(spec.param_seed)),
                         spec.serving, eos_id=spec.eos_id,
                         sample_temp=spec.sample_temp, seed=spec.seed)
    except Exception as e:  # construction failed: report, don't hang
        try:
            conn.send_bytes(msg_to_bytes("died", f"build: {e!r}"))
        finally:
            conn.close()
        return

    events: List[tuple] = []
    eng.on_admit = lambda rid, t: events.append(("admit", rid, t))
    eng.on_token = lambda rid, tok, t: events.append(("token", rid, tok, t))
    eng.on_warm = lambda rid, k, c, s: events.append(("warm", rid, k, c, s))
    eng.on_park = lambda rid, sid: events.append(("park", rid, sid))

    # sequenced stream state: every events/fin frame carries a monotonic
    # seq and lands in a bounded outbox so the parent's delivery guard
    # can request a gap replay ("resync")
    stream_seq = 0
    outbox: deque = deque(maxlen=OUTBOX_DEPTH)

    def send_seq(kind: str, payload: Any) -> None:
        nonlocal stream_seq
        stream_seq += 1
        outbox.append((stream_seq, kind, payload))
        conn.send_bytes(msg_to_bytes(kind, (stream_seq, payload)))

    def handle_rpc(seq: int, op: str, arg: dict) -> None:
        try:
            if op == "encode":
                out = eng.encode_image(arg["image"], arg["num_patches"],
                                       arg["frontend_dim"])
            elif op == "extract":
                out = eng.extract_slot(arg["rid"],
                                       remove=arg["remove"]).to_bytes()
            elif op == "inject":
                eng.inject_slot(SlotPayload.from_bytes(arg["wire"]))
                out = True
            elif op == "resume_session":
                parked = eng.resume_session(arg["sid"])
                out = (parked.data.to_bytes()
                       if parked is not None
                       and isinstance(parked.data, SlotPayload) else None)
            elif op == "adopt_session":
                try:
                    payload = SlotPayload.from_bytes(arg["wire"])
                except MigrationError:
                    out = False
                else:
                    out = bool(eng.adopt_session(arg["sid"], payload))
            elif op == "drop_session":
                eng.sessions.resume(arg["sid"])
                out = True
            elif op == "ping":
                out = True
            else:
                raise TransportError(f"unknown rpc op {op!r}")
            conn.send_bytes(msg_to_bytes("reply", (seq, True, out)))
        except MigrationError as e:
            conn.send_bytes(msg_to_bytes("reply", (seq, False,
                                                   ("migration", str(e)))))
        except Exception as e:
            conn.send_bytes(msg_to_bytes("reply", (seq, False,
                                                   ("error", repr(e)))))

    def stats() -> dict:
        return {
            "stream_seq": stream_seq,
            "free_slots": sum(s is None for s in eng.slots),
            "total_slots": len(eng.slots),
            "queue": len(eng.waiting),
            "kv_headroom": eng.kv_headroom(),
            "sessions": list(eng.sessions.ids()),
            "healthy": eng.healthy,
            "counters": {
                "decode_tokens": eng.decode_tokens,
                "prefill_tokens": eng.prefill_tokens,
                "encode_tokens": eng.encode_tokens,
                "prefix_hits": eng.prefix_hits,
                "prefix_hit_tokens": eng.prefix_hit_tokens,
                "resumed_sessions": eng.resumed_sessions,
                "resumed_tokens": eng.resumed_tokens,
                "parks": eng.parks,
                "drafted_tokens": eng.drafted_tokens,
                "accepted_tokens": eng.accepted_tokens,
                "spec_rounds": eng.spec_rounds},
        }

    conn.send_bytes(msg_to_bytes("ready", stats()))
    last_stats = time.monotonic()
    running = True
    try:
        while running:
            busy = bool(eng.waiting) or any(
                s is not None for s in eng.slots)
            # drain commands; when idle, block briefly so the worker
            # doesn't spin a core waiting for work
            while conn.poll(0.0 if busy else 0.02):
                kind, payload = msg_from_bytes(
                    conn.recv_bytes(maxlength=DEFAULT_MAX_FRAME_BYTES))
                if kind == "stop":
                    running = False
                    break
                if kind == "submit":
                    eng.submit(payload["rid"], payload["tokens"],
                               max_new=payload["max_new"],
                               extras=payload["extras"],
                               deadline=payload["deadline"],
                               session=payload["session"])
                elif kind == "cancel":
                    eng.cancel(payload)
                elif kind == "throttle":
                    eng.throttle = float(payload)
                elif kind == "resync":
                    replay = [(s, k, p) for s, k, p in outbox
                              if s > int(payload)]
                    conn.send_bytes(msg_to_bytes("replay", replay))
                elif kind == "rpc":
                    handle_rpc(*payload)
                busy = True  # a command may have created work
            if not running:
                break
            if eng.waiting or any(s is not None for s in eng.slots):
                eng.step()
            if events:
                send_seq("events", events)
                events = []
            fins = None
            if eng.finished:
                fins = [(st.rid, list(st.generated), st.t_done)
                        for st in eng.finished]
                eng.finished.clear()
                send_seq("fin", fins)
            now = time.monotonic()
            if fins is not None or now - last_stats > 0.05:
                conn.send_bytes(msg_to_bytes("stats", stats()))
                last_stats = now
    except (EOFError, OSError, BrokenPipeError):
        pass  # parent went away
    except Exception as e:
        try:
            conn.send_bytes(msg_to_bytes("died", repr(e)))
        except Exception:
            pass
    finally:
        conn.close()


class ProcessTransport:
    """A replica in a spawn-context worker process behind a duplex pipe.

    The parent mirrors the worker's utilization (slots, queue, KV
    headroom, parked session ids, counters) from its periodic stats
    messages — observation reads are mirror-fresh, never blocking RPCs.
    A dead worker (crash, closed pipe, RPC timeout) flips ``alive``; the
    owning pool then reports the replica's in-flight rids as *lost* so
    the runtime's shared failure path resubmits them to siblings.
    """

    kind = "process"
    supports_restore = False

    def __init__(self, spec: ReplicaSpec, start_timeout_s: float = 120.0,
                 rpc_timeout_s: float = 60.0,
                 max_frame_bytes: int = DEFAULT_MAX_FRAME_BYTES):
        self.spec = spec
        self.alive = True
        self.rpc_timeout_s = rpc_timeout_s
        self.max_frame_bytes = int(max_frame_bytes)
        self._rpc_seq = 0
        self._live_rids: set = set()
        self._pending_fins: List[FinishedSeq] = []
        self._pending_lost: List[int] = []
        self._hooks = (None, None, None, None)
        # the sequenced event/fin stream always rides a delivery guard
        # (exactly-once even on an honest pipe); byzantine chaos and the
        # shared stats dict are armed later by the pool when a fault plan
        # carries message faults
        self._chaos = None
        self._frame_link = f"frame:{spec.name}"
        self._now_rel: Callable[[], float] = lambda: 0.0
        self._guard = DeliveryGuard(
            f"events:{spec.name}", resync=self._request_resync,
            resync_patience=64)
        self._stats: Dict[str, Any] = {
            "free_slots": spec.serving.max_batch,
            "total_slots": spec.serving.max_batch,
            "queue": 0, "kv_headroom": 1.0, "sessions": [],
            "healthy": True, "counters": {}}
        # parent-side model config twin (for patch geometry / embed bytes
        # without a round trip) — the worker builds the same reduced config
        from repro.configs import reduced_config
        self.cfg = reduced_config(spec.model).replace(dtype=spec.dtype)
        ctx = mp.get_context("spawn")
        self._conn, child = ctx.Pipe(duplex=True)
        self._proc = ctx.Process(
            target=_worker_main,
            args=(child, msg_to_bytes("spec", spec)),
            name=f"tier-replica-{spec.name}", daemon=True)
        self._proc.start()
        child.close()
        # block until the worker's engine is built: submissions before
        # "ready" would race construction failures
        deadline = time.monotonic() + start_timeout_s
        while True:
            if self._conn.poll(0.1):
                try:
                    kind, payload = msg_from_bytes(
                        self._conn.recv_bytes(maxlength=self.max_frame_bytes),
                        self.max_frame_bytes)
                except (EOFError, OSError) as e:
                    # spawn failed before the worker could report (e.g. a
                    # non-importable __main__): surface a TransportError
                    self._mark_dead()
                    raise TransportError(
                        f"replica {spec.name} died during spawn: "
                        f"{e!r}") from e
                if kind == "ready":
                    self._stats.update(payload)
                    break
                if kind == "died":
                    self._mark_dead()
                    raise TransportError(
                        f"replica {spec.name} failed to start: {payload}")
            if time.monotonic() > deadline:
                self._mark_dead()
                raise TransportError(
                    f"replica {spec.name} start timeout")

    @property
    def serving(self) -> ServingConfig:
        return self.spec.serving

    def wire_hooks(self, on_admit, on_token, on_warm, on_park) -> None:
        self._hooks = (on_admit, on_token, on_warm, on_park)

    def arm_delivery(self, chaos, stats: Dict[str, int],
                     now_rel: Callable[[], float], link: str) -> None:
        """Attach byzantine chaos + the runtime's shared wire-stats dict
        to this replica's streams. The existing guard keeps its sequence
        state; raw frame corruption applies on the matching frame link."""
        self._chaos = chaos
        self._now_rel = now_rel
        self._frame_link = "frame:" + link.split(":", 1)[-1]
        self._guard.link = link
        self._guard.chaos = chaos
        self._guard.stats = stats
        self._guard.now_rel = now_rel

    # -- plumbing -----------------------------------------------------------

    def _mark_dead(self) -> None:
        if not self.alive:
            return
        self.alive = False
        self._pending_lost.extend(sorted(self._live_rids))
        self._live_rids.clear()

    def _send(self, kind: str, payload: Any) -> None:
        if not self.alive:
            raise TransportError(f"replica {self.spec.name} is dead")
        try:
            self._conn.send_bytes(msg_to_bytes(kind, payload))
        except (OSError, BrokenPipeError, ValueError) as e:
            self._mark_dead()
            raise TransportError(
                f"replica {self.spec.name} pipe broken: {e}") from e

    def _dispatch(self, kind: str, payload: Any) -> None:
        """Route one inbound message. Sequenced events/fin frames pass
        through the delivery guard (exactly-once, in order); replays and
        stats feed its gap machinery out of band."""
        if kind == "events" or kind == "fin":
            seq, body = payload
            self._guard.receive(int(seq), kind, body)
            self._flush_guard()
        elif kind == "replay":
            for seq, k, body in payload:
                self._guard.redeliver(int(seq), k, body)
            self._flush_guard()
        elif kind == "stats":
            self._stats.update(payload)
            self._guard.expected = max(
                self._guard.expected, int(payload.get("stream_seq", 0)))
        elif kind == "died":
            self._mark_dead()

    def _flush_guard(self) -> None:
        """Deliver in-order frames released by the guard."""
        for kind, body in self._guard.drain():
            if kind == "events":
                on_admit, on_token, on_warm, on_park = self._hooks
                for ev in body:
                    if ev[0] == "admit" and on_admit:
                        on_admit(ev[1], ev[2])
                    elif ev[0] == "token" and on_token:
                        on_token(ev[1], ev[2], ev[3])
                    elif ev[0] == "warm" and on_warm:
                        on_warm(ev[1], ev[2], ev[3], ev[4])
                    elif ev[0] == "park" and on_park:
                        on_park(ev[1], ev[2])
            else:  # fin
                for rid, generated, t_done in body:
                    self._live_rids.discard(rid)
                    self._pending_fins.append(
                        FinishedSeq(rid, list(generated), t_done))

    def _request_resync(self, last_seq: int) -> None:
        self._send("resync", int(last_seq))

    def _drain(self) -> None:
        try:
            while self.alive and self._conn.poll(0.0):
                raw = self._conn.recv_bytes(maxlength=self.max_frame_bytes)
                if self._chaos is not None and self._chaos.decide(
                        "corrupt", self._frame_link, self._now_rel()):
                    raw = self._chaos.tamper(raw, self._frame_link)
                    self._chaos.bump("corrupt_injected")
                try:
                    kind, payload = msg_from_bytes(raw, self.max_frame_bytes)
                except TransportError:
                    # corrupt frame: count, discard, let the sequence
                    # layer detect the hole and resync — never unpickled
                    self._guard._bump("corrupt_detected")
                    continue
                if kind == "reply":
                    continue  # stale reply from a timed-out RPC
                self._dispatch(kind, payload)
        except (EOFError, OSError, BrokenPipeError):
            self._mark_dead()
        if self.alive and not self._proc.is_alive():
            self._mark_dead()

    def _rpc(self, op: str, **arg):
        self._rpc_seq += 1
        seq = self._rpc_seq
        self._send("rpc", (seq, op, arg))
        deadline = time.monotonic() + self.rpc_timeout_s
        while time.monotonic() < deadline:
            try:
                if not self._conn.poll(0.05):
                    continue
                kind, payload = msg_from_bytes(
                    self._conn.recv_bytes(maxlength=self.max_frame_bytes),
                    self.max_frame_bytes)
            except (EOFError, OSError, BrokenPipeError) as e:
                self._mark_dead()
                raise TransportError(
                    f"replica {self.spec.name} died mid-RPC") from e
            if kind == "reply":
                rseq, ok, out = payload
                if rseq != seq:
                    continue  # stale reply from an earlier timeout
                if ok:
                    return out
                tag, detail = out
                if tag == "migration":
                    raise MigrationError(detail)
                raise TransportError(f"{op} failed on "
                                     f"{self.spec.name}: {detail}")
            self._dispatch(kind, payload)
        self._mark_dead()
        raise TransportError(f"rpc {op} timed out on {self.spec.name}")

    # -- request plane ------------------------------------------------------

    def submit(self, rid: int, tokens, max_new: int, extras,
               deadline, session) -> None:
        self._send("submit", {"rid": rid, "tokens": np.asarray(tokens),
                              "max_new": max_new, "extras": extras,
                              "deadline": deadline, "session": session})
        self._live_rids.add(rid)

    def cancel(self, rid: int) -> None:
        self._live_rids.discard(rid)
        if self.alive:
            try:
                self._send("cancel", rid)
            except TransportError:
                pass  # already dead: the rid is gone either way

    def poll(self) -> Tuple[List[FinishedSeq], bool, List[int]]:
        self._drain()
        self._guard.heal()
        self._flush_guard()
        fins, self._pending_fins = self._pending_fins, []
        lost, self._pending_lost = self._pending_lost, []
        return fins, bool(self._live_rids), lost

    # -- observation (mirror-fresh, non-blocking) ---------------------------

    def free_slots(self) -> int:
        return int(self._stats["free_slots"]) if self.alive else 0

    def total_slots(self) -> int:
        return int(self._stats["total_slots"])

    def queue_len(self) -> int:
        return int(self._stats["queue"]) if self.alive else 0

    def kv_headroom(self) -> float:
        return float(self._stats["kv_headroom"]) if self.alive else 0.0

    def occupancy(self) -> int:
        # live rids the parent actually submitted: robust against a stale
        # stats mirror between heartbeats
        return len(self._live_rids)

    def rids(self) -> List[int]:
        return sorted(self._live_rids)

    def slot_rids(self) -> List[int]:
        return []  # no slot-granular visibility across the pipe

    def decode_slots(self) -> List[Tuple[int, int]]:
        return []  # preemption scans only local replicas

    def prefix_hit_len(self, tokens, extras_fp: bytes) -> int:
        return 0  # affinity probe is local-only; process picks by load

    def counters(self) -> Dict[str, int]:
        return dict(self._stats.get("counters", {}))

    @property
    def healthy(self) -> bool:
        return self.alive and bool(self._stats.get("healthy", True))

    def heartbeat_ok(self) -> bool:
        return self.alive and self._proc.is_alive()

    def set_throttle(self, mult: float) -> None:
        if self.alive:
            try:
                self._send("throttle", float(mult))
            except TransportError:
                pass

    # -- partial offload ----------------------------------------------------

    def encode_image(self, image, num_patches: int = 0,
                     frontend_dim: int = 0):
        return self._rpc("encode", image=np.asarray(image),
                         num_patches=num_patches, frontend_dim=frontend_dim)

    # -- slot / session wire ------------------------------------------------

    def extract_wire(self, rid: int, *, remove: bool = False) -> bytes:
        wire = self._rpc("extract", rid=rid, remove=remove)
        if remove:
            self._live_rids.discard(rid)
        return wire

    def inject_wire(self, wire: bytes) -> None:
        self._rpc("inject", wire=wire)

    def has_session(self, sid: str) -> bool:
        return self.alive and sid in self._stats.get("sessions", [])

    def session_ids(self) -> List[str]:
        return list(self._stats.get("sessions", [])) if self.alive else []

    def session_count(self) -> int:
        return len(self.session_ids())

    def resume_session_wire(self, sid: str) -> Optional[bytes]:
        try:
            return self._rpc("resume_session", sid=sid)
        except TransportError:
            return None

    def adopt_session_wire(self, sid: str, wire: bytes) -> bool:
        try:
            return bool(self._rpc("adopt_session", sid=sid, wire=wire))
        except TransportError:
            return False

    def drop_session(self, sid: str) -> None:
        try:
            self._rpc("drop_session", sid=sid)
        except TransportError:
            pass

    # -- fault discipline ---------------------------------------------------

    def snapshot(self) -> dict:
        raise TransportError(
            "process replicas have no host-side snapshot; chaos injection "
            "(fail_rate / crash plans) requires the local transport")

    def restore(self, snap: dict) -> None:
        raise TransportError("process replicas cannot restore")

    def close(self) -> None:
        if self.alive:
            try:
                self._send("stop", None)
            except TransportError:
                pass
        self.alive = False
        self._proc.join(timeout=5.0)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=5.0)
        try:
            self._conn.close()
        except OSError:
            pass
