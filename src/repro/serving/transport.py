"""Replica transports: how an :class:`~repro.serving.pool.EnginePool`
talks to ONE ``TierEngine`` replica.

Two implementations behind the same duck-typed surface:

* :class:`LocalTransport` — the replica lives in this process and every
  call is a direct method call on the engine. With a single replica this
  is bit-identical to the pre-pool serving path (same call order, same
  engine hooks), which keeps it the parity/debug baseline.
* :class:`ProcessTransport` — the replica runs in a worker process
  (``multiprocessing`` *spawn* context: fork is unsafe once jax has
  initialized). The worker rebuilds its engine deterministically from a
  :class:`ReplicaSpec` (same reduced model, same param seed as the
  in-process construction, so temp=0 tokens are identical), free-runs
  ``step()`` while it has work, and streams admit/token/warm/park events,
  finished sequences and utilization stats back over a pipe. Synchronous
  operations (image encode, slot extract/inject, session ship) are
  tagged RPCs handled between worker steps; ``SlotPayload`` and parked
  sessions cross the pipe in the existing versioned migration wire
  format (``SlotPayload.to_bytes``).

Every pipe message is framed by :func:`msg_to_bytes` with a transport
wire version so a mismatched peer fails loudly instead of misparsing.
"""
from __future__ import annotations

import multiprocessing as mp
import pickle
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.config import ServingConfig
from repro.serving.engine import MigrationError, SlotPayload

TRANSPORT_WIRE_VERSION = 1

# event tuples streamed from a replica: ("admit", rid, t),
# ("token", rid, token, t), ("warm", rid, kind, cached, suffix),
# ("park", rid, sid)
EVENT_KINDS = ("admit", "token", "warm", "park")


class TransportError(RuntimeError):
    """A replica transport failed (dead worker, bad frame, RPC timeout)."""


@dataclass
class FinishedSeq:
    """Transport-neutral finished sequence (what ``_harvest`` consumes)."""
    rid: int
    generated: List[int]
    t_done: Optional[float]


def msg_to_bytes(kind: str, payload: Any) -> bytes:
    """Frame one transport message: version-tagged, pickled."""
    return pickle.dumps((TRANSPORT_WIRE_VERSION, kind, payload),
                        protocol=pickle.HIGHEST_PROTOCOL)


def msg_from_bytes(raw: bytes) -> Tuple[str, Any]:
    """Parse + validate one frame; raises TransportError on any mismatch."""
    try:
        msg = pickle.loads(raw)
    except Exception as e:  # truncated / corrupt frame
        raise TransportError(f"undecodable transport frame: {e}") from e
    if not isinstance(msg, tuple) or len(msg) != 3:
        raise TransportError(f"malformed transport frame: {type(msg)}")
    ver, kind, payload = msg
    if ver != TRANSPORT_WIRE_VERSION:
        raise TransportError(
            f"transport wire version {ver} != {TRANSPORT_WIRE_VERSION}")
    if not isinstance(kind, str):
        raise TransportError(f"malformed message kind: {kind!r}")
    return kind, payload


@dataclass(frozen=True)
class ReplicaSpec:
    """Everything a worker needs to rebuild its engine deterministically.

    ``param_seed`` matches the in-process construction
    (``build_cluster_engines`` seeds tier *i* with ``PRNGKey(i)``), so a
    process replica serves the SAME weights as its local twin and temp=0
    decoding is token-identical across transports.
    """
    model: str
    serving: ServingConfig
    dtype: str = "float32"
    param_seed: int = 0
    eos_id: int = 2
    sample_temp: float = 0.0
    seed: int = 0
    name: str = "replica"


def _prefix_hit_len(store, tokens: np.ndarray, extras_fp: bytes) -> int:
    """Longest stored strict prefix of ``tokens`` (0 = miss) WITHOUT
    touching LRU recency — the affinity probe must not reorder the store
    the eventual admission will consult."""
    if not store.enabled:
        return 0
    tokens = np.asarray(tokens)
    for n in sorted(store._lengths, reverse=True):
        if n >= len(tokens) or n < store.min_prefix:
            continue
        if store.contains(tokens[:n], extras_fp):
            return n
    return 0


class LocalTransport:
    """In-process replica: direct calls on a live ``TierEngine``."""

    kind = "local"
    supports_restore = True

    def __init__(self, engine):
        self.engine = engine
        self.alive = True

    # -- config surface -----------------------------------------------------

    @property
    def cfg(self):
        return self.engine.cfg

    @property
    def serving(self) -> ServingConfig:
        return self.engine.serving

    def wire_hooks(self, on_admit, on_token, on_warm, on_park) -> None:
        self.engine.on_admit = on_admit
        self.engine.on_token = on_token
        self.engine.on_warm = on_warm
        self.engine.on_park = on_park

    # -- request plane ------------------------------------------------------

    def submit(self, rid: int, tokens, max_new: int, extras,
               deadline, session) -> None:
        self.engine.submit(rid, tokens, max_new=max_new, extras=extras,
                           deadline=deadline, session=session)

    def cancel(self, rid: int) -> None:
        self.engine.cancel(rid)

    def poll(self) -> Tuple[List[FinishedSeq], bool, List[int]]:
        """One engine step; returns (finished, any-activity, lost rids)."""
        eng = self.engine
        n = eng.step()
        fins = [FinishedSeq(st.rid, list(st.generated), st.t_done)
                for st in eng.finished]
        eng.finished.clear()
        active = bool(n) or bool(eng.waiting) \
            or any(s is not None for s in eng.slots)
        return fins, active, []

    # -- observation --------------------------------------------------------

    def free_slots(self) -> int:
        return sum(s is None for s in self.engine.slots)

    def total_slots(self) -> int:
        return len(self.engine.slots)

    def queue_len(self) -> int:
        return len(self.engine.waiting)

    def kv_headroom(self) -> float:
        return self.engine.kv_headroom()

    def occupancy(self) -> int:
        return len(self.engine.waiting) + sum(
            s is not None for s in self.engine.slots)

    def rids(self) -> List[int]:
        return self.engine.rids()

    def slot_rids(self) -> List[int]:
        return [s.rid for s in self.engine.slots if s is not None]

    def decode_slots(self) -> List[Tuple[int, int]]:
        """(rid, remaining decode budget) per occupied slot, slot order."""
        return [(s.rid, s.max_new - len(s.generated))
                for s in self.engine.slots if s is not None]

    def prefix_hit_len(self, tokens, extras_fp: bytes) -> int:
        return _prefix_hit_len(self.engine.prefix_store, tokens, extras_fp)

    def counters(self) -> Dict[str, int]:
        e = self.engine
        return {"decode_tokens": e.decode_tokens,
                "prefill_tokens": e.prefill_tokens,
                "encode_tokens": e.encode_tokens,
                "prefix_hits": e.prefix_hits,
                "prefix_hit_tokens": e.prefix_hit_tokens,
                "resumed_sessions": e.resumed_sessions,
                "resumed_tokens": e.resumed_tokens,
                "parks": e.parks,
                "drafted_tokens": e.drafted_tokens,
                "accepted_tokens": e.accepted_tokens,
                "spec_rounds": e.spec_rounds}

    @property
    def healthy(self) -> bool:
        return self.engine.healthy

    def heartbeat_ok(self) -> bool:
        return self.engine.heartbeat_ok()

    def set_throttle(self, mult: float) -> None:
        self.engine.throttle = mult

    # -- partial offload ----------------------------------------------------

    def encode_image(self, image, num_patches: int = 0,
                     frontend_dim: int = 0):
        return self.engine.encode_image(image, num_patches, frontend_dim)

    # -- slot / session wire ------------------------------------------------

    def extract_wire(self, rid: int, *, remove: bool = False) -> bytes:
        return self.engine.extract_slot(rid, remove=remove).to_bytes()

    def inject_wire(self, wire: bytes) -> None:
        self.engine.inject_slot(SlotPayload.from_bytes(wire))

    def has_session(self, sid: str) -> bool:
        return sid in self.engine.sessions

    def session_ids(self) -> List[str]:
        return list(self.engine.sessions.ids())

    def session_count(self) -> int:
        return len(self.engine.sessions)

    def resume_session_wire(self, sid: str) -> Optional[bytes]:
        parked = self.engine.resume_session(sid)
        if parked is None or not isinstance(parked.data, SlotPayload):
            return None
        return parked.data.to_bytes()

    def adopt_session_wire(self, sid: str, wire: bytes) -> bool:
        try:
            payload = SlotPayload.from_bytes(wire)
        except MigrationError:
            return False  # corrupt in transit: the turn cold-prefills
        return bool(self.engine.adopt_session(sid, payload))

    def drop_session(self, sid: str) -> None:
        self.engine.sessions.resume(sid)  # pop + discard

    # -- fault discipline ---------------------------------------------------

    def snapshot(self) -> dict:
        return self.engine.snapshot()

    def restore(self, snap: dict) -> None:
        self.engine.restore(snap)

    def close(self) -> None:
        self.alive = False


# ---------------------------------------------------------------------------
# process transport


def _worker_main(conn, spec_raw: bytes) -> None:
    """Worker entry: rebuild the engine from its spec, then free-run —
    step while there is work, drain the command pipe between steps, and
    stream events / finished sequences / stats upward."""
    import os
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    try:
        kind, spec = msg_from_bytes(spec_raw)
        assert kind == "spec"
        import jax  # deferred: the worker picks its own platform above
        from repro.configs import reduced_config
        from repro.models import build_model
        from repro.serving.engine import TierEngine

        cfg = reduced_config(spec.model).replace(dtype=spec.dtype)
        model = build_model(cfg)
        eng = TierEngine(model, model.init(jax.random.PRNGKey(spec.param_seed)),
                         spec.serving, eos_id=spec.eos_id,
                         sample_temp=spec.sample_temp, seed=spec.seed)
    except Exception as e:  # construction failed: report, don't hang
        try:
            conn.send_bytes(msg_to_bytes("died", f"build: {e!r}"))
        finally:
            conn.close()
        return

    events: List[tuple] = []
    eng.on_admit = lambda rid, t: events.append(("admit", rid, t))
    eng.on_token = lambda rid, tok, t: events.append(("token", rid, tok, t))
    eng.on_warm = lambda rid, k, c, s: events.append(("warm", rid, k, c, s))
    eng.on_park = lambda rid, sid: events.append(("park", rid, sid))

    def handle_rpc(seq: int, op: str, arg: dict) -> None:
        try:
            if op == "encode":
                out = eng.encode_image(arg["image"], arg["num_patches"],
                                       arg["frontend_dim"])
            elif op == "extract":
                out = eng.extract_slot(arg["rid"],
                                       remove=arg["remove"]).to_bytes()
            elif op == "inject":
                eng.inject_slot(SlotPayload.from_bytes(arg["wire"]))
                out = True
            elif op == "resume_session":
                parked = eng.resume_session(arg["sid"])
                out = (parked.data.to_bytes()
                       if parked is not None
                       and isinstance(parked.data, SlotPayload) else None)
            elif op == "adopt_session":
                try:
                    payload = SlotPayload.from_bytes(arg["wire"])
                except MigrationError:
                    out = False
                else:
                    out = bool(eng.adopt_session(arg["sid"], payload))
            elif op == "drop_session":
                eng.sessions.resume(arg["sid"])
                out = True
            elif op == "ping":
                out = True
            else:
                raise TransportError(f"unknown rpc op {op!r}")
            conn.send_bytes(msg_to_bytes("reply", (seq, True, out)))
        except MigrationError as e:
            conn.send_bytes(msg_to_bytes("reply", (seq, False,
                                                   ("migration", str(e)))))
        except Exception as e:
            conn.send_bytes(msg_to_bytes("reply", (seq, False,
                                                   ("error", repr(e)))))

    def stats() -> dict:
        return {
            "free_slots": sum(s is None for s in eng.slots),
            "total_slots": len(eng.slots),
            "queue": len(eng.waiting),
            "kv_headroom": eng.kv_headroom(),
            "sessions": list(eng.sessions.ids()),
            "healthy": eng.healthy,
            "counters": {
                "decode_tokens": eng.decode_tokens,
                "prefill_tokens": eng.prefill_tokens,
                "encode_tokens": eng.encode_tokens,
                "prefix_hits": eng.prefix_hits,
                "prefix_hit_tokens": eng.prefix_hit_tokens,
                "resumed_sessions": eng.resumed_sessions,
                "resumed_tokens": eng.resumed_tokens,
                "parks": eng.parks,
                "drafted_tokens": eng.drafted_tokens,
                "accepted_tokens": eng.accepted_tokens,
                "spec_rounds": eng.spec_rounds},
        }

    conn.send_bytes(msg_to_bytes("ready", stats()))
    last_stats = time.monotonic()
    running = True
    try:
        while running:
            busy = bool(eng.waiting) or any(
                s is not None for s in eng.slots)
            # drain commands; when idle, block briefly so the worker
            # doesn't spin a core waiting for work
            while conn.poll(0.0 if busy else 0.02):
                kind, payload = msg_from_bytes(conn.recv_bytes())
                if kind == "stop":
                    running = False
                    break
                if kind == "submit":
                    eng.submit(payload["rid"], payload["tokens"],
                               max_new=payload["max_new"],
                               extras=payload["extras"],
                               deadline=payload["deadline"],
                               session=payload["session"])
                elif kind == "cancel":
                    eng.cancel(payload)
                elif kind == "throttle":
                    eng.throttle = float(payload)
                elif kind == "rpc":
                    handle_rpc(*payload)
                busy = True  # a command may have created work
            if not running:
                break
            if eng.waiting or any(s is not None for s in eng.slots):
                eng.step()
            if events:
                conn.send_bytes(msg_to_bytes("events", events))
                events = []
            fins = None
            if eng.finished:
                fins = [(st.rid, list(st.generated), st.t_done)
                        for st in eng.finished]
                eng.finished.clear()
                conn.send_bytes(msg_to_bytes("fin", fins))
            now = time.monotonic()
            if fins is not None or now - last_stats > 0.05:
                conn.send_bytes(msg_to_bytes("stats", stats()))
                last_stats = now
    except (EOFError, OSError, BrokenPipeError):
        pass  # parent went away
    except Exception as e:
        try:
            conn.send_bytes(msg_to_bytes("died", repr(e)))
        except Exception:
            pass
    finally:
        conn.close()


class ProcessTransport:
    """A replica in a spawn-context worker process behind a duplex pipe.

    The parent mirrors the worker's utilization (slots, queue, KV
    headroom, parked session ids, counters) from its periodic stats
    messages — observation reads are mirror-fresh, never blocking RPCs.
    A dead worker (crash, closed pipe, RPC timeout) flips ``alive``; the
    owning pool then reports the replica's in-flight rids as *lost* so
    the runtime's shared failure path resubmits them to siblings.
    """

    kind = "process"
    supports_restore = False

    def __init__(self, spec: ReplicaSpec, start_timeout_s: float = 120.0,
                 rpc_timeout_s: float = 60.0):
        self.spec = spec
        self.alive = True
        self.rpc_timeout_s = rpc_timeout_s
        self._rpc_seq = 0
        self._live_rids: set = set()
        self._pending_fins: List[FinishedSeq] = []
        self._pending_lost: List[int] = []
        self._hooks = (None, None, None, None)
        self._stats: Dict[str, Any] = {
            "free_slots": spec.serving.max_batch,
            "total_slots": spec.serving.max_batch,
            "queue": 0, "kv_headroom": 1.0, "sessions": [],
            "healthy": True, "counters": {}}
        # parent-side model config twin (for patch geometry / embed bytes
        # without a round trip) — the worker builds the same reduced config
        from repro.configs import reduced_config
        self.cfg = reduced_config(spec.model).replace(dtype=spec.dtype)
        ctx = mp.get_context("spawn")
        self._conn, child = ctx.Pipe(duplex=True)
        self._proc = ctx.Process(
            target=_worker_main,
            args=(child, msg_to_bytes("spec", spec)),
            name=f"tier-replica-{spec.name}", daemon=True)
        self._proc.start()
        child.close()
        # block until the worker's engine is built: submissions before
        # "ready" would race construction failures
        deadline = time.monotonic() + start_timeout_s
        while True:
            if self._conn.poll(0.1):
                try:
                    kind, payload = msg_from_bytes(self._conn.recv_bytes())
                except (EOFError, OSError) as e:
                    # spawn failed before the worker could report (e.g. a
                    # non-importable __main__): surface a TransportError
                    self._mark_dead()
                    raise TransportError(
                        f"replica {spec.name} died during spawn: "
                        f"{e!r}") from e
                if kind == "ready":
                    self._stats.update(payload)
                    break
                if kind == "died":
                    self._mark_dead()
                    raise TransportError(
                        f"replica {spec.name} failed to start: {payload}")
            if time.monotonic() > deadline:
                self._mark_dead()
                raise TransportError(
                    f"replica {spec.name} start timeout")

    @property
    def serving(self) -> ServingConfig:
        return self.spec.serving

    def wire_hooks(self, on_admit, on_token, on_warm, on_park) -> None:
        self._hooks = (on_admit, on_token, on_warm, on_park)

    # -- plumbing -----------------------------------------------------------

    def _mark_dead(self) -> None:
        if not self.alive:
            return
        self.alive = False
        self._pending_lost.extend(sorted(self._live_rids))
        self._live_rids.clear()

    def _send(self, kind: str, payload: Any) -> None:
        if not self.alive:
            raise TransportError(f"replica {self.spec.name} is dead")
        try:
            self._conn.send_bytes(msg_to_bytes(kind, payload))
        except (OSError, BrokenPipeError, ValueError) as e:
            self._mark_dead()
            raise TransportError(
                f"replica {self.spec.name} pipe broken: {e}") from e

    def _dispatch(self, kind: str, payload: Any) -> None:
        """Route one inbound message (events/fin/stats/died)."""
        if kind == "events":
            on_admit, on_token, on_warm, on_park = self._hooks
            for ev in payload:
                if ev[0] == "admit" and on_admit:
                    on_admit(ev[1], ev[2])
                elif ev[0] == "token" and on_token:
                    on_token(ev[1], ev[2], ev[3])
                elif ev[0] == "warm" and on_warm:
                    on_warm(ev[1], ev[2], ev[3], ev[4])
                elif ev[0] == "park" and on_park:
                    on_park(ev[1], ev[2])
        elif kind == "fin":
            for rid, generated, t_done in payload:
                self._live_rids.discard(rid)
                self._pending_fins.append(
                    FinishedSeq(rid, list(generated), t_done))
        elif kind == "stats":
            self._stats.update(payload)
        elif kind == "died":
            self._mark_dead()

    def _drain(self) -> None:
        try:
            while self.alive and self._conn.poll(0.0):
                kind, payload = msg_from_bytes(self._conn.recv_bytes())
                if kind == "reply":
                    continue  # stale reply from a timed-out RPC
                self._dispatch(kind, payload)
        except (EOFError, OSError, BrokenPipeError, TransportError):
            self._mark_dead()
        if self.alive and not self._proc.is_alive():
            self._mark_dead()

    def _rpc(self, op: str, **arg):
        self._rpc_seq += 1
        seq = self._rpc_seq
        self._send("rpc", (seq, op, arg))
        deadline = time.monotonic() + self.rpc_timeout_s
        while time.monotonic() < deadline:
            try:
                if not self._conn.poll(0.05):
                    continue
                kind, payload = msg_from_bytes(self._conn.recv_bytes())
            except (EOFError, OSError, BrokenPipeError) as e:
                self._mark_dead()
                raise TransportError(
                    f"replica {self.spec.name} died mid-RPC") from e
            if kind == "reply":
                rseq, ok, out = payload
                if rseq != seq:
                    continue  # stale reply from an earlier timeout
                if ok:
                    return out
                tag, detail = out
                if tag == "migration":
                    raise MigrationError(detail)
                raise TransportError(f"{op} failed on "
                                     f"{self.spec.name}: {detail}")
            self._dispatch(kind, payload)
        self._mark_dead()
        raise TransportError(f"rpc {op} timed out on {self.spec.name}")

    # -- request plane ------------------------------------------------------

    def submit(self, rid: int, tokens, max_new: int, extras,
               deadline, session) -> None:
        self._send("submit", {"rid": rid, "tokens": np.asarray(tokens),
                              "max_new": max_new, "extras": extras,
                              "deadline": deadline, "session": session})
        self._live_rids.add(rid)

    def cancel(self, rid: int) -> None:
        self._live_rids.discard(rid)
        if self.alive:
            try:
                self._send("cancel", rid)
            except TransportError:
                pass  # already dead: the rid is gone either way

    def poll(self) -> Tuple[List[FinishedSeq], bool, List[int]]:
        self._drain()
        fins, self._pending_fins = self._pending_fins, []
        lost, self._pending_lost = self._pending_lost, []
        return fins, bool(self._live_rids), lost

    # -- observation (mirror-fresh, non-blocking) ---------------------------

    def free_slots(self) -> int:
        return int(self._stats["free_slots"]) if self.alive else 0

    def total_slots(self) -> int:
        return int(self._stats["total_slots"])

    def queue_len(self) -> int:
        return int(self._stats["queue"]) if self.alive else 0

    def kv_headroom(self) -> float:
        return float(self._stats["kv_headroom"]) if self.alive else 0.0

    def occupancy(self) -> int:
        # live rids the parent actually submitted: robust against a stale
        # stats mirror between heartbeats
        return len(self._live_rids)

    def rids(self) -> List[int]:
        return sorted(self._live_rids)

    def slot_rids(self) -> List[int]:
        return []  # no slot-granular visibility across the pipe

    def decode_slots(self) -> List[Tuple[int, int]]:
        return []  # preemption scans only local replicas

    def prefix_hit_len(self, tokens, extras_fp: bytes) -> int:
        return 0  # affinity probe is local-only; process picks by load

    def counters(self) -> Dict[str, int]:
        return dict(self._stats.get("counters", {}))

    @property
    def healthy(self) -> bool:
        return self.alive and bool(self._stats.get("healthy", True))

    def heartbeat_ok(self) -> bool:
        return self.alive and self._proc.is_alive()

    def set_throttle(self, mult: float) -> None:
        if self.alive:
            try:
                self._send("throttle", float(mult))
            except TransportError:
                pass

    # -- partial offload ----------------------------------------------------

    def encode_image(self, image, num_patches: int = 0,
                     frontend_dim: int = 0):
        return self._rpc("encode", image=np.asarray(image),
                         num_patches=num_patches, frontend_dim=frontend_dim)

    # -- slot / session wire ------------------------------------------------

    def extract_wire(self, rid: int, *, remove: bool = False) -> bytes:
        wire = self._rpc("extract", rid=rid, remove=remove)
        if remove:
            self._live_rids.discard(rid)
        return wire

    def inject_wire(self, wire: bytes) -> None:
        self._rpc("inject", wire=wire)

    def has_session(self, sid: str) -> bool:
        return self.alive and sid in self._stats.get("sessions", [])

    def session_ids(self) -> List[str]:
        return list(self._stats.get("sessions", [])) if self.alive else []

    def session_count(self) -> int:
        return len(self.session_ids())

    def resume_session_wire(self, sid: str) -> Optional[bytes]:
        try:
            return self._rpc("resume_session", sid=sid)
        except TransportError:
            return None

    def adopt_session_wire(self, sid: str, wire: bytes) -> bool:
        try:
            return bool(self._rpc("adopt_session", sid=sid, wire=wire))
        except TransportError:
            return False

    def drop_session(self, sid: str) -> None:
        try:
            self._rpc("drop_session", sid=sid)
        except TransportError:
            pass

    # -- fault discipline ---------------------------------------------------

    def snapshot(self) -> dict:
        raise TransportError(
            "process replicas have no host-side snapshot; chaos injection "
            "(fail_rate / crash plans) requires the local transport")

    def restore(self, snap: dict) -> None:
        raise TransportError("process replicas cannot restore")

    def close(self) -> None:
        if self.alive:
            try:
                self._send("stop", None)
            except TransportError:
                pass
        self.alive = False
        self._proc.join(timeout=5.0)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=5.0)
        try:
            self._conn.close()
        except OSError:
            pass
