"""Discrete-event simulation of the paper's edge-cloud testbed (§4).

Stations (edge GPU, cloud GPU, WAN uplink) are FIFO queues with service times
from the analytic cost model over the REAL model configs; the scheduler in
the loop is the real MoA-Off implementation (same code path that serves the
live engine). Fault tolerance is exercised in-simulation: nodes fail with a
configurable rate (heartbeat-detected, requests retried) and slow stragglers
are hedged to the other tier.

Outputs per policy: latency distribution, accuracy, per-tier compute
(FLOP·s used) and memory (byte·s) overheads — everything Table 1 / Fig. 3 /
Fig. 4 need.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.config import ModelConfig, PolicyConfig, SimConfig
from repro.configs import get_config
from repro.core.baselines import make_policy
from repro.core.request import Decision, ModalityInput, Outcome, Request
from repro.core.scheduler import MoAOffScheduler
from repro.serving import cost_model as cm
from repro.serving.accuracy_model import VQAV2, AccuracyModel


@dataclass(order=True)
class Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


class Station:
    """FIFO multi-server station with failure injection + utilization stats."""

    def __init__(self, name: str, servers: int, fail_rate: float = 0.0):
        self.name = name
        self.servers = servers
        self.busy = 0
        self.queue: List[dict] = []
        self.fail_rate = fail_rate
        self.busy_time = 0.0
        self._last_t = 0.0
        self.flops = 0.0
        self.mem_byte_s = 0.0

    def utilization_update(self, t: float):
        self.busy_time += self.busy / max(self.servers, 1) * (t - self._last_t)
        self._last_t = t

    # a station "at capacity" = all servers busy + ~3 queued per server;
    # ℓ = 0.8 (the Eq.5 gate) then corresponds to a ~2-deep queue
    QUEUE_TOLERANCE = 4

    @property
    def load(self) -> float:
        denom = max(self.servers, 1) * self.QUEUE_TOLERANCE
        return min(1.0, (self.busy + len(self.queue)) / denom)


class EdgeCloudSimulator:
    def __init__(self, sim_cfg: SimConfig, policy_name: str = "moa-off",
                 policy_cfg: PolicyConfig = PolicyConfig(),
                 acc_model: AccuracyModel = VQAV2,
                 fail_rate: float = 0.0, hedge_after_s: float = 0.0,
                 cloud_servers: int = 4, edge_servers: int = 1):
        self.cfg = sim_cfg
        self.rng = np.random.default_rng(sim_cfg.seed)
        self.policy_name = policy_name
        self.scheduler = MoAOffScheduler(policy=make_policy(policy_name,
                                                            policy_cfg))
        self.acc = acc_model
        self.edge_model = get_config(sim_cfg.edge.model)
        self.cloud_model = get_config(sim_cfg.cloud.model)
        self.edge = Station("edge", edge_servers, fail_rate)
        self.cloud = Station("cloud", cloud_servers, fail_rate)
        self.link = Station("link", 1)
        self.hedge_after_s = hedge_after_s
        self.events: List[Event] = []
        self._seq = itertools.count()
        self.outcomes: List[Outcome] = []
        self.t = 0.0

    # ------------------------------------------------------------------

    def _push(self, t: float, kind: str, **payload):
        heapq.heappush(self.events, Event(t, next(self._seq), kind, payload))

    def _station(self, tier: str) -> Station:
        return self.edge if tier == "edge" else self.cloud

    def _model(self, tier: str) -> ModelConfig:
        return self.edge_model if tier == "edge" else self.cloud_model

    def _tier_cfg(self, tier: str):
        return self.cfg.edge if tier == "edge" else self.cfg.cloud

    # ------------------------------------------------------------------

    def _service_request(self, job: dict) -> Tuple[float, float, float]:
        """(service_seconds, flops, mem_byte_s) for one fused inference."""
        req: Request = job["request"]
        tier = job["tier"]
        mcfg = self._model(tier)
        tcfg = self._tier_cfg(tier)
        text_tokens = 0
        image_tokens = 0
        for m in req.modalities.values():
            n = cm.modality_tokens(mcfg, m)
            if m.kind == "image":
                image_tokens += n
            else:
                text_tokens += n
        # the paper's "severe latency tail typical of edge-only models
        # struggling with difficult samples": the weak model rambles /
        # re-derives on inputs beyond its capability knee -> decode length
        # grows with difficulty (easy inputs run at full speed)
        decode_tokens = req.decode_tokens
        if tier == "edge":
            decode_tokens = int(decode_tokens
                                * (1.0 + 14.0 * max(0.0, req.difficulty - 0.45)))
        # PARTIAL offloading (§3.2): modalities routed to the edge of a
        # cloud-fused request are ENCODED at the edge — only their compact
        # embeddings ride along, so the cloud never spends prefill FLOPs on
        # them. (This is MoA-Off's fine-grained scheduling; uniform policies
        # ship the whole request.)
        routes = job["decision"].routes
        if tier == "cloud" and any(r == "edge" for r in routes.values()):
            edge_cfg = self.edge_model
            edge_tc = self.cfg.edge
            off_text = sum(cm.modality_tokens(edge_cfg, m)
                           for nm, m in req.modalities.items()
                           if m.kind != "image" and routes.get(nm) == "edge")
            text_tokens = max(0, text_tokens - off_text)
            if off_text:
                enc = cm.prefill_flops(edge_cfg, off_text, 0)
                self.edge.flops += enc
                self.edge.mem_byte_s += 2.0 * enc  # ~bytes/flop of prefill
        costs = cm.request_phase_costs(mcfg, text_tokens, image_tokens,
                                       decode_tokens, tcfg)
        sec = costs["prefill"].seconds + costs["decode"].seconds
        flops = costs["prefill"].flops + costs["decode"].flops
        kv = cm._kv_bytes_per_token(mcfg) * (text_tokens + image_tokens
                                             + req.decode_tokens)
        mem_byte_s = (cm.weights_bytes(mcfg) / max(self._station(tier).servers, 1)
                      + kv) * sec
        return sec, flops, mem_byte_s

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        self._push(req.arrival_s, "arrival", request=req)

    def _observe(self):
        self.scheduler.observe(edge_load=self.edge.load,
                               cloud_load=self.cloud.load,
                               bandwidth_bps=self.cfg.bandwidth_bps)
        self.scheduler.estimator.observe_queues(
            self.edge.busy + len(self.edge.queue),
            self.cloud.busy + len(self.cloud.queue))

    def _on_arrival(self, ev: Event):
        req: Request = ev.payload["request"]
        self._observe()
        decision = self.scheduler.route(req)
        # score cost: the modality-aware module runs on the edge CPU/NPU —
        # orders of magnitude below model inference (§4.2.3); modelled as a
        # fixed sub-millisecond cost on the request path.
        score_cost = 5e-4 if self.policy_name.startswith("moa-off") else 0.0
        fusion_tier = "cloud" if decision.any_cloud else "edge"
        job = {"request": req, "decision": decision, "tier": fusion_tier,
               "t_start": ev.t, "retries": 0, "hedged": False,
               "done": False}
        # bytes that must cross the WAN: payloads of cloud-routed modalities
        up_bytes = sum(m.size_bytes for name, m in req.modalities.items()
                       if decision.routes.get(name) == "cloud")
        if fusion_tier == "cloud" and up_bytes == 0:
            up_bytes = 2048  # at minimum the text/prompt goes up
        job["transfer_bytes"] = up_bytes
        if up_bytes > 0:
            self._enqueue_link(ev.t + score_cost, job)
        else:
            self._enqueue_station(ev.t + score_cost, job)
        if self.hedge_after_s > 0:
            self._push(ev.t + self.hedge_after_s, "hedge_check", job=job)

    # -- WAN link ----------------------------------------------------------

    def _enqueue_link(self, t: float, job: dict):
        self.link.utilization_update(t)
        if self.link.busy < self.link.servers:
            self.link.busy += 1
            sec = cm.transfer_seconds(job["transfer_bytes"],
                                      self.cfg.bandwidth_bps, self.cfg.rtt_s)
            self._push(t + sec, "transfer_done", job=job)
        else:
            self.link.queue.append({"job": job})

    def _on_transfer_done(self, ev: Event):
        job = ev.payload["job"]
        self.link.utilization_update(ev.t)
        self.link.busy -= 1
        if self.link.queue:
            nxt = self.link.queue.pop(0)["job"]
            self.link.busy += 1
            sec = cm.transfer_seconds(nxt["transfer_bytes"],
                                      self.cfg.bandwidth_bps, self.cfg.rtt_s)
            self._push(ev.t + sec, "transfer_done", job=nxt)
        self._enqueue_station(ev.t, job)

    # -- compute stations ----------------------------------------------------

    def _enqueue_station(self, t: float, job: dict):
        st = self._station(job["tier"])
        st.utilization_update(t)
        if st.busy < st.servers:
            self._start_service(t, st, job)
        else:
            st.queue.append(job)

    def _start_service(self, t: float, st: Station, job: dict):
        st.busy += 1
        sec, flops, mem = self._service_request(job)
        job["service_s"] = sec
        # fault injection: the node serving this job dies mid-flight and the
        # failure is detected after a heartbeat timeout, then retried
        if st.fail_rate > 0 and self.rng.random() < st.fail_rate:
            detect = 2.0  # heartbeat timeout
            self._push(t + detect, "service_failed", job=job, station=st.name)
        else:
            self._push(t + sec, "service_done", job=job, station=st.name)

    def _next_from_queue(self, t: float, st: Station):
        st.utilization_update(t)
        st.busy -= 1
        if st.queue:
            job = st.queue.pop(0)
            self._start_service(t, st, job)

    def _on_service_failed(self, ev: Event):
        st = self.edge if ev.payload["station"] == "edge" else self.cloud
        job = ev.payload["job"]
        self._next_from_queue(ev.t, st)
        if job["done"]:
            return
        job["retries"] += 1
        self._enqueue_station(ev.t, job)  # retry (possibly behind queue)

    def _on_hedge_check(self, ev: Event):
        job = ev.payload["job"]
        if job["done"] or job.get("in_service_done"):
            return
        # straggler mitigation: duplicate to the other tier; first wins
        if not job["hedged"]:
            clone = dict(job)
            clone["tier"] = "cloud" if job["tier"] == "edge" else "edge"
            clone["hedged"] = True
            job["hedged"] = True
            clone["transfer_bytes"] = 0
            self._enqueue_station(ev.t, clone)

    def _on_service_done(self, ev: Event):
        st = self.edge if ev.payload["station"] == "edge" else self.cloud
        job = ev.payload["job"]
        self._next_from_queue(ev.t, st)
        if job["done"]:
            return  # the hedged twin finished first
        job["done"] = True
        req: Request = job["request"]
        tier = ev.payload["station"]
        sec, flops, mem = job["service_s"], *self._resources(job)
        st.flops += flops
        st.mem_byte_s += mem
        down = self.cfg.rtt_s if tier == "cloud" else 0.0
        latency = ev.t + down - req.arrival_s
        on_time = latency <= req.slo_s
        correct = self.acc.sample(self.rng, req.difficulty, tier, on_time)
        self.scheduler.observe(latency_s=latency)
        self.outcomes.append(Outcome(
            rid=req.rid, latency_s=latency, routes=job["decision"].routes,
            correct=correct,
            edge_flops=flops if tier == "edge" else 0.0,
            cloud_flops=flops if tier == "cloud" else 0.0,
            edge_mem_bytes=mem if tier == "edge" else 0.0,
            cloud_mem_bytes=mem if tier == "cloud" else 0.0,
            transfer_bytes=job["transfer_bytes"], hedged=job["hedged"],
            retries=job["retries"]))

    def _resources(self, job):
        _, flops, mem = self._service_request(job)
        return flops, mem

    # ------------------------------------------------------------------

    def run(self) -> List[Outcome]:
        handlers = {
            "arrival": self._on_arrival,
            "transfer_done": self._on_transfer_done,
            "service_done": self._on_service_done,
            "service_failed": self._on_service_failed,
            "hedge_check": self._on_hedge_check,
        }
        while self.events:
            ev = heapq.heappop(self.events)
            self.t = ev.t
            handlers[ev.kind](ev)
        return self.outcomes

    # ------------------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        lats = np.array([o.latency_s for o in self.outcomes])
        acc = np.mean([o.correct for o in self.outcomes])
        edge_f = sum(o.edge_flops for o in self.outcomes)
        cloud_f = sum(o.cloud_flops for o in self.outcomes)
        edge_m = sum(o.edge_mem_bytes for o in self.outcomes)
        cloud_m = sum(o.cloud_mem_bytes for o in self.outcomes)
        return {
            "accuracy": float(acc),
            "mean_latency_s": float(lats.mean()),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "p99_latency_s": float(np.percentile(lats, 99)),
            "edge_flops": edge_f, "cloud_flops": cloud_f,
            "total_flops": edge_f + cloud_f,
            "edge_mem_byte_s": edge_m, "cloud_mem_byte_s": cloud_m,
            "total_mem_byte_s": edge_m + cloud_m,
            "edge_util": self.edge.busy_time / max(self.t, 1e-9),
            "cloud_util": self.cloud.busy_time / max(self.t, 1e-9),
            "frac_edge": float(np.mean([not any(
                r == "cloud" for r in o.routes.values())
                for o in self.outcomes])),
            "hedged": float(np.mean([o.hedged for o in self.outcomes])),
            "retries": float(np.mean([o.retries for o in self.outcomes])),
        }
