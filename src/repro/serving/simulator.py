"""Discrete-event simulation of an N-tier edge–cloud cluster (§4,
generalized from the paper's two-tier testbed).

Stations (one FIFO multi-server queue per tier, one WAN link per remote
tier) take service times from the analytic cost model over the REAL model
configs; the scheduler in the loop is the real MoA-Off implementation (same
code path that serves the live engine). Fault tolerance is exercised
in-simulation: nodes fail with a configurable rate (heartbeat-detected,
requests retried) and slow stragglers are hedged to the least-loaded other
tier.

The topology comes from ``ClusterTopology`` (config arg or ``--topology``
name); with none given the paper's edge/cloud pair is built from the legacy
``SimConfig`` fields, reproducing the original behavior and metric keys
exactly. Outputs per policy: latency distribution, accuracy, per-tier
compute (FLOP·s used) and memory (byte·s) overheads — everything Table 1 /
Fig. 3 / Fig. 4 need.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.config import (ClusterTopology, ModelConfig, PolicyConfig,
                          SimConfig, TierSpec, two_tier_topology)
from repro.configs import get_config
from repro.core.baselines import make_policy
from repro.core.request import Decision, ModalityInput, Outcome, Request
from repro.core.scheduler import MoAOffScheduler
from repro.serving import cost_model as cm
from repro.serving.accuracy_model import VQAV2, AccuracyModel


@dataclass(order=True)
class Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    payload: dict = field(compare=False, default_factory=dict)


class Station:
    """FIFO multi-server station with failure injection + utilization stats."""

    def __init__(self, name: str, servers: int, fail_rate: float = 0.0):
        self.name = name
        self.servers = servers
        self.busy = 0
        self.queue: List[dict] = []
        self.fail_rate = fail_rate
        self.busy_time = 0.0
        self._last_t = 0.0
        self.flops = 0.0
        self.mem_byte_s = 0.0

    def utilization_update(self, t: float):
        self.busy_time += self.busy / max(self.servers, 1) * (t - self._last_t)
        self._last_t = t

    # a station "at capacity" = all servers busy + ~3 queued per server;
    # ℓ = 0.8 (the Eq.5 gate) then corresponds to a ~2-deep queue
    QUEUE_TOLERANCE = 4

    @property
    def load(self) -> float:
        denom = max(self.servers, 1) * self.QUEUE_TOLERANCE
        return min(1.0, (self.busy + len(self.queue)) / denom)


class ClusterSimulator:
    """Cluster runtime simulator over an arbitrary ``ClusterTopology``."""

    def __init__(self, sim_cfg: SimConfig, policy_name: str = "moa-off",
                 policy_cfg: PolicyConfig = PolicyConfig(),
                 acc_model: AccuracyModel = VQAV2,
                 fail_rate: float = 0.0, hedge_after_s: float = 0.0,
                 cloud_servers: int = 4, edge_servers: int = 1,
                 topology: Optional[ClusterTopology] = None):
        self.cfg = sim_cfg
        topo = topology or sim_cfg.topology
        if topo is not None and (edge_servers != 1 or cloud_servers != 4):
            raise ValueError(
                "edge_servers/cloud_servers only apply to the legacy "
                "two-tier default; set TierSpec.servers on the topology "
                "instead")
        topo = topo or two_tier_topology(
            sim_cfg.edge, sim_cfg.cloud, sim_cfg.bandwidth_bps,
            sim_cfg.rtt_s, edge_servers=edge_servers,
            cloud_servers=cloud_servers)
        self.topology = topo
        self.rng = np.random.default_rng(sim_cfg.seed)
        self.policy_name = policy_name
        self.scheduler = MoAOffScheduler(policy=make_policy(
            policy_name, policy_cfg, topology=topo))
        self.acc = acc_model
        self.specs: Dict[str, TierSpec] = {t.name: t for t in topo.tiers}
        self.models: Dict[str, ModelConfig] = {
            t.name: get_config(t.model) for t in topo.tiers}
        self.stations: Dict[str, Station] = {
            t.name: Station(t.name, t.servers, fail_rate) for t in topo.tiers}
        self.links: Dict[str, Station] = {
            t.name: Station(f"link:{t.name}", 1)
            for t in topo.tiers if t.is_remote}
        # legacy attribute views (None when the topology lacks the name)
        self.edge = self.stations.get("edge")
        self.cloud = self.stations.get("cloud")
        self.link = self.links.get("cloud")
        self.hedge_after_s = hedge_after_s
        self.encode_flops: Dict[str, float] = {}  # partial-offload side work
        self.events: List[Event] = []
        self._seq = itertools.count()
        self.outcomes: List[Outcome] = []
        self.t = 0.0

    # ------------------------------------------------------------------

    def _push(self, t: float, kind: str, **payload):
        heapq.heappush(self.events, Event(t, next(self._seq), kind, payload))

    def _station(self, tier: str) -> Station:
        return self.stations[tier]

    def _model(self, tier: str) -> ModelConfig:
        return self.models[tier]

    def _tier_cfg(self, tier: str) -> TierSpec:
        return self.specs[tier]

    # ------------------------------------------------------------------

    def _service_request(self, job: dict) -> Tuple[float, float, float]:
        """(service_seconds, flops, mem_byte_s) for one fused inference.

        Pure function of (request, routes, serving tier) — all accounting
        side effects live with the callers, so it can be re-evaluated (e.g.
        for a hedged clone on another tier) without double charging.
        """
        req: Request = job["request"]
        tier = job["tier"]
        mcfg = self._model(tier)
        tcfg = self._tier_cfg(tier)
        text_tokens = 0
        image_tokens = 0
        for m in req.modalities.values():
            n = cm.modality_tokens(mcfg, m)
            if m.kind == "image":
                image_tokens += n
            else:
                text_tokens += n
        # the paper's "severe latency tail typical of edge-only models
        # struggling with difficult samples": a weak model rambles /
        # re-derives on inputs beyond its capability knee -> decode length
        # grows with difficulty, scaled by how far the tier sits from
        # cloud-class capability (easy inputs run at full speed)
        decode_tokens = req.decode_tokens
        weakness = 1.0 - tcfg.capability
        if weakness > 0:
            decode_tokens = int(decode_tokens * (
                1.0 + 14.0 * weakness * max(0.0, req.difficulty - 0.45)))
        # PARTIAL offloading (§3.2): modalities routed to another tier of a
        # fused request are ENCODED there — only their compact embeddings
        # ride along, so the serving tier never spends prefill FLOPs on
        # them. (This is MoA-Off's fine-grained scheduling; uniform policies
        # ship the whole request.) The discount belongs to the PLANNED
        # fusion tier only: a hedged clone running elsewhere has no
        # embeddings waiting for it and must prefill everything.
        if tier == job.get("fusion", tier):
            routes = job["decision"].routes
            off_text = sum(cm.modality_tokens(mcfg, m)
                           for nm, m in req.modalities.items()
                           if m.kind != "image"
                           and routes.get(nm, tier) != tier)
            text_tokens = max(0, text_tokens - off_text)
        costs = cm.request_phase_costs(mcfg, text_tokens, image_tokens,
                                       decode_tokens, tcfg)
        sec = costs["prefill"].seconds + costs["decode"].seconds
        flops = costs["prefill"].flops + costs["decode"].flops
        kv = cm._kv_bytes_per_token(mcfg) * (text_tokens + image_tokens
                                             + req.decode_tokens)
        mem_byte_s = (cm.weights_bytes(mcfg) / max(tcfg.servers, 1)
                      + kv) * sec
        return sec, flops, mem_byte_s

    def _encode_charges(self, req: Request, routes: Dict[str, str],
                        fusion: str) -> List[Tuple[str, float, float]]:
        """Partial-offload encode work: (tier, flops, mem_byte_s) for every
        non-image modality encoded away from the fusion tier. Charged ONCE
        per request, at arrival, to the encoding tier's station counters."""
        charges = []
        for nm, m in req.modalities.items():
            routed = routes.get(nm, fusion)
            if m.kind == "image" or routed == fusion:
                continue
            enc_cfg = self._model(routed)
            spec = self._tier_cfg(routed)
            toks = cm.modality_tokens(enc_cfg, m)
            if toks <= 0:
                continue
            enc = cm.request_phase_costs(enc_cfg, toks, 0, 0, spec)["prefill"]
            kv = cm._kv_bytes_per_token(enc_cfg) * toks
            mem = (cm.weights_bytes(enc_cfg) / max(spec.servers, 1)
                   + kv) * enc.seconds
            charges.append((routed, enc.flops, mem))
        return charges

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        self._push(req.arrival_s, "arrival", request=req)

    def _observe(self):
        remote = self.topology.remote_tiers
        # the scalar b of Eq. 5 is the edge<->cloud WAN: the anchor remote
        # tier's uplink (per-tier uplinks ride in the bandwidths dict)
        wan = (self.topology.default_remote.uplink_bps if remote
               else self.cfg.bandwidth_bps)
        self.scheduler.observe(
            loads={name: st.load for name, st in self.stations.items()},
            bandwidth_bps=wan,
            bandwidths={t.name: t.uplink_bps for t in remote})
        self.scheduler.estimator.observe_queue_depths(
            {name: st.busy + len(st.queue)
             for name, st in self.stations.items()})

    def _on_arrival(self, ev: Event):
        req: Request = ev.payload["request"]
        self._observe()
        decision = self.scheduler.route(req)
        # score cost: the modality-aware module runs on the edge CPU/NPU —
        # orders of magnitude below model inference (§4.2.3); modelled as a
        # fixed sub-millisecond cost on the request path.
        score_cost = 5e-4 if self.policy_name.startswith("moa-off") else 0.0
        fusion = self.topology.fusion_tier(decision.routes)
        # "done" is a shared cell so a hedged clone finishing first also
        # retires the original (and vice versa) — exactly one Outcome/request
        job = {"request": req, "decision": decision, "tier": fusion,
               "fusion": fusion, "t_start": ev.t, "retries": 0,
               "hedged": False, "done": [False]}
        for tier, enc_f, enc_m in self._encode_charges(req, decision.routes,
                                                       fusion):
            st = self.stations[tier]
            st.flops += enc_f
            st.mem_byte_s += enc_m
            self.encode_flops[tier] = self.encode_flops.get(tier, 0.0) + enc_f
        # bytes that must cross a WAN: payloads of remote-routed modalities,
        # tallied per remote tier (their links transfer in parallel)
        remote_bytes: Dict[str, float] = {}
        for name, m in req.modalities.items():
            routed = decision.routes.get(name, fusion)
            if self.specs[routed].is_remote:
                remote_bytes[routed] = (remote_bytes.get(routed, 0.0)
                                        + m.size_bytes)
        if self.specs[fusion].is_remote:
            # the fusion tier's own link carries at minimum the text/prompt
            remote_bytes[fusion] = remote_bytes.get(fusion, 0.0) or 2048.0
        job["transfer_bytes"] = sum(remote_bytes.values())
        if remote_bytes:
            # each remote tier's payload crosses its OWN uplink; the links
            # run in parallel and service starts when the last one lands
            # (sorted for deterministic event order)
            for tname, nbytes in sorted(remote_bytes.items()):
                self._enqueue_link(ev.t + score_cost, tname, job, nbytes)
        else:
            self._enqueue_station(ev.t + score_cost, job)
        if self.hedge_after_s > 0:
            self._push(ev.t + self.hedge_after_s, "hedge_check", job=job)

    # -- WAN links ---------------------------------------------------------

    def _link_seconds(self, tier: str, num_bytes: float) -> float:
        spec = self.specs[tier]
        return cm.transfer_seconds(num_bytes, spec.uplink_bps, spec.rtt_s)

    def _enqueue_link(self, t: float, tier: str, job: dict,
                      num_bytes: float):
        """Queue one transfer (a job may hold several, one per remote tier
        its modalities route to); the job proceeds to its station only once
        every pending transfer has landed."""
        xfer = {"job": job, "tier": tier, "bytes": num_bytes}
        job["pending_transfers"] = job.get("pending_transfers", 0) + 1
        link = self.links[tier]
        link.utilization_update(t)
        if link.busy < link.servers:
            link.busy += 1
            sec = self._link_seconds(tier, num_bytes)
            self._push(t + sec, "transfer_done", xfer=xfer)
        else:
            link.queue.append(xfer)

    def _on_transfer_done(self, ev: Event):
        xfer = ev.payload["xfer"]
        link = self.links[xfer["tier"]]
        link.utilization_update(ev.t)
        link.busy -= 1
        if link.queue:
            nxt = link.queue.pop(0)
            link.busy += 1
            sec = self._link_seconds(nxt["tier"], nxt["bytes"])
            self._push(ev.t + sec, "transfer_done", xfer=nxt)
        job = xfer["job"]
        job["pending_transfers"] -= 1
        if job["pending_transfers"] == 0:
            self._enqueue_station(ev.t, job)

    # -- compute stations --------------------------------------------------

    def _enqueue_station(self, t: float, job: dict):
        st = self._station(job["tier"])
        st.utilization_update(t)
        if st.busy < st.servers:
            self._start_service(t, st, job)
        else:
            st.queue.append(job)

    def _start_service(self, t: float, st: Station, job: dict):
        st.busy += 1
        job["in_service"] = True
        # compute once per (job, tier) and cache — _on_service_done reads
        # the cached values, so resources are charged exactly once
        if job.get("cost_tier") != job["tier"]:
            sec, flops, mem = self._service_request(job)
            job.update(service_s=sec, service_flops=flops, service_mem=mem,
                       cost_tier=job["tier"])
        sec = job["service_s"]
        # fault injection: the node serving this job dies mid-flight and the
        # failure is detected after a heartbeat timeout, then retried
        if st.fail_rate > 0 and self.rng.random() < st.fail_rate:
            detect = 2.0  # heartbeat timeout
            self._push(t + detect, "service_failed", job=job, station=st.name)
        else:
            self._push(t + sec, "service_done", job=job, station=st.name)

    def _next_from_queue(self, t: float, st: Station):
        st.utilization_update(t)
        st.busy -= 1
        if st.queue:
            job = st.queue.pop(0)
            self._start_service(t, st, job)

    def _on_service_failed(self, ev: Event):
        st = self.stations[ev.payload["station"]]
        job = ev.payload["job"]
        self._next_from_queue(ev.t, st)
        if job["done"][0]:
            return
        job["retries"] += 1
        job["in_service"] = False
        self._enqueue_station(ev.t, job)  # retry (possibly behind queue)

    def _on_hedge_check(self, ev: Event):
        job = ev.payload["job"]
        # only genuinely queued/straggling jobs are hedged — a job already
        # being served (or finished) is left alone
        if job["done"][0] or job.get("in_service"):
            return
        if not job["hedged"]:
            others = [n for n in self.stations if n != job["tier"]]
            if not others:
                return
            # duplicate to the least-loaded other tier; first copy wins
            alt = min(others, key=lambda n: (self.stations[n].load, n))
            clone = dict(job)
            clone["tier"] = alt
            clone["hedged"] = True
            job["hedged"] = True
            # keep transfer_bytes: the original's WAN transfer already
            # happened, and the single Outcome must account for it even
            # when the clone wins
            clone["in_service"] = False
            self._enqueue_station(ev.t, clone)

    def _on_service_done(self, ev: Event):
        tier = ev.payload["station"]
        st = self.stations[tier]
        job = ev.payload["job"]
        self._next_from_queue(ev.t, st)
        if job["done"][0]:
            return  # the hedged twin finished first
        job["done"][0] = True
        req: Request = job["request"]
        sec = job["service_s"]
        flops, mem = job["service_flops"], job["service_mem"]
        st.flops += flops
        st.mem_byte_s += mem
        spec = self.specs[tier]
        down = spec.rtt_s if spec.is_remote else 0.0
        latency = ev.t + down - req.arrival_s
        on_time = latency <= req.slo_s
        correct = self.acc.sample(self.rng, req.difficulty, tier, on_time,
                                  capability=spec.capability)
        self.scheduler.observe(latency_s=latency)
        self.outcomes.append(Outcome(
            rid=req.rid, latency_s=latency, routes=job["decision"].routes,
            correct=correct, tier_flops={tier: flops},
            tier_mem_bytes={tier: mem},
            transfer_bytes=job["transfer_bytes"], hedged=job["hedged"],
            retries=job["retries"], served_tier=tier))

    # ------------------------------------------------------------------

    def run(self) -> List[Outcome]:
        handlers = {
            "arrival": self._on_arrival,
            "transfer_done": self._on_transfer_done,
            "service_done": self._on_service_done,
            "service_failed": self._on_service_failed,
            "hedge_check": self._on_hedge_check,
        }
        while self.events:
            ev = heapq.heappop(self.events)
            self.t = ev.t
            handlers[ev.kind](ev)
        return self.outcomes

    # ------------------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        lats = np.array([o.latency_s for o in self.outcomes])
        acc = np.mean([o.correct for o in self.outcomes])
        per_flops = {name: 0.0 for name in self.stations}
        per_mem = {name: 0.0 for name in self.stations}
        for o in self.outcomes:
            for t, v in o.tier_flops.items():
                per_flops[t] += v
            for t, v in o.tier_mem_bytes.items():
                per_mem[t] += v
        local = {t.name for t in self.topology.local_tiers}
        frac_local = float(np.mean([
            all(r in local for r in o.routes.values())
            for o in self.outcomes]))
        out = {
            "accuracy": float(acc),
            "mean_latency_s": float(lats.mean()),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "p99_latency_s": float(np.percentile(lats, 99)),
            "total_flops": sum(per_flops.values()),
            "total_mem_byte_s": sum(per_mem.values()),
            "frac_edge": frac_local,  # legacy name: fully-local fraction
            "frac_local": frac_local,
            "hedged": float(np.mean([o.hedged for o in self.outcomes])),
            "retries": float(np.mean([o.retries for o in self.outcomes])),
        }
        for name, st in self.stations.items():
            out[f"{name}_flops"] = per_flops[name]
            out[f"{name}_mem_byte_s"] = per_mem[name]
            out[f"{name}_util"] = st.busy_time / max(self.t, 1e-9)
        return out


# the original two-tier entry point: same class, topology defaulted from the
# legacy SimConfig edge/cloud pair
EdgeCloudSimulator = ClusterSimulator
