"""Discrete-event simulation of an N-tier edge–cloud cluster (§4,
generalized from the paper's two-tier testbed).

``ClusterSimulator`` is now a thin shell over the shared, event-driven
:class:`~repro.serving.runtime.ClusterRuntime` driven by its
:class:`~repro.serving.runtime.AnalyticBackend` — the SAME lifecycle state
machine that powers the live ``ClusterServer``, executed against a virtual
clock and the analytic cost model instead of real engines. Stations (one
FIFO multi-server queue per tier, one WAN link per remote tier) take service
times from the cost model over the REAL model configs; the scheduler in the
loop is the real MoA-Off implementation (same code path that serves the live
engine). Fault tolerance is exercised in-simulation: nodes fail with a
configurable rate (heartbeat-detected, requests retried) and slow stragglers
are hedged to the least-loaded other tier.

The topology comes from ``ClusterTopology`` (config arg or ``--topology``
name); with none given the paper's edge/cloud pair is built from the legacy
``SimConfig`` fields, reproducing the original behavior and metric keys
exactly (regression-locked against pre-refactor golden values in
``tests/test_runtime_parity.py``). Outputs per policy: latency distribution,
accuracy, per-tier compute (FLOP·s used) and memory (byte·s) overheads —
everything Table 1 / Fig. 3 / Fig. 4 need.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.config import (ClusterTopology, ModelConfig, PolicyConfig,
                          ResilienceConfig, ServingConfig, SimConfig,
                          SpecConfig, TierSpec, two_tier_topology)
from repro.core.baselines import make_policy
from repro.core.request import Outcome, Request
from repro.core.scheduler import MoAOffScheduler
from repro.serving.accuracy_model import VQAV2, AccuracyModel
from repro.serving.faults import FaultPlan
from repro.serving.runtime import (AnalyticBackend, ClusterRuntime, Event,
                                   Station)

__all__ = ["ClusterSimulator", "EdgeCloudSimulator", "Event", "Station"]


class ClusterSimulator:
    """Cluster runtime simulator over an arbitrary ``ClusterTopology``."""

    def __init__(self, sim_cfg: SimConfig, policy_name: str = "moa-off",
                 policy_cfg: PolicyConfig = PolicyConfig(),
                 acc_model: AccuracyModel = VQAV2,
                 fail_rate: float = 0.0, hedge_after_s: float = 0.0,
                 cloud_servers: int = 4, edge_servers: int = 1,
                 topology: Optional[ClusterTopology] = None,
                 migrate: bool = False, migrate_threshold: int = 0,
                 hedge_in_service: bool = False, sessions: bool = False,
                 session_move_threshold: int = 0,
                 prefix_cache_mb: float = 0.0,
                 session_cache_mb: float = 64.0,
                 max_context_tokens: Optional[int] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 serving_cfg: Optional[ServingConfig] = None,
                 spec: Optional[SpecConfig] = None,
                 audit: bool = False):
        # legacy-shim: a plan carrying only a Bernoulli rate compiles back
        # into the scalar knob, through the same rng stream as ever
        if fault_plan is not None and fail_rate == 0.0:
            fail_rate = fault_plan.fail_rate
        self.cfg = sim_cfg
        topo = topology or sim_cfg.topology
        if topo is not None and (edge_servers != 1 or cloud_servers != 4):
            raise ValueError(
                "edge_servers/cloud_servers only apply to the legacy "
                "two-tier default; set TierSpec.servers on the topology "
                "instead")
        topo = topo or two_tier_topology(
            sim_cfg.edge, sim_cfg.cloud, sim_cfg.bandwidth_bps,
            sim_cfg.rtt_s, edge_servers=edge_servers,
            cloud_servers=cloud_servers)
        self.topology = topo
        self.policy_name = policy_name
        self.scheduler = MoAOffScheduler(policy=make_policy(
            policy_name, policy_cfg, topology=topo))
        self.acc = acc_model
        self.backend = AnalyticBackend(
            topo, acc_model, seed=sim_cfg.seed, fail_rate=fail_rate,
            fallback_bandwidth_bps=sim_cfg.bandwidth_bps,
            prefix_cache_mb=prefix_cache_mb,
            session_cache_mb=session_cache_mb,
            max_context_tokens=max_context_tokens,
            serving=serving_cfg)
        self.runtime = ClusterRuntime(topo, self.scheduler, policy_name,
                                      self.backend,
                                      hedge_after_s=hedge_after_s,
                                      migrate=migrate,
                                      migrate_threshold=migrate_threshold,
                                      hedge_in_service=hedge_in_service,
                                      sessions=sessions,
                                      session_move_threshold=
                                      session_move_threshold,
                                      resilience=resilience,
                                      fault_plan=fault_plan, spec=spec,
                                      audit=audit)
        self.hedge_after_s = hedge_after_s
        # legacy attribute views (None when the topology lacks the name)
        self.edge = self.stations.get("edge")
        self.cloud = self.stations.get("cloud")
        self.link = self.links.get("cloud")

    # -- delegation views (legacy simulator surface) -----------------------

    @property
    def rng(self) -> np.random.Generator:
        return self.backend.rng

    @property
    def specs(self) -> Dict[str, TierSpec]:
        return self.backend.specs

    @property
    def models(self) -> Dict[str, ModelConfig]:
        return self.backend.models

    @property
    def stations(self) -> Dict[str, Station]:
        return self.backend.stations

    @property
    def links(self) -> Dict[str, Station]:
        return self.runtime.links

    @property
    def encode_flops(self) -> Dict[str, float]:
        return self.backend.encode_flops

    @property
    def events(self) -> List[Event]:
        return self.runtime.events

    @property
    def outcomes(self) -> List[Outcome]:
        return self.runtime.outcomes

    @property
    def t(self) -> float:
        return self.runtime.t

    # ------------------------------------------------------------------

    def submit(self, req: Request):
        self.runtime.submit(req)

    def run(self) -> List[Outcome]:
        return self.runtime.run()

    # ------------------------------------------------------------------

    def metrics(self) -> Dict[str, float]:
        lats = np.array([o.latency_s for o in self.outcomes])
        acc = np.mean([o.correct for o in self.outcomes])
        per_flops = {name: 0.0 for name in self.stations}
        per_mem = {name: 0.0 for name in self.stations}
        for o in self.outcomes:
            for t, v in o.tier_flops.items():
                per_flops[t] += v
            for t, v in o.tier_mem_bytes.items():
                per_mem[t] += v
        local = {t.name for t in self.topology.local_tiers}
        frac_local = float(np.mean([
            all(r in local for r in o.routes.values())
            for o in self.outcomes]))
        out = {
            "accuracy": float(acc),
            "mean_latency_s": float(lats.mean()),
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p95_latency_s": float(np.percentile(lats, 95)),
            "p99_latency_s": float(np.percentile(lats, 99)),
            "total_flops": sum(per_flops.values()),
            "total_mem_byte_s": sum(per_mem.values()),
            "frac_edge": frac_local,  # legacy name: fully-local fraction
            "frac_local": frac_local,
            "hedged": float(np.mean([o.hedged for o in self.outcomes])),
            "retries": float(np.mean([o.retries for o in self.outcomes])),
        }
        if self.runtime.migrate:
            # migration metrics only when the edge is on: the golden
            # pre-refactor metric KEY SET must stay exact otherwise
            out["migrated"] = float(np.mean(
                [o.migrated for o in self.outcomes]))
            out["migration_bytes"] = float(sum(
                o.migration_bytes for o in self.outcomes))
        if self.runtime.sessions or any(
                s.enabled for s in self.backend.prefix.values()):
            # prefix & session KV reuse metrics, gated for the same reason
            out["resumed"] = float(np.mean(
                [o.warm == "resume" for o in self.outcomes]))
            out["prefix_hits"] = float(np.mean(
                [o.warm == "prefix" for o in self.outcomes]))
            out["warm_tokens"] = float(sum(
                o.warm_tokens for o in self.outcomes))
            out["session_moves"] = float(self.runtime.session_moves)
        rt = self.runtime
        if (rt.resilience.enabled or rt.plan is not None
                or any(o.failed for o in self.outcomes)):
            # resilience metrics, gated to keep the golden key set exact:
            # goodput = completed-on-time fraction OF SUBMITTED work
            # (failed/shed outcomes count against it)
            n = max(len(self.outcomes), 1)
            out["failed"] = float(sum(o.failed and o.fail_reason == "retries"
                                      for o in self.outcomes)) / n
            out["shed"] = float(sum(o.fail_reason == "shed"
                                    for o in self.outcomes)) / n
            out["degraded"] = float(sum(o.degraded
                                        for o in self.outcomes)) / n
            out["goodput"] = float(sum((not o.failed) and o.on_time
                                       for o in self.outcomes)) / n
            out["quarantines"] = float(
                rt.health.quarantine_count if rt.health is not None else 0)
            out["rescued_sessions"] = float(rt.rescued_sessions)
        for name, st in self.stations.items():
            out[f"{name}_flops"] = per_flops[name]
            out[f"{name}_mem_byte_s"] = per_mem[name]
            out[f"{name}_util"] = st.busy_time / max(self.t, 1e-9)
        return out


# the original two-tier entry point: same class, topology defaulted from the
# legacy SimConfig edge/cloud pair
EdgeCloudSimulator = ClusterSimulator
