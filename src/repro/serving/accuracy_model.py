"""Calibrated accuracy model P(correct | difficulty, tier).

The simulator's ground truth. Shape chosen so that (a) easy requests are
answered equally well by both tiers — the property MoA-Off exploits — and
(b) the tier MEANS over the request distribution match the paper's Table 1
endpoints (cloud-only ~76-78%, edge-only ~61-64% on VQAv2; slightly lower on
MMBench). MoA-Off / PerLLM accuracies are NOT fitted — they emerge from
routing, which is the point of the reproduction.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class AccuracyModel:
    # P(correct | d) = base - slope*d - cliff*max(0, d - knee)
    # knee ~= τ: requests below the offloading threshold are answered (almost)
    # as well by the edge model — the property the MoA-Off routing exploits;
    # above it the weak model degrades steeply (matches the 15pp edge-cloud
    # gap of Table 1 given the synthetic difficulty distribution)
    base: float = 0.935
    cloud_slope: float = 0.32
    edge_slope: float = 0.32
    edge_knee: float = 0.50
    edge_cliff: float = 2.4
    late_penalty: float = 0.9  # SLO-missed responses lose some utility

    def p_correct(self, difficulty: float, tier: str,
                  capability: float = None) -> float:
        """P(correct). ``capability`` ∈ [0,1] interpolates the cliff between
        the edge curve (0.0) and the cloud curve (1.0); when omitted it is
        inferred from the legacy tier name."""
        d = float(np.clip(difficulty, 0.0, 1.0))
        if capability is None:
            # conservative fallback: only the literal cloud tier gets the
            # cliff-free curve; unknown tier names behave edge-grade
            capability = 1.0 if tier == "cloud" else 0.0
        p = self.base - self.cloud_slope * d
        p -= (1.0 - float(np.clip(capability, 0.0, 1.0))) \
            * self.edge_cliff * max(0.0, d - self.edge_knee)
        return float(np.clip(p, 0.02, 0.99))

    def sample(self, rng: np.random.Generator, difficulty: float, tier: str,
               on_time: bool = True, capability: float = None) -> bool:
        p = self.p_correct(difficulty, tier, capability)
        if not on_time:
            p *= self.late_penalty
        return bool(rng.random() < p)

    def mean_accuracy(self, tier: str, n: int = 20001,
                      capability: float = None) -> float:
        ds = np.linspace(0, 1, n)
        return float(np.mean([self.p_correct(d, tier, capability)
                              for d in ds]))


# dataset-flavoured variants (MMBench is a bit harder across the board)
VQAV2 = AccuracyModel()
MMBENCH = AccuracyModel(base=0.925, cloud_slope=0.34, edge_cliff=2.6)
