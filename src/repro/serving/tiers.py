"""Two-tier live serving: the MoA-Off scheduler in front of two real engines.

``EdgeCloudServer`` is the end-to-end driver: requests carry real payloads
(images as arrays, text as strings through the toy tokenizer); the scheduler
scores them with the kernel-backed complexity module, routes per modality
(Eq. 6), and the chosen tier's continuous-batching engine generates tokens.
A simulated WAN delay (bandwidth + RTT) is charged on cloud-routed bytes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.config import ServingConfig
from repro.core.request import ModalityInput, Request
from repro.core.scheduler import MoAOffScheduler
from repro.data.tokenizer import ToyTokenizer
from repro.serving.engine import TierEngine


@dataclass
class ServedResult:
    rid: int
    tier: str
    routes: Dict[str, str]
    tokens: List[int]
    latency_s: float
    wan_s: float


class EdgeCloudServer:
    def __init__(self, edge_engine: TierEngine, cloud_engine: TierEngine,
                 scheduler: Optional[MoAOffScheduler] = None,
                 bandwidth_bps: float = 300e6, rtt_s: float = 0.02):
        self.edge = edge_engine
        self.cloud = cloud_engine
        self.scheduler = scheduler or MoAOffScheduler()
        self.tok = ToyTokenizer()
        self.bandwidth = bandwidth_bps
        self.rtt = rtt_s
        self._rid = 0
        self._meta: Dict[int, dict] = {}
        self.results: List[ServedResult] = []

    def _engine(self, tier: str) -> TierEngine:
        return self.edge if tier == "edge" else self.cloud

    def submit(self, text: str, image: Optional[np.ndarray] = None,
               max_new: int = 16) -> int:
        rid = self._rid
        self._rid += 1
        mods = {}
        if image is not None:
            mods["image"] = ModalityInput("image", data=image,
                                          size_bytes=image.size // 2)
        ids = self.tok.encode(text)
        arr = np.asarray(ids, np.int32)
        mods["text"] = ModalityInput(
            "text", data=arr, size_bytes=len(ids) * 4,
            meta={"tokens": len(ids),
                  "entities": int(self.tok.is_entity(arr).sum()),
                  "sentences": max(1, int(self.tok.is_sentence_end(arr).sum()))})
        req = Request(rid=rid, arrival_s=time.monotonic(), modalities=mods)

        # live load feedback into the scheduler state
        for tier, eng in (("edge", self.edge), ("cloud", self.cloud)):
            load = 1.0 - sum(s is None for s in eng.slots) / len(eng.slots)
            if tier == "edge":
                self.scheduler.observe(edge_load=load,
                                       bandwidth_bps=self.bandwidth)
            else:
                self.scheduler.observe(cloud_load=load)

        decision = self.scheduler.route(req)
        tier = "cloud" if decision.any_cloud else "edge"
        wan_bytes = sum(m.size_bytes for n, m in mods.items()
                        if decision.routes.get(n) == "cloud")
        wan_s = (self.rtt + 8.0 * wan_bytes / self.bandwidth) if tier == "cloud" else 0.0

        eng = self._engine(tier)
        extras = {}
        mcfg = eng.cfg
        if image is not None and decision.routes.get("image") == tier == "cloud" \
                or (image is not None and tier == "edge"):
            if mcfg.frontend == "vision_stub":
                extras["patches"] = self._patchify(image, mcfg)
        tokens = self.tok.pad(ids, min(len(ids), eng.serving.max_seq // 2))
        eng.submit(rid, tokens, max_new=max_new, extras=extras)
        self._meta[rid] = {"tier": tier, "routes": decision.routes,
                           "wan_s": wan_s, "t0": req.arrival_s}
        return rid

    @staticmethod
    def _patchify(image: np.ndarray, mcfg) -> np.ndarray:
        """Stub frontend: average-pool the image into num_patches embeddings."""
        p, fd = mcfg.num_patches, mcfg.frontend_dim
        flat = image.reshape(-1).astype(np.float32) / 255.0
        need = p * fd
        rep = int(np.ceil(need / flat.size))
        return np.tile(flat, rep)[:need].reshape(p, fd)

    def run(self, max_steps: int = 10_000) -> List[ServedResult]:
        """Drive both engines until all submitted requests finish."""
        steps = 0
        while steps < max_steps:
            a = self.edge.step()
            b = self.cloud.step()
            if a == 0 and b == 0 and not self.edge.waiting and not self.cloud.waiting:
                break
            steps += 1
        now = time.monotonic()
        for eng, tier in ((self.edge, "edge"), (self.cloud, "cloud")):
            for st in eng.finished:
                if st.rid not in self._meta:
                    continue
                meta = self._meta.pop(st.rid)
                lat = (st.t_done or now) - meta["t0"] + meta["wan_s"]
                self.scheduler.observe(latency_s=lat)
                self.results.append(ServedResult(
                    rid=st.rid, tier=tier, routes=meta["routes"],
                    tokens=st.generated, latency_s=lat, wan_s=meta["wan_s"]))
            eng.finished.clear()
        return self.results
