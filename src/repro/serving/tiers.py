"""N-tier live serving: the MoA-Off scheduler in front of real engines.

``ClusterServer`` is the end-to-end driver over a ``ClusterTopology``:
requests carry real payloads (images as arrays, text as strings through the
toy tokenizer); the scheduler scores them with the kernel-backed complexity
module, routes per modality (Eq. 6 over the tier set), and the fusion tier's
continuous-batching engine generates tokens. A simulated WAN delay
(per-tier uplink bandwidth + RTT) is charged on remote-routed bytes.

``EdgeCloudServer`` is the original two-tier entry point, now a thin
wrapper building the legacy edge/cloud topology.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.config import (ClusterTopology, ServingConfig, TierSpec,
                          two_tier_topology)
from repro.core.request import ModalityInput, Request
from repro.core.scheduler import MoAOffScheduler
from repro.data.tokenizer import ToyTokenizer
from repro.serving.cost_model import transfer_seconds
from repro.serving.engine import TierEngine


@dataclass
class ServedResult:
    rid: int
    tier: str
    routes: Dict[str, str]
    tokens: List[int]
    latency_s: float
    wan_s: float
    ttft_s: float = 0.0  # time to first token (incl. charged WAN delay)


def _default_topology(engine_names, bandwidth_bps: float,
                      rtt_s: float) -> ClusterTopology:
    """Topology inferred from engine names when none is given: a tier named
    "cloud" is remote behind the WAN, everything else is local. Hardware
    specs come from the canonical testbed pair in ``two_tier_topology``."""
    edge_spec, cloud_spec = two_tier_topology(
        bandwidth_bps=bandwidth_bps, rtt_s=rtt_s).tiers
    return ClusterTopology("inferred", tuple(
        dataclasses.replace(
            cloud_spec if name == "cloud" else edge_spec, name=name)
        for name in engine_names))


class ClusterServer:
    """MoA-Off control plane in front of one live ``TierEngine`` per tier."""

    def __init__(self, engines: Dict[str, TierEngine],
                 topology: Optional[ClusterTopology] = None,
                 scheduler: Optional[MoAOffScheduler] = None,
                 bandwidth_bps: Optional[float] = None, rtt_s: float = 0.02):
        self.engines = dict(engines)
        self.topology = topology or _default_topology(
            self.engines, bandwidth_bps if bandwidth_bps is not None
            else 300e6, rtt_s)
        missing = set(self.topology.names) - set(self.engines)
        if missing:
            raise ValueError(f"no engine for topology tiers {sorted(missing)}")
        from repro.core.baselines import make_policy

        self.scheduler = scheduler or MoAOffScheduler(
            policy=make_policy("moa-off", topology=self.topology))
        self.tok = ToyTokenizer()
        # the scheduler's observed scalar b defaults to the topology's own
        # anchor WAN uplink, so Eq. 5 gating and charged WAN cost agree
        self.bandwidth = (bandwidth_bps if bandwidth_bps is not None
                          else self.topology.default_remote.uplink_bps)
        self.rtt = rtt_s
        self._rid = 0
        self._meta: Dict[int, dict] = {}
        self.results: List[ServedResult] = []

    def _engine(self, tier: str) -> TierEngine:
        return self.engines[tier]

    def _wan_seconds(self, spec: TierSpec, num_bytes: int) -> float:
        if not spec.is_remote:
            return 0.0
        return transfer_seconds(num_bytes, spec.uplink_bps, spec.rtt_s)

    def submit(self, text: str, image: Optional[np.ndarray] = None,
               max_new: int = 16) -> int:
        rid = self._rid
        self._rid += 1
        mods = {}
        if image is not None:
            mods["image"] = ModalityInput("image", data=image,
                                          size_bytes=image.size // 2)
        ids = self.tok.encode(text)
        arr = np.asarray(ids, np.int32)
        mods["text"] = ModalityInput(
            "text", data=arr, size_bytes=len(ids) * 4,
            meta={"tokens": len(ids),
                  "entities": int(self.tok.is_entity(arr).sum()),
                  "sentences": max(1, int(self.tok.is_sentence_end(arr).sum()))})
        req = Request(rid=rid, arrival_s=time.monotonic(), modalities=mods)

        # live per-tier load + queue feedback into the scheduler state (the
        # cost-model argmin reads queue depths; engine backlog = waiting list)
        loads = {}
        for tier, eng in self.engines.items():
            loads[tier] = 1.0 - sum(s is None for s in eng.slots) / len(eng.slots)
        self.scheduler.observe(
            loads=loads, bandwidth_bps=self.bandwidth,
            queue_depths={t: len(e.waiting)
                          for t, e in self.engines.items()},
            bandwidths={t.name: t.uplink_bps
                        for t in self.topology.remote_tiers})

        decision = self.scheduler.route(req)
        tier = self.topology.fusion_tier(decision.routes)
        spec = self.topology.tier(tier)
        # every modality routed to a remote tier crosses that tier's uplink
        # (even when the fusion runs locally); distinct links transfer in
        # parallel, so the slowest one bounds the WAN delay. A remote fusion
        # with no remote-routed payload still pays its RTT for the prompt.
        remote_bytes: Dict[str, int] = {}
        for n, m in mods.items():
            routed = decision.routes.get(n, tier)
            if self.topology.tier(routed).is_remote:
                remote_bytes[routed] = (remote_bytes.get(routed, 0)
                                        + m.size_bytes)
        if spec.is_remote and tier not in remote_bytes:
            remote_bytes[tier] = 0
        wan_s = max((self._wan_seconds(self.topology.tier(t), b)
                     for t, b in remote_bytes.items()), default=0.0)

        eng = self._engine(tier)
        extras = {}
        mcfg = eng.cfg
        # the serving engine sees raw patches only when the image is routed
        # to it (a locally-fused request always encodes its own image);
        # images encoded on another tier ride along as compact embeddings
        if image is not None and (decision.routes.get("image") == tier
                                  or not spec.is_remote):
            if mcfg.frontend == "vision_stub":
                extras["patches"] = self._patchify(image, mcfg)
        tokens = self.tok.pad(ids, min(len(ids), eng.serving.max_seq // 2))
        eng.submit(rid, tokens, max_new=max_new, extras=extras)
        self._meta[rid] = {"tier": tier, "routes": decision.routes,
                           "wan_s": wan_s, "t0": req.arrival_s}
        return rid

    @staticmethod
    def _patchify(image: np.ndarray, mcfg) -> np.ndarray:
        """Stub frontend: average-pool the image into num_patches embeddings."""
        p, fd = mcfg.num_patches, mcfg.frontend_dim
        flat = image.reshape(-1).astype(np.float32) / 255.0
        need = p * fd
        rep = int(np.ceil(need / flat.size))
        return np.tile(flat, rep)[:need].reshape(p, fd)

    def run(self, max_steps: int = 10_000) -> List[ServedResult]:
        """Drive every engine until all submitted requests finish."""
        steps = 0
        while steps < max_steps:
            active = sum(eng.step() for eng in self.engines.values())
            waiting = any(eng.waiting for eng in self.engines.values())
            if active == 0 and not waiting:
                break
            steps += 1
        now = time.monotonic()
        for tier, eng in self.engines.items():
            for st in eng.finished:
                if st.rid not in self._meta:
                    continue
                meta = self._meta.pop(st.rid)
                lat = (st.t_done or now) - meta["t0"] + meta["wan_s"]
                ttft = ((st.t_first_token or st.t_done or now) - meta["t0"]
                        + meta["wan_s"])
                self.scheduler.observe(latency_s=lat)
                self.results.append(ServedResult(
                    rid=st.rid, tier=tier, routes=meta["routes"],
                    tokens=st.generated, latency_s=lat, wan_s=meta["wan_s"],
                    ttft_s=ttft))
            eng.finished.clear()
        return self.results


class EdgeCloudServer(ClusterServer):
    """Two-tier live serving (the paper's testbed) over ClusterServer."""

    def __init__(self, edge_engine: TierEngine, cloud_engine: TierEngine,
                 scheduler: Optional[MoAOffScheduler] = None,
                 bandwidth_bps: float = 300e6, rtt_s: float = 0.02):
        topo = two_tier_topology(bandwidth_bps=bandwidth_bps, rtt_s=rtt_s)
        super().__init__({"edge": edge_engine, "cloud": cloud_engine},
                         topology=topo, scheduler=scheduler,
                         bandwidth_bps=bandwidth_bps, rtt_s=rtt_s)
        self.edge = edge_engine
        self.cloud = cloud_engine
