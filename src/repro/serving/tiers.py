"""N-tier live serving: the MoA-Off scheduler in front of real engines.

``ClusterServer`` is now a thin shell over the shared event-driven
:class:`~repro.serving.runtime.ClusterRuntime` driven by its
:class:`~repro.serving.runtime.LiveBackend` — the SAME lifecycle state
machine as the discrete-event ``ClusterSimulator``, executed on the
monotonic clock against one real ``TierEngine`` per tier. That buys the
live path everything that used to be sim-only fiction:

* **Executed partial offload** — an image routed off the fusion tier is
  genuinely encoded by the routed tier's engine and only its compact patch
  embeddings ship into the fusion prefill (previously the image was
  silently skipped and a latency adder charged).
* **Modeled WAN with queueing** — remote-routed payloads cross their tier's
  uplink through a real link station (parallel links, join before service)
  as elapsed wall time, not a post-hoc latency adder.
* **Streaming decode** — per-request TTFT and SLO (``on_time``) tracking via
  the engine's token stream, with EDF-ordered admission into engine slots.
* **Hedging and fault recovery** — ``hedge_after_s`` clones stragglers onto
  the least-loaded other tier (first finisher wins, loser cancelled) and
  ``fail_rate`` injects node faults recovered through engine
  ``snapshot()``/``restore()``.

``EdgeCloudServer`` is the original two-tier entry point, now a thin
wrapper building the legacy edge/cloud topology.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.config import (ClusterTopology, ResilienceConfig, ServingConfig,
                          SpecConfig, two_tier_topology)
from repro.core.request import ModalityInput, Request
from repro.core.scheduler import MoAOffScheduler
from repro.data.tokenizer import ToyTokenizer
from repro.serving.engine import TierEngine
from repro.serving.faults import FaultPlan
from repro.serving.pool import EnginePool, build_engine_pools  # noqa: F401
from repro.serving.runtime import ClusterRuntime, LiveBackend


@dataclass
class ServedResult:
    rid: int
    tier: str
    routes: Dict[str, str]
    tokens: List[int]
    latency_s: float
    wan_s: float
    ttft_s: float = 0.0  # time to first streamed token (incl. WAN delay)
    on_time: bool = True  # finished within the request's SLO
    truncated: bool = False  # prompt clipped to the engine budget
    hedged: bool = False
    retries: int = 0
    migrated: bool = False  # KV cache moved across tiers mid-flight
    migration_bytes: float = 0.0  # slot-payload bytes shipped
    warm: str = ""  # "prefix" | "resume": admitted onto reused KV rows
    warm_tokens: float = 0.0  # cached tokens whose prefill was skipped
    failed: bool = False  # terminal: never completed
    fail_reason: str = ""  # "retries" | "shed" | "" (completed)
    degraded: bool = False  # served after re-routing off an open circuit


def build_cluster_engines(topology: ClusterTopology,
                          serving: ServingConfig,
                          dtype: str = "float32") -> Dict[str, TierEngine]:
    """One reduced-model ``TierEngine`` per topology tier (deterministic
    per-tier param seeds) — the canonical construction shared by the
    launcher, the cluster benchmark and the tests."""
    from repro.configs import reduced_config  # local imports, no cycle
    from repro.models import build_model

    engines = {}
    for i, tier in enumerate(topology.tiers):
        cfg = reduced_config(tier.model).replace(dtype=dtype)
        model = build_model(cfg)
        engines[tier.name] = TierEngine(
            model, model.init(jax.random.PRNGKey(i)), serving)
    return engines


def _default_topology(engine_names, bandwidth_bps: float,
                      rtt_s: float) -> ClusterTopology:
    """Topology inferred from engine names when none is given: a tier named
    "cloud" is remote behind the WAN, everything else is local. Hardware
    specs come from the canonical testbed pair in ``two_tier_topology``."""
    edge_spec, cloud_spec = two_tier_topology(
        bandwidth_bps=bandwidth_bps, rtt_s=rtt_s).tiers
    return ClusterTopology("inferred", tuple(
        dataclasses.replace(
            cloud_spec if name == "cloud" else edge_spec, name=name)
        for name in engine_names))


class ClusterServer:
    """MoA-Off control plane in front of live engines — one ``TierEngine``
    per tier, or a replicated :class:`~repro.serving.pool.EnginePool`
    (built by :func:`~repro.serving.pool.build_engine_pools`); ``engines``
    values may be either, and bare engines wrap into single-replica pools
    with bit-identical behavior."""

    def __init__(self, engines: Dict[str, TierEngine],
                 topology: Optional[ClusterTopology] = None,
                 scheduler: Optional[MoAOffScheduler] = None,
                 bandwidth_bps: Optional[float] = None, rtt_s: float = 0.02,
                 hedge_after_s: float = 0.0, fail_rate: float = 0.0,
                 seed: int = 0, migrate: bool = False,
                 migrate_threshold: int = 0, hedge_in_service: bool = False,
                 snapshot_every: int = 4, sessions: bool = False,
                 session_move_threshold: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 spec: Optional[SpecConfig] = None,
                 audit: bool = False):
        # legacy-shim: a plan carrying only a Bernoulli rate compiles back
        # into the scalar knob, through the same rng stream as ever
        if fault_plan is not None and fail_rate == 0.0:
            fail_rate = fault_plan.fail_rate
        supplied = dict(engines)
        self.topology = topology or _default_topology(
            supplied, bandwidth_bps if bandwidth_bps is not None
            else 300e6, rtt_s)
        missing = set(self.topology.names) - set(supplied)
        if missing:
            raise ValueError(f"no engine for topology tiers {sorted(missing)}")
        from repro.core.baselines import make_policy

        self.scheduler = scheduler or MoAOffScheduler(
            policy=make_policy("moa-off", topology=self.topology))
        self.tok = ToyTokenizer()
        self.backend = LiveBackend(supplied, self.topology,
                                   fail_rate=fail_rate, seed=seed,
                                   snapshot_every=snapshot_every)
        # pool view (always) and the single-replica back-compat engine view
        # (tests/benches read counters off ``server.engines``)
        self.pools = self.backend.pools
        self.engines = self.backend.engines
        self.runtime = ClusterRuntime(
            self.topology, self.scheduler,
            getattr(self.scheduler.policy, "name", "moa-off"), self.backend,
            hedge_after_s=hedge_after_s,
            observed_bandwidth_bps=bandwidth_bps, migrate=migrate,
            migrate_threshold=migrate_threshold,
            hedge_in_service=hedge_in_service, sessions=sessions,
            session_move_threshold=session_move_threshold,
            resilience=resilience, fault_plan=fault_plan, spec=spec,
            audit=audit)
        self._rid = 0
        self._reported = 0  # outcomes already converted to ServedResults
        self.results: List[ServedResult] = []
        # per-session transcript: prompt ids of the last submitted turn and
        # its rid (the next turn extends prompt + that turn's generation)
        self._session_hist: Dict[str, Dict] = {}

    def _engine(self, tier: str) -> TierEngine:
        return self.engines[tier]

    # ------------------------------------------------------------------

    def build_request(self, text: str, image: Optional[np.ndarray] = None,
                      max_new: int = 16, slo_s: float = 5.0,
                      delay_s: float = 0.0,
                      complexity: Optional[Dict[str, float]] = None,
                      session: Optional[str] = None,
                      prompt_ids: Optional[np.ndarray] = None) -> Request:
        """Tokenize/score-prepare one request without submitting it (the
        sim-vs-live parity test feeds the same payloads to both backends).
        ``complexity`` pins per-modality scores, bypassing the scorer.
        ``prompt_ids`` bypasses tokenization (multi-turn histories already
        carry generated token ids)."""
        rid = self._rid
        self._rid += 1
        mods: Dict[str, ModalityInput] = {}
        if image is not None:
            mods["image"] = ModalityInput("image", data=image,
                                          size_bytes=image.size // 2)
        if prompt_ids is not None:
            arr = np.asarray(prompt_ids, np.int32)
            if text:
                arr = np.concatenate(
                    [arr, np.asarray(self.tok.encode(text), np.int32)])
        else:
            arr = np.asarray(self.tok.encode(text), np.int32)
        mods["text"] = ModalityInput(
            "text", data=arr, size_bytes=len(arr) * 4,
            meta={"tokens": len(arr),
                  "entities": int(self.tok.is_entity(arr).sum()),
                  "sentences": max(1, int(self.tok.is_sentence_end(arr).sum()))})
        if complexity:
            for name, c in complexity.items():
                if name in mods:
                    mods[name].complexity = float(c)
        return Request(rid=rid, arrival_s=time.monotonic() + delay_s,
                       modalities=mods, decode_tokens=max_new, slo_s=slo_s,
                       session=session)

    def submit(self, text: str, image: Optional[np.ndarray] = None,
               max_new: int = 16, slo_s: float = 5.0,
               delay_s: float = 0.0,
               complexity: Optional[Dict[str, float]] = None) -> int:
        """Queue one request; ``delay_s`` paces its arrival into the future
        (the runtime processes it when the monotonic clock reaches it), so a
        caller can model an arrival process instead of a closed batch."""
        req = self.build_request(text, image, max_new=max_new, slo_s=slo_s,
                                 delay_s=delay_s, complexity=complexity)
        return self.submit_request(req)

    def submit_request(self, req: Request) -> int:
        self.runtime.submit(req)
        return req.rid

    # -- multi-turn sessions -------------------------------------------------

    def build_turn(self, sid: str, text: str,
                   image: Optional[np.ndarray] = None, max_new: int = 16,
                   slo_s: float = 5.0, delay_s: float = 0.0,
                   complexity: Optional[Dict[str, float]] = None) -> Request:
        """One chat turn of session ``sid``: the prompt is the FULL
        conversation so far — previous turns' prompts and generated tokens
        — plus the new user text, so the engine's parked state (or prefix
        store) makes it a suffix-only prefill. Requires the previous turn
        to have completed (its generation is part of the history)."""
        st = self._session_hist.setdefault(
            sid, {"ids": np.zeros((0,), np.int32), "last": None})
        if st["last"] is not None:
            rec = self.runtime.records.get(st["last"])
            gen = rec.tokens if rec is not None and rec.done else []
            if gen:
                st["ids"] = np.concatenate(
                    [st["ids"], np.asarray(gen, np.int32)])
        req = self.build_request(text, image=image, max_new=max_new,
                                 slo_s=slo_s, delay_s=delay_s,
                                 complexity=complexity, session=sid,
                                 prompt_ids=st["ids"])
        st["ids"] = np.asarray(req.modalities["text"].data, np.int32)
        st["last"] = req.rid
        return req

    def submit_turn(self, sid: str, text: str,
                    image: Optional[np.ndarray] = None, max_new: int = 16,
                    slo_s: float = 5.0, delay_s: float = 0.0,
                    complexity: Optional[Dict[str, float]] = None) -> int:
        return self.submit_request(self.build_turn(
            sid, text, image=image, max_new=max_new, slo_s=slo_s,
            delay_s=delay_s, complexity=complexity))

    # ------------------------------------------------------------------

    def run(self, timeout_s: float = 300.0) -> List[ServedResult]:
        """Drive the runtime until every submitted request resolves — a
        completion OR a terminal failed/shed Outcome — or ``timeout_s`` of
        wall clock elapses; on timeout the results gathered so far are
        returned (partial results under a permanent fault, not a hang)."""
        self.runtime.run(max_wall_s=timeout_s)
        outcomes = self.runtime.outcomes
        for out in outcomes[self._reported:]:
            rec = self.runtime.records[out.rid]
            self.results.append(ServedResult(
                rid=out.rid, tier=out.served_tier, routes=out.routes,
                tokens=list(rec.tokens), latency_s=out.latency_s,
                wan_s=rec.wan_s, ttft_s=out.ttft_s, on_time=out.on_time,
                truncated=out.truncated, hedged=out.hedged,
                retries=out.retries, migrated=out.migrated,
                migration_bytes=out.migration_bytes, warm=out.warm,
                warm_tokens=out.warm_tokens, failed=out.failed,
                fail_reason=out.fail_reason, degraded=out.degraded))
        self._reported = len(outcomes)
        return self.results

    def close(self) -> None:
        """Shut down replica transports (joins/terminates process workers;
        a no-op for purely local pools)."""
        for pool in self.pools.values():
            pool.close()


class EdgeCloudServer(ClusterServer):
    """Two-tier live serving (the paper's testbed) over ClusterServer."""

    def __init__(self, edge_engine: TierEngine, cloud_engine: TierEngine,
                 scheduler: Optional[MoAOffScheduler] = None,
                 bandwidth_bps: float = 300e6, rtt_s: float = 0.02,
                 hedge_after_s: float = 0.0, fail_rate: float = 0.0):
        topo = two_tier_topology(bandwidth_bps=bandwidth_bps, rtt_s=rtt_s)
        super().__init__({"edge": edge_engine, "cloud": cloud_engine},
                         topology=topo, scheduler=scheduler,
                         bandwidth_bps=bandwidth_bps, rtt_s=rtt_s,
                         hedge_after_s=hedge_after_s, fail_rate=fail_rate)
        self.edge = edge_engine
        self.cloud = cloud_engine
