"""Central configuration dataclasses for the repro framework.

Everything is a frozen dataclass so configs are hashable (usable as jit
static args) and safely shareable across threads (serving engine, async
checkpointer).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description for every model family in the zoo.

    A single config class covers dense / MoE / SSM / hybrid / enc-dec / VLM
    families; the ``family`` tag selects the block stack in
    ``repro.models.build_model``.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    activation: str = "swiglu"  # swiglu | squared_relu | geglu | gelu
    qk_norm: bool = False

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden width
    num_shared_experts: int = 0
    first_k_dense: int = 0  # leading dense layers before MoE starts
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "local_attn")
    local_window: int = 2048
    lru_width: int = 0  # 0 -> d_model

    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder context (audio frames after conv stub)

    # --- modality frontend (VLM / audio) ---
    frontend: str = "none"  # none | vision_stub | audio_stub
    num_patches: int = 0  # vision tokens prepended to the text sequence
    frontend_dim: int = 0  # embedding dim of the precomputed patches/frames

    # --- misc ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        """True if serving cost is sub-quadratic in context (long_500k eligible)."""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.counting import count_params  # local import, no cycle

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_active_params

        return count_active_params(self)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned per-arch shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> dict:
    """Which of the 4 assigned shapes run for this arch (with skip reasons).

    Returns {shape_name: None | skip_reason}.
    """
    out = {}
    for name, shape in SHAPES.items():
        reason = None
        if name == "long_500k" and not cfg.is_subquadratic:
            reason = (
                "full quadratic attention; 512k-token KV-cache decode is "
                "defined for sub-quadratic archs only (DESIGN.md §4)"
            )
        out[name] = reason
    return out


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def multi_pod(self) -> bool:
        return "pod" in self.axis_names


SINGLE_POD_MESH = MeshConfig((16, 16), ("data", "model"))
MULTI_POD_MESH = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class ShardingConfig:
    """Knobs for the distribution strategy (hillclimbed in §Perf)."""

    zero_stage: int = 1  # 0: replicated opt state, 1: opt sharded over dp, 3: params too
    remat_policy: str = "dots"  # none | dots | full
    scan_layers: bool = True
    sequence_parallel: bool = True  # shard long activations over data axis
    gradient_accum: int = 1
    # collective-schedule knobs (beyond-paper perf levers)
    all_gather_params_once: bool = False  # ZeRO-3: gather per-layer inside scan
    overlap_collectives: bool = True  # async collective start (XLA flag hint)


# ---------------------------------------------------------------------------
# MoA-Off policy configuration (the paper's §3)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ComplexityConfig:
    """Weights/constants of §3.1 (paper defaults: averaged weights, τ=0.5)."""

    # image weights (sum to 1; paper sets them to their average -> 0.25 each)
    w_res: float = 0.25
    w_edge: float = 0.25
    w_ent: float = 0.25
    w_lap: float = 0.25
    ref_h: int = 1024  # (H0, W0) reference resolution
    ref_w: int = 1024
    # calibration percentiles (P5/P95 over a calibration set; Eq. 2 & 4)
    edge_p5: float = 2.0
    edge_p95: float = 60.0
    lap_p5: float = 10.0
    lap_p95: float = 2_500.0
    eps: float = 1e-6
    # text weights (average -> 0.5 each)
    beta_len: float = 0.5
    beta_ner: float = 0.5
    len_l0: int = 512  # token-length threshold L0
    ner_gamma: float = 4.0  # entities-per-sentence scale γ
    # audio extension (beyond-paper; same recipe applied to frame features)
    audio_ref_frames: int = 1_500


@dataclass(frozen=True)
class PolicyConfig:
    """Eq. 5/6 thresholds and system-state limits."""

    tau_image: float = 0.5
    tau_text: float = 0.5
    tau_audio: float = 0.5
    edge_load_max: float = 0.8  # ℓ_max
    bandwidth_beta: float = 500e6  # β in bit/s (above the paper's 200-400Mbps sweep)
    paper_faithful_bandwidth: bool = True  # literal Eq.5 `b <= β` (see DESIGN.md)
    # adaptive extension: EWMA-driven threshold adjustment (beyond paper §3.2's
    # "integrates modality-aware thresholds with system-level dynamics")
    adaptive_tau: bool = True
    tau_step: float = 0.02
    target_edge_util: float = 0.65


# ---------------------------------------------------------------------------
# Serving / simulation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TierConfig:
    """One serving tier (edge or cloud) in the cost model / simulator."""

    name: str
    model: str  # config name served on this tier
    num_chips: int
    flops_per_s: float  # achievable FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    mfu: float = 0.4  # achieved fraction of peak in the latency model
    startup_s: float = 0.002  # per-batch dispatch overhead


@dataclass(frozen=True)
class TierSpec(TierConfig):
    """A tier in a :class:`ClusterTopology` — TierConfig plus placement.

    ``uplink_bps == 0`` marks the tier as local (no WAN hop to reach it);
    remote tiers pay ``transfer_seconds(bytes, uplink_bps, rtt_s)`` per
    request. ``capability`` ∈ [0,1] anchors the accuracy model: 0.0 behaves
    like the paper's edge model (steep cliff past the difficulty knee),
    1.0 like the cloud model (no cliff); intermediate values interpolate.
    """

    servers: int = 1  # parallel FIFO servers at this tier
    uplink_bps: float = 0.0  # 0 -> local tier, no transfer needed
    rtt_s: float = 0.0
    capability: float = 0.0
    # return path toward the user; 0 -> assume symmetric (== uplink_bps).
    # Response tokens (and any embeddings coming back from a remote encoder)
    # are charged on this link by both execution backends.
    downlink_bps: float = 0.0

    @property
    def is_remote(self) -> bool:
        return self.uplink_bps > 0

    @classmethod
    def from_tier(cls, cfg: TierConfig, **kw) -> "TierSpec":
        if isinstance(cfg, TierSpec):
            return dataclasses.replace(cfg, **kw)
        return cls(**dataclasses.asdict(cfg), **kw)


@dataclass(frozen=True)
class ClusterTopology:
    """N named heterogeneous tiers forming an edge–cloud continuum.

    Frozen + tuple-backed so it can ride inside other frozen configs. The
    first declared local tier is the *anchor* edge (where non-offloaded work
    lands for modality-blind baselines); the highest-capability remote tier
    is the anchor cloud.
    """

    name: str
    tiers: Tuple[TierSpec, ...]

    def __post_init__(self):
        names = [t.name for t in self.tiers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tier names in topology: {names}")
        if not self.tiers:
            raise ValueError("topology needs at least one tier")

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(t.name for t in self.tiers)

    @property
    def local_tiers(self) -> Tuple[TierSpec, ...]:
        return tuple(t for t in self.tiers if not t.is_remote)

    @property
    def remote_tiers(self) -> Tuple[TierSpec, ...]:
        return tuple(t for t in self.tiers if t.is_remote)

    def tier(self, name: str) -> TierSpec:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(f"unknown tier {name!r}; have {self.names}")

    @property
    def default_local(self) -> TierSpec:
        locals_ = self.local_tiers
        return locals_[0] if locals_ else self.tiers[0]

    @property
    def default_remote(self) -> TierSpec:
        remotes = self.remote_tiers
        pool = remotes if remotes else self.tiers
        return max(pool, key=lambda t: t.capability)

    def fusion_tier(self, routes: dict) -> str:
        """Where the fused generation runs: the most capable routed tier
        (legacy semantics: cloud if any modality went cloud, else edge)."""
        routed = [self.tier(r) for r in sorted(set(routes.values()))]
        if not routed:
            return self.default_local.name
        # tier name as final tie-break: deterministic across interpreter
        # runs even when two routed tiers share capability and placement
        best = max(routed, key=lambda t: (t.capability, t.is_remote, t.name))
        return best.name


@dataclass(frozen=True)
class ServingConfig:
    max_batch: int = 32
    max_seq: int = 4_096
    # --- paged KV pool ---
    # KV rows live in fixed-size pages; each slot holds a page table into a
    # shared physical pool, so concurrency is bounded by POOL pages, not by
    # max_batch * max_seq dense rows. Admission is continuous (a request is
    # admitted the moment enough pages free up, splitting the fused block at
    # the page-pressure boundary) and prefix/session hits map shared pages
    # copy-on-write into the new slot's table. ``paged=False`` keeps the
    # dense per-slot pool (the parity baseline).
    paged: bool = False
    kv_page_size: int = 256
    # physical pages in the pool, EXCLUDING the null page. 0 -> auto-size to
    # max_batch * (max_seq // kv_page_size): every slot can hold a full
    # sequence, so admission order (and thus decode output) is identical to
    # the dense pool. Benchmarks shrink this to trade capacity for memory.
    kv_pool_pages: int = 0
    # warm admissions for recurrent families (ssm/hybrid) replay the suffix
    # in ONE chunked pass seeded from the cached state (decode_chunk_recurrent)
    # instead of a per-token warm scan. Bit-identical state trajectory; flip
    # off to fall back to the sequential scan.
    chunked_recurrent_suffix: bool = True
    prefill_chunk: int = 2_048
    hedge_after_s: float = 1.5  # straggler mitigation: hedged re-issue
    retry_limit: int = 2
    heartbeat_interval_s: float = 0.5
    heartbeat_timeout_s: float = 2.0
    # --- device-resident hot path ---
    # decode steps fused into ONE jitted lax.scan per host call: sampling,
    # EOS/budget/cap masking and KV writes stay on device, the host sees one
    # (B, fused_steps) token block. 1 = the legacy per-token path (host-side
    # numpy sampling, one dispatch + transfer per token) kept for parity
    # testing.
    fused_steps: int = 8
    # decode attention backend: "auto" = Pallas decode kernel where it
    # compiles natively (TPU), XLA elsewhere; "pallas"/"xla" force one.
    decode_impl: str = "auto"
    # batch same-length-bucket waiting prompts into one prefill call (pad to
    # power-of-two buckets) instead of one retraced prefill per request.
    # Ignored (off) on the legacy fused_steps=1 path.
    bucket_prefill: bool = True
    # unroll the layer loop inside fused decode so each layer's K/V scatter
    # updates the stacked cache leaf IN PLACE (the scanned form re-assembles
    # — i.e. copies — the whole KV cache every token). O(L) HLO; only
    # applied on the fused path.
    unroll_decode_layers: bool = True
    # fused decode attends a power-of-two cache VIEW just covering the
    # longest active context (+ the fused block), instead of all of
    # ``max_seq`` every token; the view is sliced/pasted once per K-token
    # block. Off on the legacy path (which always pays full capacity).
    context_buckets: bool = True
    # --- prefix & session KV reuse ---
    # byte budget (MB) of the tier-local prefix store: admitted prompts
    # deposit their cache rows at bucket-aligned prefix lengths, and a new
    # prompt extending a stored prefix copies the rows and prefills only
    # the suffix. 0 disables the store (bit-identical to pre-feature
    # serving).
    prefix_cache_mb: float = 0.0
    # byte budget (MB) of the parked-session store: a finished turn of a
    # request submitted with a session id parks its slot state
    # (SlotPayload) so the next turn re-injects and prefills only the new
    # tokens. LRU-evicted; a miss falls back to a cold full prefill.
    session_cache_mb: float = 64.0
    # smallest prefix worth storing/hitting (shorter prompts re-prefill)
    prefix_min_tokens: int = 16
    # idle-wait cap for the live backend's event loop, seconds. 0 = fully
    # event-driven: an idle server sleeps until its next scheduled event
    # (paced arrival / hedge check / fault detect) instead of burning a
    # core polling. A positive value caps each doze — useful when external
    # state (process-replica pipes, injected clock skew) must be re-polled
    # on a bounded cadence; process transports force an internal 20 ms cap
    # regardless.
    idle_poll_s: float = 0.0

    def __post_init__(self):
        if self.idle_poll_s < 0:
            raise ValueError(
                f"idle_poll_s must be >= 0 (0 = event-driven idle wait), "
                f"got {self.idle_poll_s}")
        ps = self.kv_page_size
        if ps <= 0 or ps & (ps - 1):
            raise ValueError(
                f"kv_page_size must be a positive power of two, got {ps} "
                f"(page-table arithmetic uses shifts/masks)")
        if ps < 8:
            raise ValueError(
                f"kv_page_size {ps} < 8: page tables would carry "
                f"max_seq/page_size = {self.max_seq // max(ps, 1)} entries "
                f"per slot; use >= 8")
        if self.paged and self.max_seq % ps:
            raise ValueError(
                f"kv_page_size {ps} must divide max_seq {self.max_seq} so "
                f"every slot's page table has a whole number of pages")
        if self.paged and ps > self.max_seq:
            raise ValueError(
                f"kv_page_size {ps} exceeds max_seq {self.max_seq}: the "
                f"context-bucket ladder (min 32) could never cover a page")
        if self.kv_pool_pages < 0:
            raise ValueError(
                f"kv_pool_pages must be >= 0 (0 = auto-size), got "
                f"{self.kv_pool_pages}")
        if self.paged and self.kv_pool_pages:
            need = self.max_seq // ps
            if self.kv_pool_pages < need:
                raise ValueError(
                    f"kv_pool_pages {self.kv_pool_pages} < {need} pages "
                    f"needed to hold ONE max_seq={self.max_seq} sequence at "
                    f"kv_page_size={ps}; no request could ever be admitted")

    @property
    def pages_per_slot(self) -> int:
        """Page-table length: logical pages covering one full sequence."""
        return self.max_seq // self.kv_page_size

    @property
    def pool_pages(self) -> int:
        """Physical pages in the paged pool (excluding the null page)."""
        return self.kv_pool_pages or self.max_batch * self.pages_per_slot


@dataclass(frozen=True)
class SpecConfig:
    """Cross-tier speculative decoding: a cheap DRAFT tier proposes blocks
    of ``draft_k`` tokens and the TARGET tier verifies the whole block in
    ONE chunked forward against its own KV, accepting the longest matching
    prefix plus its own correction token. Output is token-for-token the
    target-only stream (the committed tokens are the target's own samples
    under its own key stream), so quality is exactly the target's.

    The scheduler speculates only while the acceptance-rate EWMA stays at
    or above ``min_accept``; ``init_accept`` seeds the EWMA so a cold
    system gives speculation a chance before any feedback exists.
    """

    draft_tier: str = "edge"
    target_tier: str = "cloud"
    draft_k: int = 8  # proposed tokens per round
    min_accept: float = 0.3  # stop speculating below this EWMA
    init_accept: float = 0.7  # optimistic cold-start acceptance rate

    def __post_init__(self):
        if self.draft_tier == self.target_tier:
            raise ValueError(
                f"speculation needs two tiers, got draft == target == "
                f"{self.draft_tier!r}")
        if self.draft_k < 1:
            raise ValueError(f"draft_k must be >= 1, got {self.draft_k}")
        if not 0.0 <= self.min_accept <= 1.0:
            raise ValueError(
                f"min_accept must be in [0, 1], got {self.min_accept}")
        if not 0.0 <= self.init_accept <= 1.0:
            raise ValueError(
                f"init_accept must be in [0, 1], got {self.init_accept}")


@dataclass(frozen=True)
class ResilienceConfig:
    """Tier-health / graceful-degradation knobs for the cluster runtime.

    Everything defaults OFF: a default-constructed config leaves the
    runtime's behavior (and the golden analytic metrics) untouched.
    ``health=True`` turns on the per-tier circuit breaker
    (healthy -> suspect -> quarantined -> probing -> healthy, see
    ``serving/health.py``); the other switches gate retry backoff,
    deadline-aware load shedding and WAN transfer timeouts independently.
    """

    # circuit breaker: quarantine a tier after this many consecutive
    # failures; 0 < suspect_after <= quarantine_after
    health: bool = False
    suspect_after: int = 1
    quarantine_after: int = 3
    # failure-rate EWMA (informational health signal published to the
    # scheduler alongside the state machine)
    failure_ewma_alpha: float = 0.3
    # a quarantined tier admits one probe request after this cool-down;
    # the probe's outcome decides healthy vs re-quarantined
    probe_after_s: float = 5.0
    # retries wait cap(min(base * 2^(n-1))) * (1 + jitter) instead of
    # re-enqueueing immediately; jitter is deterministic per (rid, attempt)
    retry_backoff: bool = False
    backoff_base_s: float = 0.25
    backoff_cap_s: float = 4.0
    backoff_jitter: float = 0.25
    # shed (terminal Outcome, fail_reason="shed") instead of queueing when
    # the request's SLO deadline is provably unmeetable
    shed: bool = False
    # WAN transfers/migrations older than this are abandoned (0 = never);
    # required for progress under a full link partition
    transfer_timeout_s: float = 0.0
    # evacuate parked sessions off a tier entering quarantine onto the
    # best available compatible tier (existing SlotPayload transport)
    rescue_sessions: bool = True

    def __post_init__(self):
        if not 0 < self.suspect_after <= self.quarantine_after:
            raise ValueError(
                "need 0 < suspect_after <= quarantine_after, got "
                f"{self.suspect_after}/{self.quarantine_after}")

    @property
    def enabled(self) -> bool:
        """Any resilience feature on (gates the new metric keys)."""
        return (self.health or self.retry_backoff or self.shed
                or self.transfer_timeout_s > 0)


@dataclass(frozen=True)
class SimConfig:
    """Discrete-event cluster simulation of the paper's testbed."""

    bandwidth_bps: float = 300e6  # WAN edge<->cloud
    rtt_s: float = 0.02
    num_requests: int = 1_000
    arrival_rate: float = 20.0  # req/s Poisson
    seed: int = 0
    edge: TierConfig = field(
        default_factory=lambda: TierConfig(
            "edge", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.25
        )  # RTX-3090-class: 35.6 TFLOP/s fp16, 936 GB/s
    )
    cloud: TierConfig = field(
        default_factory=lambda: TierConfig(
            "cloud", "qwen2.5-vl-7b", 1, 312e12, 1_555e9, mfu=0.42
        )  # A100-40GB-class: 312 TFLOP/s bf16, 1.56 TB/s
    )
    # optional N-tier cluster; None -> the legacy two-tier pair above
    topology: Optional[ClusterTopology] = None


# ---------------------------------------------------------------------------
# Topology builders / registry
# ---------------------------------------------------------------------------


def two_tier_topology(edge: Optional[TierConfig] = None,
                      cloud: Optional[TierConfig] = None,
                      bandwidth_bps: float = 300e6, rtt_s: float = 0.02,
                      edge_servers: int = 1, cloud_servers: int = 1,
                      name: str = "edge-cloud") -> ClusterTopology:
    """The paper's testbed (§4.1) as a ClusterTopology: one local edge GPU,
    one remote cloud GPU behind a WAN uplink."""
    e = edge or TierConfig("edge", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.25)
    c = cloud or TierConfig("cloud", "qwen2.5-vl-7b", 1, 312e12, 1_555e9,
                            mfu=0.42)
    return ClusterTopology(name, (
        TierSpec.from_tier(e, servers=edge_servers, capability=0.0),
        TierSpec.from_tier(c, servers=cloud_servers, uplink_bps=bandwidth_bps,
                           rtt_s=rtt_s, capability=1.0),
    ))


def _edge_cloud() -> ClusterTopology:
    return two_tier_topology()


def _edge_edge_cloud() -> ClusterTopology:
    """Two heterogeneous edge GPUs (3090-class + Orin-class) + one cloud."""
    return ClusterTopology("edge-edge-cloud", (
        TierSpec("edge", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.25,
                 capability=0.0),
        TierSpec("edge1", "qwen2-vl-2b", 1, 10.6e12, 204e9, mfu=0.20,
                 capability=0.0),  # Jetson-Orin-class
        TierSpec("cloud", "qwen2.5-vl-7b", 1, 312e12, 1_555e9, mfu=0.42,
                 servers=2, uplink_bps=300e6, rtt_s=0.02, capability=1.0),
    ))


def _edge_regional_cloud() -> ClusterTopology:
    """Cloud-edge continuum: edge GPU, regional A10-class node on a fat
    metro link, A100 cloud across the WAN."""
    return ClusterTopology("edge-regional-cloud", (
        TierSpec("edge", "qwen2-vl-2b", 1, 35.6e12, 936e9, mfu=0.25,
                 capability=0.0),
        TierSpec("regional", "qwen2.5-vl-7b", 1, 125e12, 933e9, mfu=0.35,
                 servers=2, uplink_bps=1e9, rtt_s=0.005, capability=0.7),
        TierSpec("cloud", "qwen2.5-vl-7b", 1, 312e12, 1_555e9, mfu=0.42,
                 uplink_bps=300e6, rtt_s=0.02, capability=1.0),
    ))


def _continuum_4() -> ClusterTopology:
    """Four tiers: two edge GPUs + regional + cloud."""
    base = _edge_regional_cloud()
    edge1 = TierSpec("edge1", "qwen2-vl-2b", 1, 10.6e12, 204e9, mfu=0.20,
                     capability=0.0)
    return ClusterTopology("continuum-4",
                           (base.tiers[0], edge1) + base.tiers[1:])


TOPOLOGIES = {
    "edge-cloud": _edge_cloud,
    "edge-edge-cloud": _edge_edge_cloud,
    "edge-regional-cloud": _edge_regional_cloud,
    "continuum-4": _continuum_4,
}


def get_topology(name: str) -> ClusterTopology:
    try:
        return TOPOLOGIES[name]()
    except KeyError:
        raise KeyError(f"unknown topology {name!r}; "
                       f"have {sorted(TOPOLOGIES)}") from None


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # bfloat16 for ZeRO-memory-tight cells


@dataclass(frozen=True)
class TrainConfig:
    model: str = "tiny-dense"
    batch_size: int = 8
    seq_len: int = 256
    steps: int = 200
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    seed: int = 0
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)


# ---------------------------------------------------------------------------
# Roofline constants (TPU v5e, from the assignment)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RooflineConstants:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    hbm_per_chip: float = 16e9  # v5e HBM capacity


ROOFLINE = RooflineConstants()
