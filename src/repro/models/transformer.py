"""Decoder-only LM covering the dense / MoE / VLM / SSM / hybrid families.

One class, block-dispatch per family; repeated blocks run under
``jax.lax.scan`` (stacked params, O(1) HLO vs depth) with optional remat.
Three modes share the block code: ``train`` (full seq, no cache),
``prefill`` (full seq, emits cache), ``decode`` (one token, consumes cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig, ShardingConfig
from repro.distributed.sharding import logical_constraint
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (cross_entropy_loss, embed, pad_vocab,
                                 rms_norm, rope_freqs, apply_rope, unembed)
from repro.models.mlp import mlp_forward, mlp_specs
from repro.models.spec import (ParamSpec, init_tree, shape_tree)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(policy)


def attn_specs(cfg: ModelConfig, prefix_axes=(), include_mlp=True,
               moe=False) -> dict:
    ps = tuple(n for n, _ in prefix_axes)
    pa = tuple(a for _, a in prefix_axes)
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = {
        "ln1": ParamSpec(ps + (d,), pa + ("embed",), "zeros"),
        "wq": ParamSpec(ps + (d, h * hd), pa + ("embed", "heads"), "scaled"),
        "wk": ParamSpec(ps + (d, k * hd), pa + ("embed", "kv_heads"), "scaled"),
        "wv": ParamSpec(ps + (d, k * hd), pa + ("embed", "kv_heads"), "scaled"),
        "wo": ParamSpec(ps + (h * hd, d), pa + ("heads", "embed"), "scaled"),
        "ln2": ParamSpec(ps + (d,), pa + ("embed",), "zeros"),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec(ps + (hd,), pa + (None,), "zeros")
        s["k_norm"] = ParamSpec(ps + (hd,), pa + (None,), "zeros")
    if moe:
        s["moe"] = moe_mod.moe_specs(cfg, prefix_axes)
    elif include_mlp:
        s["mlp"] = mlp_specs(d, cfg.d_ff, cfg.activation, prefix_axes)
    return s


def rglru_unit_specs(cfg: ModelConfig, prefix_axes=()) -> dict:
    ps = tuple(n for n, _ in prefix_axes)
    pa = tuple(a for _, a in prefix_axes)
    s = rglru_mod.rglru_specs(cfg, prefix_axes)
    s["ln2"] = ParamSpec(ps + (cfg.d_model,), pa + ("embed",), "zeros")
    s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.activation, prefix_axes)
    return s


class DecoderLM:
    """Functional decoder LM; all methods are jit-compatible pure functions."""

    def __init__(self, cfg: ModelConfig, mesh=None,
                 sharding: ShardingConfig = ShardingConfig(),
                 attn_impl: str = "auto", moe_impl: str = "auto",
                 param_dtype: str = "", decode_impl: str = "auto"):
        assert cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid"), cfg.family
        self.cfg = cfg
        self.mesh = mesh
        self.sharding = sharding
        self.attn_impl = attn_impl
        self.moe_impl = moe_impl
        self.decode_impl = decode_impl
        # Unroll the layer loop in decode mode and scatter each layer's new
        # K/V directly into the layer-stacked cache leaf. The default
        # lax.scan over layers consumes the cache as a scanned input and
        # re-assembles the stacked output (a full KV-cache copy per token);
        # the unrolled form updates in place (under buffer donation), at the
        # cost of O(L) HLO — serving engines opt in, training/dry-run don't.
        self.decode_unroll = False
        self.v_pad = pad_vocab(cfg.vocab_size)
        self.dtype = jnp.dtype(param_dtype or cfg.dtype)
        # Megatron-style sequence parallelism: the residual stream (and thus
        # the per-layer saved activations under remat) is sharded over the
        # model axis between blocks; XLA re-gathers inside attention/MLP.
        self._seq = "seq_sp" if sharding.sequence_parallel else "seq"

    # ------------------------------------------------------------------
    # specs / init
    # ------------------------------------------------------------------

    def _hybrid_counts(self) -> Tuple[int, int]:
        """(full pattern repeats, extra leading-kind units)."""
        pat = len(self.cfg.block_pattern)
        return self.cfg.num_layers // pat, self.cfg.num_layers % pat

    def specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        s: Dict[str, Any] = {
            "embed": ParamSpec((self.v_pad, d), ("vocab", "embed"), "normal"),
            "ln_f": ParamSpec((d,), ("embed",), "zeros"),
        }
        if cfg.frontend == "vision_stub":
            s["proj_in"] = ParamSpec((cfg.frontend_dim, d), ("frontend", "embed"),
                                     "scaled")
        if not cfg.tie_embeddings:
            s["unembed"] = ParamSpec((self.v_pad, d), ("vocab", "embed"), "scaled")

        L = cfg.num_layers
        if cfg.family in ("dense", "vlm"):
            s["blocks"] = attn_specs(cfg, ((L, "layers"),))
        elif cfg.family == "moe":
            n_moe = L - cfg.first_k_dense
            if cfg.first_k_dense:
                s["dense_blocks"] = attn_specs(cfg, ((cfg.first_k_dense, "layers"),))
            s["blocks"] = attn_specs(cfg, ((n_moe, "layers"),), moe=True)
        elif cfg.family == "ssm":
            s["blocks"] = ssm_mod.ssd_specs(cfg, ((L, "layers"),))
        elif cfg.family == "hybrid":
            reps, extra = self._hybrid_counts()
            n_rec = sum(1 for b in cfg.block_pattern if b == "rglru")
            s["repeats"] = {
                "rglru": rglru_unit_specs(cfg, ((reps, "repeats"), (n_rec, "pattern"))),
                "attn": attn_specs(cfg, ((reps, "repeats"),)),
            }
            if extra:
                s["extra"] = rglru_unit_specs(cfg, ((extra, "layers"),))
        return s

    def init(self, rng: jax.Array) -> dict:
        return init_tree(self.specs(), rng, self.dtype)

    def param_shapes(self) -> dict:
        return shape_tree(self.specs(), self.dtype)

    # ------------------------------------------------------------------
    # input specs (dry-run / data pipeline contract)
    # ------------------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Tuple[dict, dict]:
        """(ShapeDtypeStruct tree, logical-axes tree) for a shape cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            axes = {"tokens": ("batch", "seq")}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
                axes["labels"] = ("batch", "seq")
            if cfg.frontend == "vision_stub":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.num_patches, cfg.frontend_dim), jnp.float32)
                axes["patches"] = ("batch", None, "frontend")
        else:  # decode: one token against a seq_len-deep cache
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, 1), i32),
                "positions": jax.ShapeDtypeStruct((b,), i32),
            }
            axes = {"tokens": ("batch", "seq"), "positions": ("batch",)}
        return specs, axes

    # ------------------------------------------------------------------
    # cache
    # ------------------------------------------------------------------

    def cache_spec_tree(self, batch: int, capacity: int) -> dict:
        """Shapes+axes of the decode cache as (ParamSpec-like) descriptors."""
        cfg = self.cfg
        hd, k = cfg.resolved_head_dim, cfg.num_kv_heads
        L = cfg.num_layers
        t: Dict[str, Any] = {}
        full_kv = lambda n: {
            "k": ParamSpec((n, batch, capacity, k, hd),
                           ("layers", "batch", "seq", "kv_heads", None), "zeros"),
            "v": ParamSpec((n, batch, capacity, k, hd),
                           ("layers", "batch", "seq", "kv_heads", None), "zeros"),
        }
        if cfg.family in ("dense", "vlm"):
            t["blocks"] = full_kv(L)
            t["pos"] = ParamSpec((batch, capacity), ("batch", "seq"), "zeros")
            t["index"] = ParamSpec((batch,), ("batch",), "zeros")
        elif cfg.family == "moe":
            if cfg.first_k_dense:
                t["dense_blocks"] = full_kv(cfg.first_k_dense)
            t["blocks"] = full_kv(L - cfg.first_k_dense)
            t["pos"] = ParamSpec((batch, capacity), ("batch", "seq"), "zeros")
            t["index"] = ParamSpec((batch,), ("batch",), "zeros")
        elif cfg.family == "ssm":
            cs = ssm_mod.ssd_cache_shape(cfg, batch)
            t["blocks"] = {
                "conv": ParamSpec((L,) + cs["conv"],
                                  ("layers", "batch", None, "heads"), "zeros"),
                "h": ParamSpec((L,) + cs["h"],
                               ("layers", "batch", "heads", None, None), "zeros"),
            }
        elif cfg.family == "hybrid":
            reps, extra = self._hybrid_counts()
            n_rec = sum(1 for bk in cfg.block_pattern if bk == "rglru")
            w = min(capacity, cfg.local_window)
            cs = rglru_mod.rglru_cache_shape(cfg, batch)
            rg = lambda pre, preax: {
                "conv": ParamSpec(pre + cs["conv"], preax + ("batch", None, "heads"),
                                  "zeros"),
                "h": ParamSpec(pre + cs["h"], preax + ("batch", "heads"), "zeros"),
            }
            t["repeats"] = {
                "rglru": rg((reps, n_rec), ("repeats", "pattern")),
                "attn": {
                    "k": ParamSpec((reps, batch, w, cfg.num_kv_heads, hd),
                                   ("repeats", "batch", "seq", "kv_heads", None), "zeros"),
                    "v": ParamSpec((reps, batch, w, cfg.num_kv_heads, hd),
                                   ("repeats", "batch", "seq", "kv_heads", None), "zeros"),
                },
            }
            if extra:
                t["extra"] = rg((extra,), ("layers",))
            t["pos"] = ParamSpec((batch, w), ("batch", "seq"), "zeros")
            t["index"] = ParamSpec((batch,), ("batch",), "zeros")
        return t

    def cache_specs(self, batch: int, capacity: int):
        """ShapeDtypeStruct tree of the decode cache.

        pos/index are int32; recurrent ``h`` states are fp32 (accumulated);
        kv and conv history use the model dtype.
        """
        tree = self.cache_spec_tree(batch, capacity)
        out = {}
        for key, sub in tree.items():
            if key in ("pos", "index"):
                out[key] = jax.ShapeDtypeStruct(sub.shape, jnp.int32)
                continue
            out[key] = jax.tree_util.tree_map_with_path(
                lambda path, ps: jax.ShapeDtypeStruct(
                    ps.shape,
                    jnp.float32 if any(
                        getattr(p, "key", None) == "h" for p in path)
                    else self.dtype),
                sub, is_leaf=lambda x: isinstance(x, ParamSpec))
        return out

    def cache_axes(self, batch: int, capacity: int) -> dict:
        """Logical-axes tree parallel to cache_specs (for dry-run sharding)."""
        return jax.tree.map(lambda ps: ps.axes,
                            self.cache_spec_tree(batch, capacity),
                            is_leaf=lambda x: isinstance(x, ParamSpec))

    def init_cache(self, batch: int, capacity: int) -> dict:
        structs = self.cache_specs(batch, capacity)
        cache = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), structs)
        if "pos" in cache:
            cache["pos"] = jnp.full(cache["pos"].shape, -1, jnp.int32)
        return cache

    # ------------------------------------------------------------------
    # blocks
    # ------------------------------------------------------------------

    def _constrain(self, x, axes):
        return logical_constraint(x, axes, self.mesh)

    def _attn_block(self, lp, x, cos, sin, pos_q, pos_kv, mode, window,
                    lcache, idx, moe: bool, layer: Optional[int] = None,
                    ctx: Optional[int] = None,
                    pages: Optional[jax.Array] = None):
        cfg = self.cfg
        b, s, d = x.shape
        h_, k_, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        hh = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dh->bsh", hh, lp["wq"]).reshape(b, s, h_, hd)
        k = jnp.einsum("bsd,dh->bsh", hh, lp["wk"]).reshape(b, s, k_, hd)
        v = jnp.einsum("bsd,dh->bsh", hh, lp["wv"]).reshape(b, s, k_, hd)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
            k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        q = self._constrain(q, ("batch", "seq", "heads", None))

        new_cache = None
        if mode == "chunk":
            # multi-token decode against the cache ("suffix prefill"): the
            # S new tokens' KV rows land at consecutive indices after the
            # per-slot write index (linear placement — full-capacity-pos
            # families only), and attention masks by absolute position, so
            # each new token sees the cached prefix plus its causal
            # predecessors within the chunk. One weights pass for the whole
            # suffix instead of one per token.
            bi = jnp.arange(b)
            widx = idx[:, None] + jnp.arange(s)[None]  # (B,S)
            kc = lcache["k"].at[bi[:, None], widx].set(
                k.astype(lcache["k"].dtype))
            vc = lcache["v"].at[bi[:, None], widx].set(
                v.astype(lcache["v"].dtype))
            kr, vr, pr = kc, vc, pos_kv
            if ctx is not None and ctx < kr.shape[1]:
                kr, vr, pr = kr[:, :ctx], vr[:, :ctx], pos_kv[:, :ctx]
            out = attn_mod.attention(q, kr, vr, pos_q, pr, causal=True,
                                     window=window, impl=self.attn_impl)
            new_cache = {"k": kc, "v": vc}
        elif mode == "ringchunk":
            # multi-token decode against a RING-BUFFER window cache (hybrid
            # family suffix prefill): each suffix token attends the old ring
            # content plus the suffix's own KV, masked by absolute position.
            # Exact for ANY suffix length: whatever a per-token decode would
            # have overwritten before token t carries a position <= t - window
            # and is window-masked regardless. The new ring is rebuilt from
            # the suffix tail under the invariant "position p lives at slot
            # p % w".
            w = lcache["k"].shape[1]
            kcat = jnp.concatenate([lcache["k"].astype(k.dtype), k], axis=1)
            vcat = jnp.concatenate([lcache["v"].astype(v.dtype), v], axis=1)
            pcat = jnp.concatenate([pos_kv, pos_q.astype(jnp.int32)], axis=1)
            # local/pallas impls assume a self-attention layout (no cache
            # positions); force a position-aware path for the concat layout
            impl = self.attn_impl if self.attn_impl in ("dense", "flash") \
                else "auto"
            out = attn_mod.attention(q, kcat, vcat, pos_q, pcat, causal=True,
                                     window=window, impl=impl)
            bi = jnp.arange(b)
            if s < w:
                widx = (idx[:, None] + jnp.arange(s)[None]) % w  # (B,S)
                kc = lcache["k"].at[bi[:, None], widx].set(
                    k.astype(lcache["k"].dtype))
                vc = lcache["v"].at[bi[:, None], widx].set(
                    v.astype(lcache["v"].dtype))
            else:
                roll = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))
                r0 = pos_q[:, s - w] % w
                kc = roll(k[:, s - w:], r0).astype(lcache["k"].dtype)
                vc = roll(v[:, s - w:], r0).astype(lcache["v"].dtype)
            new_cache = {"k": kc, "v": vc}
        elif mode == "decode" and pages is not None:
            # paged decode: KV rows live in a shared physical pool
            # ((L,)P,page,K,hd); the slot's int32 page table maps logical
            # page -> physical page (0 = null page). The write scatters one
            # row THROUGH the table; attention gathers whole pages through
            # it and masks unwritten entries via pos (so null-page garbage
            # contributes an exact zero).
            bi = jnp.arange(b)
            page = lcache["k"].shape[-3]
            pg = pages[bi, idx // page]  # (B,) physical page of the write
            off = idx % page
            if layer is None:
                kc = lcache["k"].at[pg, off].set(
                    k[:, 0].astype(lcache["k"].dtype))
                vc = lcache["v"].at[pg, off].set(
                    v[:, 0].astype(lcache["v"].dtype))
                kp, vp = kc, vc
            else:
                kc = lcache["k"].at[layer, pg, off].set(
                    k[:, 0].astype(lcache["k"].dtype))
                vc = lcache["v"].at[layer, pg, off].set(
                    v[:, 0].astype(lcache["v"].dtype))
                kp, vp = kc[layer], vc[layer]
            cap = pos_kv.shape[1]
            ctx_eff = ctx if (ctx is not None and ctx < cap) else cap
            npg = -(-ctx_eff // page)  # whole pages covering the context
            out = attn_mod.decode_attention_paged(
                q, kp, vp, pages[:, :npg], pos_q[:, 0],
                pos_kv[:, :ctx_eff], window=window, impl=self.decode_impl)
            new_cache = {"k": kc, "v": vc}
        elif mode == "decode":
            # per-slot write position (continuous batching: slots independent)
            bi = jnp.arange(b)
            if layer is None:
                kc = lcache["k"].at[bi, idx].set(
                    k[:, 0].astype(lcache["k"].dtype))
                vc = lcache["v"].at[bi, idx].set(
                    v[:, 0].astype(lcache["v"].dtype))
                kr, vr = kc, vc
            else:
                # unrolled decode: lcache leaves stay layer-stacked
                # (L,B,T,K,hd); scatter THIS layer's row in place
                kc = lcache["k"].at[layer, bi, idx].set(
                    k[:, 0].astype(lcache["k"].dtype))
                vc = lcache["v"].at[layer, bi, idx].set(
                    v[:, 0].astype(lcache["v"].dtype))
                kr, vr = kc[layer], vc[layer]
            pr = pos_kv
            if ctx is not None and ctx < kr.shape[1]:
                # context hint: attend only the leading ctx cache entries
                # (linear placement; caller guarantees every live position,
                # including this token's write, sits below ctx). Static
                # slices — XLA fuses them into the attention reads instead
                # of copying the cache.
                kr, vr, pr = kr[:, :ctx], vr[:, :ctx], pos_kv[:, :ctx]
            out = attn_mod.decode_attention(q, kr, vr, pos_q[:, 0], pr,
                                            window=window,
                                            impl=self.decode_impl)
            new_cache = {"k": kc, "v": vc}
        else:
            out = attn_mod.attention(
                q, k, v, pos_q, pos_q, causal=True, window=window,
                impl=self.attn_impl)
            if mode == "prefill":
                new_cache = {"k": k, "v": v}
        out = out.reshape(b, s, h_ * hd)
        x = x + jnp.einsum("bsh,hd->bsd", out, lp["wo"]).astype(x.dtype)
        x = self._constrain(x, ("batch", self._seq, "embed"))

        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if moe:
            y, aux = moe_mod.moe_forward(lp["moe"], h2, cfg, self.mesh,
                                         self.moe_impl)
        else:
            y, aux = mlp_forward(lp["mlp"], h2, cfg.activation), jnp.zeros((), jnp.float32)
        x = x + y.astype(x.dtype)
        return self._constrain(x, ("batch", self._seq, "embed")), aux, new_cache

    def _rglru_unit(self, lp, x, mode, lcache):
        cfg = self.cfg
        if mode == "decode":
            x, new_cache = rglru_mod.rglru_decode(lp, x, cfg, lcache)
        elif mode == "ringchunk":
            # stateful suffix pass: fold the cached decode state (conv window
            # + LRU hidden) into the full-sequence scan
            x, new_cache = rglru_mod.rglru_forward(
                lp, x, cfg, conv_state=lcache["conv"], h_state=lcache["h"])
        else:
            x, new_cache = rglru_mod.rglru_forward(lp, x, cfg)
            if mode == "train":
                new_cache = None
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_forward(lp["mlp"], h2, cfg.activation).astype(x.dtype)
        return x, new_cache

    # ------------------------------------------------------------------
    # the stack
    # ------------------------------------------------------------------

    def _rope(self, positions):
        cfg = self.cfg
        if cfg.family == "ssm":
            return None, None
        return rope_freqs(positions, cfg.resolved_head_dim, cfg.rope_theta)

    def _stack(self, params, x, positions, mode, cache, ctx=None):
        cfg = self.cfg
        cos, sin = self._rope(positions)
        remat_on = mode == "train"
        policy = self.sharding.remat_policy if remat_on else "none"
        idx = cache["index"] if (cache is not None and "index" in cache) else None
        pos_kv = cache["pos"] if (cache is not None and "pos" in cache) else None
        pages = cache.get("pages") if cache is not None else None

        if cfg.family in ("dense", "vlm", "moe"):
            aux_total = jnp.zeros((), jnp.float32)
            new_cache: Dict[str, Any] = {}

            def run_group(x, aux_total, gparams, gcache, moe_flag):
                if mode == "decode" and self.decode_unroll and gcache is not None:
                    return self._run_group_unrolled(
                        x, aux_total, gparams, gcache, moe_flag, cos, sin,
                        positions, pos_kv, idx, ctx, pages)

                def body(carry, xs):
                    xx, aux = carry
                    lp, lc = xs
                    xx, a, nc = self._attn_block(
                        lp, xx, cos, sin, positions, pos_kv, mode, None,
                        lc, idx, moe_flag, ctx=ctx, pages=pages)
                    return (xx, aux + a), nc
                bodyc = _remat(body, policy)
                if gcache is None:
                    (x, aux_total), ys = jax.lax.scan(
                        lambda c, lp: bodyc(c, (lp, None)), (x, aux_total),
                        gparams)
                else:
                    (x, aux_total), ys = jax.lax.scan(
                        bodyc, (x, aux_total), (gparams, gcache))
                return x, aux_total, ys

            if cfg.family == "moe" and cfg.first_k_dense:
                gcache = cache.get("dense_blocks") if cache else None
                x, aux_total, ys = run_group(x, aux_total, params["dense_blocks"],
                                             gcache, False)
                if mode != "train" and ys is not None:
                    new_cache["dense_blocks"] = ys
            gcache = cache.get("blocks") if cache else None
            x, aux_total, ys = run_group(x, aux_total, params["blocks"], gcache,
                                         cfg.family == "moe")
            if mode != "train" and ys is not None:
                new_cache["blocks"] = ys
            return x, aux_total, new_cache

        if cfg.family == "ssm":
            def body(carry, xs):
                xx = carry
                lp, lc = xs
                if mode == "decode":
                    xx, nc = ssm_mod.ssd_decode(lp, xx, cfg, lc)
                else:
                    xx, nc = ssm_mod.ssd_forward(lp, xx, cfg)
                    if mode == "train":
                        nc = None
                return xx, nc
            bodyc = _remat(body, policy)
            gcache = cache.get("blocks") if cache else None
            if gcache is None:
                x, ys = jax.lax.scan(lambda c, lp: bodyc(c, (lp, None)), x,
                                     params["blocks"])
            else:
                x, ys = jax.lax.scan(bodyc, x, (params["blocks"], gcache))
            new_cache = {"blocks": ys} if (mode != "train" and ys is not None) else {}
            return x, jnp.zeros((), jnp.float32), new_cache

        if cfg.family == "hybrid":
            return self._hybrid_stack(params, x, positions, cos, sin, mode, cache)

        raise ValueError(cfg.family)

    def _run_group_unrolled(self, x, aux_total, gparams, gcache, moe_flag,
                            cos, sin, positions, pos_kv, idx, ctx=None,
                            pages=None):
        """Decode-mode layer loop unrolled; the stacked KV leaves thread
        through and receive one in-place (l, slot, idx) scatter per layer
        (numerically identical to the scanned form, no per-token copy)."""
        n_layers = jax.tree.leaves(gparams)[0].shape[0]
        cache = gcache
        for l in range(n_layers):
            lp = jax.tree.map(lambda p: p[l], gparams)
            x, a, cache = self._attn_block(
                lp, x, cos, sin, positions, pos_kv, "decode", None,
                cache, idx, moe_flag, layer=l, ctx=ctx, pages=pages)
            aux_total = aux_total + a
        return x, aux_total, cache

    def _hybrid_stack(self, params, x, positions, cos, sin, mode, cache):
        cfg = self.cfg
        reps, extra = self._hybrid_counts()
        n_rec = sum(1 for bk in cfg.block_pattern if bk == "rglru")
        policy = self.sharding.remat_policy if mode == "train" else "none"
        idx = cache["index"] if (cache is not None and "index" in cache) else None
        pos_kv = cache["pos"] if (cache is not None and "pos" in cache) else None
        win = cfg.local_window

        def repeat_body(carry, xs):
            xx = carry
            lp, lc = xs
            rg_caches = []
            for i in range(n_rec):
                sub = jax.tree.map(lambda p: p[i], lp["rglru"])
                subc = jax.tree.map(lambda p: p[i], lc["rglru"]) if lc else None
                xx, nc = self._rglru_unit(sub, xx, mode, subc)
                rg_caches.append(nc)
            xx, _, anc = self._attn_block(
                lp["attn"], xx, cos, sin, positions, pos_kv, mode, win,
                lc["attn"] if lc else None, idx, False)
            ys = None
            if mode != "train":
                ys = {"rglru": jax.tree.map(lambda *a: jnp.stack(a), *rg_caches),
                      "attn": anc}
            return xx, ys

        bodyc = _remat(repeat_body, policy)
        gcache = cache.get("repeats") if cache else None
        if gcache is None:
            x, ys = jax.lax.scan(lambda c, lp: bodyc(c, (lp, None)), x,
                                 params["repeats"])
        else:
            x, ys = jax.lax.scan(bodyc, x, (params["repeats"], gcache))
        new_cache = {"repeats": ys} if (mode != "train" and ys is not None) else {}

        if extra:
            ex_caches = []
            for i in range(extra):
                sub = jax.tree.map(lambda p: p[i], params["extra"])
                subc = (jax.tree.map(lambda p: p[i], cache["extra"])
                        if cache and "extra" in cache else None)
                x, nc = self._rglru_unit(sub, x, mode, subc)
                ex_caches.append(nc)
            if mode != "train" and ex_caches[0] is not None:
                new_cache["extra"] = jax.tree.map(lambda *a: jnp.stack(a), *ex_caches)
        return x, jnp.zeros((), jnp.float32), new_cache

    # ------------------------------------------------------------------
    # modes
    # ------------------------------------------------------------------

    def _embed_inputs(self, params, batch, mode):
        cfg = self.cfg
        x = embed(batch["tokens"], params["embed"]).astype(self.dtype)
        prefix = 0
        if (cfg.frontend == "vision_stub"
                and mode not in ("decode", "chunk") and "patches" in batch):
            px = jnp.einsum("bpf,fd->bpd",
                            batch["patches"].astype(self.dtype),
                            params["proj_in"])
            x = jnp.concatenate([px, x], axis=1)
            prefix = px.shape[1]
        return self._constrain(x, ("batch", self._seq, "embed")), prefix

    def forward(self, params, batch, mode="train", cache=None, ctx=None):
        """Backbone -> final hidden states (B, S_total, D)."""
        x, prefix = self._embed_inputs(params, batch, mode)
        b, s, _ = x.shape
        if mode == "decode":
            positions = batch["positions"][:, None]  # (B,1)
        elif mode == "chunk":
            positions = batch["positions"]  # (B,S) absolute suffix positions
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                         (b, s))
        x, aux, new_cache = self._stack(params, x, positions, mode, cache, ctx)
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        return x, aux, new_cache, prefix

    def _unembed_table(self, params):
        return params["embed"] if self.cfg.tie_embeddings else params["unembed"]

    def _chunked_ce(self, hidden, table, labels, mask):
        b, s, d = hidden.shape
        nc = 1
        for cand in (8, 4, 2, 1):
            if s % cand == 0 and s // cand >= 128:
                nc = cand
                break
        c = s // nc
        hs = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
        ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
        ms = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)

        def body(carry, xs):
            tot, cnt = carry
            h, lab, mk = xs
            # h upcast to f32 -> einsum accumulates f32 without copying `table`
            logits = unembed(h.astype(jnp.float32), table,
                             self.cfg.vocab_size)
            logits = self._constrain(logits, ("batch", "seq", "vocab"))
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
            nll = (logz - gold) * mk
            return (tot + jnp.sum(nll), cnt + jnp.sum(mk)), None

        (tot, cnt), _ = jax.lax.scan(
            jax.checkpoint(body), (jnp.zeros((), jnp.float32),) * 2, (hs, ls, ms))
        return tot / jnp.maximum(cnt, 1.0)

    def loss(self, params, batch):
        """Mean CE (+ MoE aux). batch: tokens, labels, optional patches/mask."""
        hidden, aux, _, prefix = self.forward(params, batch, "train")
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(labels, jnp.float32)
        if prefix:  # VLM: no loss on image-prefix positions
            b = labels.shape[0]
            labels = jnp.concatenate(
                [jnp.zeros((b, prefix), labels.dtype), labels], axis=1)
            mask = jnp.concatenate(
                [jnp.zeros((b, prefix), mask.dtype), mask], axis=1)
        ce = self._chunked_ce(hidden, self._unembed_table(params), labels,
                              mask.astype(jnp.float32))
        total = ce + self.cfg.router_aux_coef * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(self, params, batch, capacity: int):
        """Run the prompt, return (last-token logits (B, V), cache).

        With right-padded (length-bucketed) batches, ``batch["lengths"]``
        (B,) gives each row's true token count; logits are then gathered at
        each row's true last token instead of the shared final column. Pad
        rows sit AFTER all real tokens, so causal attention leaves real-token
        activations untouched; their stale cache entries are masked by the
        caller via the absolute-position ``pos`` leaf.
        """
        cfg = self.cfg
        hidden, _, layer_caches, prefix = self.forward(params, batch, "prefill")
        b, s, _ = hidden.shape
        if "lengths" in batch:
            last = prefix + batch["lengths"].astype(jnp.int32) - 1  # (B,)
            hl = hidden[jnp.arange(b), last][:, None]
        else:
            hl = hidden[:, -1:]
        logits = unembed(hl.astype(jnp.float32),
                         self._unembed_table(params).astype(jnp.float32),
                         cfg.vocab_size)[:, 0]
        cache = self._assemble_prefill_cache(layer_caches, b, s, capacity)
        return logits, cache

    def _assemble_prefill_cache(self, layer_caches, b, s, capacity):
        """Pad/roll per-layer prefill KV into capacity-sized decode caches."""
        cfg = self.cfg
        cache: Dict[str, Any] = {}

        def pad_full(kv):  # (L,B,S,K,hd) -> (L,B,T,K,hd)
            if s >= capacity:
                return kv[:, :, s - capacity:]
            pad = [(0, 0)] * kv.ndim
            pad[2] = (0, capacity - s)
            return jnp.pad(kv, pad)

        if cfg.family in ("dense", "vlm", "moe"):
            for key in ("dense_blocks", "blocks"):
                if key in layer_caches:
                    cache[key] = jax.tree.map(pad_full, layer_caches[key])
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                   (b, s))
            if s >= capacity:
                pos = pos[:, s - capacity:]
            else:
                pos = jnp.pad(pos, ((0, 0), (0, capacity - s)),
                              constant_values=-1)
            cache["pos"] = pos
            cache["index"] = jnp.full((b,), min(s, capacity) % max(capacity, 1),
                                      jnp.int32)
        elif cfg.family == "ssm":
            cache["blocks"] = layer_caches["blocks"]
        elif cfg.family == "hybrid":
            w = min(capacity, cfg.local_window)

            def ring(kv):  # (R,B,S,K,hd) -> (R,B,w,K,hd) ring-consistent
                if s >= w:
                    last = kv[:, :, s - w:]
                    return jnp.roll(last, (s - w) % w, axis=2)
                pad = [(0, 0)] * kv.ndim
                pad[2] = (0, w - s)
                return jnp.pad(kv, pad)

            rep = layer_caches["repeats"]
            cache["repeats"] = {"rglru": rep["rglru"],
                                "attn": jax.tree.map(ring, rep["attn"])}
            if "extra" in layer_caches:
                cache["extra"] = layer_caches["extra"]
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
            if s >= w:
                pos = jnp.roll(pos[:, s - w:], (s - w) % w, axis=1)
            else:
                pos = jnp.pad(pos, ((0, 0), (0, w - s)), constant_values=-1)
            cache["pos"] = pos
            cache["index"] = jnp.full((b,), s % w, jnp.int32)
        return cache

    def decode_step(self, params, cache, batch, ctx=None):
        """One token. batch: tokens (B,1), positions (B,). Returns (logits, cache).

        ``ctx`` (static) hints that every live cache entry — including this
        token's write — sits at an index below ``ctx``: attention then reads
        only the leading ``ctx`` entries of the full-width cache (the
        serving engine's context buckets). Bookkeeping (pos/index/scatter)
        always stays full-width, so the cache layout is unchanged.
        """
        cfg = self.cfg
        new_cache = dict(cache)
        if "pos" in cache:
            idx = cache["index"]  # (B,) per-slot write positions
            bi = jnp.arange(idx.shape[0])
            new_cache["pos"] = cache["pos"].at[bi, idx].set(
                batch["positions"].astype(jnp.int32))
            cap = cache["pos"].shape[1]
            new_cache["index"] = (idx + 1) % cap
            cache = dict(cache)
            cache["pos"] = new_cache["pos"]  # new token must see itself
        hidden, _, layer_caches, _ = self.forward(params, batch, "decode",
                                                  cache, ctx)
        for key, val in layer_caches.items():
            new_cache[key] = val
        logits = unembed(hidden.astype(jnp.float32),
                         self._unembed_table(params).astype(jnp.float32),
                         cfg.vocab_size)[:, 0]
        return logits, new_cache

    def decode_chunk(self, params, cache, batch, ctx=None,
                     all_logits=False):
        """Multi-token decode against the cache — the suffix prefill of a
        prefix-cache hit / resumed session. batch: tokens (B,S), absolute
        positions (B,S), optional lengths (B,) true counts for right-padded
        rows. Returns (last-real-token logits (B,V), cache), or with
        ``all_logits=True`` the full per-position logits (B,S,V) — the
        speculative-decoding verify pass samples the target's own token at
        EVERY draft position from one chunk.

        One weights pass covers the whole suffix; attention masks by
        absolute position against the cached prefix (and the suffix's own
        causal order). Only the full-capacity-pos families qualify —
        writes land linearly after each row's write index, and a padded
        row's stale tail entries carry positions past its true end, which
        the CALLER re-masks to -1 (same contract as bucketed prefill).
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm", "moe"), (
            "decode_chunk needs positionally addressable KV; "
            f"{cfg.family} carries point-in-time recurrent state")
        b, s = batch["tokens"].shape
        bi = jnp.arange(b)
        new_cache = dict(cache)
        idx = cache["index"]  # (B,) per-slot write start
        widx = idx[:, None] + jnp.arange(s)[None]  # (B,S) linear placement
        new_cache["pos"] = cache["pos"].at[bi[:, None], widx].set(
            batch["positions"].astype(jnp.int32))
        cap = cache["pos"].shape[1]
        new_cache["index"] = ((idx + s) % cap).astype(jnp.int32)
        cache = dict(cache)
        cache["pos"] = new_cache["pos"]  # new tokens must see themselves
        hidden, _, layer_caches, _ = self.forward(params, batch, "chunk",
                                                  cache, ctx)
        for key, val in layer_caches.items():
            new_cache[key] = val
        if all_logits:
            logits = unembed(hidden.astype(jnp.float32),
                             self._unembed_table(params).astype(jnp.float32),
                             cfg.vocab_size)
            return logits, new_cache
        if "lengths" in batch:
            last = batch["lengths"].astype(jnp.int32) - 1  # (B,)
            hl = hidden[bi, last][:, None]
        else:
            hl = hidden[:, -1:]
        logits = unembed(hl.astype(jnp.float32),
                         self._unembed_table(params).astype(jnp.float32),
                         cfg.vocab_size)[:, 0]
        return logits, new_cache

    def decode_chunk_recurrent(self, params, cache, batch,
                               all_logits=False):
        """Multi-token decode for the RECURRENT families (ssm/hybrid) — the
        suffix prefill of a prefix-cache hit / resumed session. batch:
        tokens (B,S), absolute positions (B,S) continuing the cached state
        (no padding: every token advances the recurrence). Returns
        (last-token logits (B,V), cache), or all per-position logits
        (B,S,V) under ``all_logits=True`` (speculative verify).

        The cached state (conv window + SSM/LRU hidden) summarizes the
        whole prefix at a point in time, so the suffix replays in ONE
        chunked pass: ``ssd_forward``/``rglru_forward`` fold the initial
        state into their scans instead of stepping token-by-token. The
        hybrid family's sliding-window ring is handled by the "ringchunk"
        attention branch (old ring + suffix KV under absolute-position
        window masking — exact for any suffix length).
        """
        cfg = self.cfg
        assert cfg.family in ("ssm", "hybrid"), (
            "decode_chunk_recurrent seeds point-in-time recurrent state; "
            f"use decode_chunk for {cfg.family}")
        x, _ = self._embed_inputs(params, batch, "chunk")
        b, s, _ = x.shape
        positions = batch["positions"].astype(jnp.int32)  # (B,S)
        new_cache = dict(cache)

        if cfg.family == "ssm":
            def body(xx, xs):
                lp, lc = xs
                xx, nc = ssm_mod.ssd_forward(
                    lp, xx, cfg, conv_state=lc["conv"], h_state=lc["h"])
                return xx, nc
            x, ys = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
            new_cache["blocks"] = ys
        else:
            cos, sin = self._rope(positions)
            x, _, layer_caches = self._hybrid_stack(
                params, x, positions, cos, sin, "ringchunk", cache)
            for key, val in layer_caches.items():
                new_cache[key] = val
            # ring bookkeeping (invariant: position p lives at slot p % w)
            w = cache["pos"].shape[1]
            idx = cache["index"]
            bi = jnp.arange(b)
            if s < w:
                widx = (idx[:, None] + jnp.arange(s)[None]) % w
                new_cache["pos"] = cache["pos"].at[bi[:, None], widx].set(
                    positions)
            else:
                roll = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))
                new_cache["pos"] = roll(positions[:, s - w:],
                                        positions[:, s - w] % w)
            new_cache["index"] = ((idx + s) % w).astype(jnp.int32)

        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if all_logits:
            return unembed(x.astype(jnp.float32),
                           self._unembed_table(params).astype(jnp.float32),
                           cfg.vocab_size), new_cache
        logits = unembed(x[:, -1:].astype(jnp.float32),
                        self._unembed_table(params).astype(jnp.float32),
                        cfg.vocab_size)[:, 0]
        return logits, new_cache
