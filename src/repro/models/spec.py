"""Parameter-spec system: one declarative tree drives init, dry-run
ShapeDtypeStructs, and sharding PartitionSpecs — no triple maintenance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Axis = Optional[str]


@dataclass(frozen=True)
class ParamSpec:
    """Declarative description of one parameter tensor.

    ``axes`` are *logical* axis names (resolved to mesh axes by
    ``repro.distributed.sharding``); len(axes) == len(shape).
    """

    shape: Tuple[int, ...]
    axes: Tuple[Axis, ...]
    init: str = "normal"  # normal | zeros | ones | scaled | small
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Dict) -> Dict:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def init_param(spec: ParamSpec, key: jax.Array, dtype: jnp.dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)
    if spec.init == "scaled":  # fan-in scaled (truncated-normal-ish)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    if spec.init == "small":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 1e-3).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_tree(specs: Dict, rng: jax.Array, dtype: jnp.dtype) -> Dict:
    """Materialize a param pytree from a spec tree (deterministic per-path keys)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    arrs = [init_param(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def shape_tree(specs: Dict, dtype: jnp.dtype) -> Dict:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs)


def axes_tree(specs: Dict) -> Dict:
    return tree_map_specs(lambda s: s.axes, specs)


def count_tree(specs: Dict) -> int:
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec):
        n = 1
        for d in s.shape:
            n *= d
        total += n
    return total
